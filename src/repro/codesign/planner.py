"""The single plan path every Pallas kernel tiles through.

:func:`plan` replaces the three hand-rolled kernel planners
(``matmul.ops.plan_tiles``, ``flash_attention.ops.plan_blocks``,
``ssd_scan.ops.plan_chunk``): one search over the kernel's
:class:`~repro.codesign.space.KernelSpace` via the existing
``union_opt`` -> ``EvaluationEngine`` machinery, one ``legalize`` repair,
one fallback ledger, and one plan cache.

Plan caching rides the persistent :class:`~repro.core.cost.store.
ResultStore` (same corruption-tolerant versioned JSON tier, same atomic
flush discipline): finished plans are stored under a
**constraints-inclusive space key** -- the digest of (planner version,
kernel space identity, constraints content, mapper, search budget,
metric, cost-model ``store_key_parts()``) -- with the shape and VMEM
budget in the entry signature. A warm query therefore answers in O(ms)
from memory or disk without invoking a mapper search; plan records can
never collide with mapping-cost records because the space-key digests
live in disjoint namespaces (``"plan"`` marker + planner fields).

Failure discipline: the historical planners wrapped ``union_opt`` in a
bare ``except Exception`` -- any bug anywhere in the engine silently
degraded every kernel to default tiles. Here only the EXPECTED search
failures (:data:`PLAN_SEARCH_ERRORS`: a mapper exhausting its budget
without a legal mapping, or a degenerate/non-conformable space) fall back
to ``space.default_config``; each fallback is counted in the
``plan_fallbacks`` ledger (same style as the engine's
``backend_fallbacks``). Anything else propagates.
"""

from __future__ import annotations

import hashlib
import json
import logging
import threading
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

from repro.core.constraints import Constraints
from repro.core.cost.base import Cost, CostModel
from repro.core.cost.store import ResultStore
from repro.codesign.space import BlockConfig, KernelSpace

log = logging.getLogger("repro.codesign")

#: bump when decode/legalize/key semantics change: cached plans from older
#: planner revisions are then keyed apart and re-searched, never misread.
PLANNER_VERSION = 1

#: The EXPECTED ways a mapping search can fail: ``union_opt`` raises
#: RuntimeError when the mapper finds no legal mapping within its budget
#: and ValueError when the (problem, model) pair is degenerate or
#: non-conformable. Only these fall back to default tiles -- anything
#: else is a real bug and propagates.
PLAN_SEARCH_ERRORS = (RuntimeError, ValueError)


@dataclass
class Plan:
    """One finished plan: the legal BlockConfig plus its provenance."""

    space: str
    shape: Tuple[int, ...]
    config: BlockConfig
    cost: Optional[Cost]  # model cost of the LEGALIZED config (predict)
    source: str  # "search" | "store" | "fallback"
    fallback: bool = False


# ---------------------------------------------------------------------- #
# ledger (same style as the engine's backend_fallbacks counter)
# ---------------------------------------------------------------------- #
_STATS_LOCK = threading.Lock()
_STATS = {
    "plan_requests": 0,
    "plan_searches": 0,
    "plan_store_hits": 0,
    "plan_fallbacks": 0,
}


def planner_stats() -> dict:
    with _STATS_LOCK:
        return dict(_STATS)


def reset_planner_stats() -> None:
    with _STATS_LOCK:
        for k in _STATS:
            _STATS[k] = 0


def _bump(key: str) -> None:
    with _STATS_LOCK:
        _STATS[key] += 1


# ---------------------------------------------------------------------- #
# plan store
# ---------------------------------------------------------------------- #
_default_store = ResultStore()
_default_store_lock = threading.Lock()


def get_plan_store() -> ResultStore:
    return _default_store


def set_plan_store(store: "Union[ResultStore, str, None]") -> ResultStore:
    """Replace the process-wide default plan store. Pass a directory path
    for a persistent disk tier, a ready :class:`ResultStore`, or ``None``
    to reset to a fresh in-memory store."""
    global _default_store
    with _default_store_lock:
        if store is None:
            _default_store = ResultStore()
        elif isinstance(store, ResultStore):
            _default_store = store
        else:
            _default_store = ResultStore(str(store))
        return _default_store


# ---------------------------------------------------------------------- #
# keys
# ---------------------------------------------------------------------- #
def _canon_constraints(cons: Constraints) -> dict:
    return {
        "name": cons.name,
        "allowed_spatial": sorted(
            (k, sorted(v)) for k, v in cons.allowed_spatial_dims.items()
        ),
        "required_spatial": sorted(
            (k, sorted(v)) for k, v in cons.required_spatial_dims.items()
        ),
        "loop_orders": sorted(
            (k, list(v)) for k, v in cons.loop_orders.items()
        ),
        "allowed_tile_sizes": sorted(
            (list(k), sorted(v)) for k, v in cons.allowed_tile_sizes.items()
        ),
        "tile_multiples": sorted(cons.tile_multiples.items()),
        "max_concurrent_spatial": cons.max_concurrent_spatial,
        "min_utilization": cons.min_utilization,
        "max_utilization": cons.max_utilization,
    }


def plan_space_key(
    space: KernelSpace,
    cons: Constraints,
    mapper: str,
    budget: int,
    metric: str,
    model: CostModel,
) -> str:
    """Constraints-inclusive plan-cache space key (disjoint from mapping-
    cost space keys by construction: those digest problem/arch content,
    this digests the ``"plan"`` marker + planner identity)."""
    desc = json.dumps(
        {
            "plan": PLANNER_VERSION,
            "space": space.name,
            "decode_dims": list(space.decode_dims),
            "constraints": _canon_constraints(cons),
            "mapper": mapper,
            "budget": int(budget),
            "metric": metric,
            "model": [repr(p) for p in model.store_key_parts()],
        },
        sort_keys=True,
    )
    return hashlib.sha256(desc.encode()).hexdigest()[:32]


def _plan_sig(shape: Sequence[int], vmem_budget: int):
    """Store-entry signature for one (shape, budget) plan. Shaped like a
    one-level mapping signature -- ``(order, tt, st)`` -- so it round-trips
    the store's JSON codec unchanged."""
    return ((("plan",), tuple(int(s) for s in shape), (int(vmem_budget),)),)


def _plan_record(config: BlockConfig, cost: Optional[Cost], fallback: bool) -> Cost:
    """Encode a finished plan as a Cost record (the store's value type):
    predicted scalars in the Cost fields, the BlockConfig + flags in the
    ``str -> float`` breakdown."""
    breakdown = {f"plan::{i}": float(b) for i, b in enumerate(config)}
    breakdown["plan::n"] = float(len(config))
    breakdown["plan::fallback"] = 1.0 if fallback else 0.0
    if cost is not None:
        return Cost(
            latency_cycles=cost.latency_cycles,
            energy_pj=cost.energy_pj,
            utilization=cost.utilization,
            macs=cost.macs,
            frequency_hz=cost.frequency_hz,
            breakdown=breakdown,
        )
    return Cost(0.0, 0.0, 0.0, 0, 1.0, breakdown)


def _record_to_plan(space: KernelSpace, shape, rec: Cost) -> Optional[Plan]:
    bd = rec.breakdown
    try:
        n = int(bd["plan::n"])
        config = tuple(int(bd[f"plan::{i}"]) for i in range(n))
    except (KeyError, TypeError, ValueError):
        return None  # not a plan record (or truncated): treat as a miss
    fallback = bool(bd.get("plan::fallback", 0.0))
    cost = (
        Cost(
            latency_cycles=rec.latency_cycles,
            energy_pj=rec.energy_pj,
            utilization=rec.utilization,
            macs=rec.macs,
            frequency_hz=rec.frequency_hz,
        )
        if rec.frequency_hz > 1.0
        else None
    )
    return Plan(
        space=space.name,
        shape=tuple(int(s) for s in shape),
        config=config,
        cost=cost,
        source="store",
        fallback=fallback,
    )


# ---------------------------------------------------------------------- #
# prediction
# ---------------------------------------------------------------------- #
def predict_cost(
    space: KernelSpace,
    shape: Sequence[int],
    config: BlockConfig,
    model: "Union[str, CostModel, None]" = None,
    vmem_budget: Optional[int] = None,
) -> Cost:
    """The cost model's prediction for the EXACT launched BlockConfig (via
    the canonical full-problem/block-tile mapping) -- the number the
    calibration table compares measured kernel time against. A calibrated
    model returns rescaled predictions here, which is precisely how
    calibration reaches the planner."""
    cm = _resolve_model(space, model)
    problem, mapping, arch = space.canonical_mapping(
        shape, config, arch=space.arch(vmem_budget)
    )
    return cm.evaluate(problem, mapping, arch)


def _resolve_model(
    space: KernelSpace, model: "Union[str, CostModel, None]"
) -> CostModel:
    if isinstance(model, CostModel):
        return model
    from repro.core.optimizer import COST_MODEL_REGISTRY

    return COST_MODEL_REGISTRY[model or space.cost_model]()


# ---------------------------------------------------------------------- #
# the plan path
# ---------------------------------------------------------------------- #
def plan(
    space: KernelSpace,
    shape: Sequence[int],
    *,
    mapper: Optional[str] = None,
    budget: Optional[int] = None,
    metric: Optional[str] = None,
    model: "Union[str, CostModel, None]" = None,
    vmem_budget: Optional[int] = None,
    store: Optional[ResultStore] = None,
    predict: bool = True,
) -> Plan:
    """Plan a legal BlockConfig for ``space`` at ``shape``.

    Resolution order: (1) probe the plan store under the constraints-
    inclusive space key -- a hit returns without any search; (2) run one
    ``union_opt`` search with the space's mapper/model/constraints over
    ``arch(vmem_budget)`` and ``decode`` the C1 temporal tile -- expected
    search failures (:data:`PLAN_SEARCH_ERRORS`) fall back to
    ``default_config`` and count in the ``plan_fallbacks`` ledger;
    (3) ``legalize`` whatever came out; (4) with ``predict=True`` attach
    the model's cost for the legalized config; (5) store the finished
    plan. ``store`` defaults to the process-wide plan store
    (:func:`set_plan_store`); the same store also warms the search's
    mapping-cost entries. Callers own ``flush()``.
    """
    shape = tuple(int(s) for s in shape)
    mapper = mapper or space.mapper
    budget = int(budget if budget is not None else space.search_budget)
    metric = metric or space.metric
    vb = int(vmem_budget or space.vmem_budget)
    cm = _resolve_model(space, model)
    cons = space.constraints(shape)
    store = store if store is not None else _default_store

    _bump("plan_requests")
    skey = plan_space_key(space, cons, mapper, budget, metric, cm)
    sig = _plan_sig(shape, vb)
    rec = store.get(skey, sig)
    if rec is not None:
        cached = _record_to_plan(space, shape, rec)
        if cached is not None:
            _bump("plan_store_hits")
            return cached

    # cold: one real mapper search through the shared evaluation machinery
    _bump("plan_searches")
    fallback = False
    try:
        from repro.core.optimizer import union_opt

        sol = union_opt(
            space.problem(shape),
            space.arch(vb),
            mapper=mapper,
            cost_model=cm,
            metric=metric,
            constraints=cons,
            result_store=store,
            climb_steps=budget,
        )
        raw = space.decode(sol.mapping, shape)
    except PLAN_SEARCH_ERRORS as e:
        _bump("plan_fallbacks")
        log.warning(
            "codesign.plan %s%s: search failed (%s: %s); using default "
            "config", space.name, shape, type(e).__name__, e,
        )
        raw = space.default_config(shape)
        fallback = True

    config = space.legalize(raw, shape, vb)
    cost = predict_cost(space, shape, config, cm, vb) if predict else None
    store.put(skey, sig, _plan_record(config, cost, fallback))
    return Plan(
        space=space.name,
        shape=shape,
        config=config,
        cost=cost,
        source="fallback" if fallback else "search",
        fallback=fallback,
    )
