"""Measured-vs-modeled calibration: the feedback half of the co-design
loop.

The cost models predict cycles from a mapping; nothing in the original
stack ever checked those predictions against the kernels we actually
emit. This module benchmarks the emitted Pallas kernel per
(kernel, shape, BlockConfig) -- interpret mode on CPU for CI, real device
timing when available -- and records the measured wall time next to the
model's predicted cycles in a :class:`CalibrationTable`.

The table persists as ONE versioned JSON file with the same discipline as
``core/cost/store.py``: plain-data JSON (never pickle -- a table is meant
to be shared as a CI artifact, and loading it must never be a
code-execution surface), writer-unique tmp + atomic rename under an
advisory flock, stale-tmp cleanup, and corrupt/version-mismatched
payloads tolerated (counted, then overwritten on next flush) rather than
fatal.

From the table two things flow back into the stack:

  * :meth:`CalibrationTable.scale_for` distills the records into a
    :class:`CalibrationScale` -- the geometric-mean ratio of measured to
    predicted seconds -- which plugs into any
    :class:`~repro.core.cost.base.CostModel` via ``set_calibration()``.
    A calibrated model rescales every latency prediction by that factor
    and reports the calibration in ``store_key_parts()``, so calibrated
    and raw results never alias in a ``ResultStore``.
  * :meth:`CalibrationTable.model_error_report` summarizes the residual
    per-kernel x shape model error AFTER applying the scale -- the
    validation artifact ``kernels_bench`` publishes.

Interpret-mode wall time is a CPU emulation, not device time; the scale
it produces is still a perfectly valid regression target for CI (it is
deterministic enough to catch model drift), which is why the ``interpret``
flag is recorded on every row and :meth:`scale_for` never mixes interpret
and device rows.
"""

from __future__ import annotations

import json
import logging
import math
import os
import time
import uuid
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX platform
    fcntl = None

from repro.codesign.space import BlockConfig, KernelSpace

log = logging.getLogger("repro.codesign")

CALIBRATION_VERSION = 1


@dataclass(frozen=True)
class CalibrationScale:
    """A distilled calibration: multiply predicted latency by ``scale``.

    ``key_parts()`` is what a calibrated :class:`CostModel` appends to its
    ``store_key_parts()`` -- it identifies the calibration (value +
    provenance), so results computed under different calibrations can
    never alias in a ResultStore."""

    scale: float
    n_records: int = 0
    source: str = ""  # e.g. "interpret:matmul" or "device:*"

    def __post_init__(self):
        if not (self.scale > 0.0 and math.isfinite(self.scale)):
            raise ValueError(
                f"calibration scale must be a finite positive number, "
                f"got {self.scale!r}"
            )

    def key_parts(self) -> Tuple[object, ...]:
        return ("calibrated", f"{self.scale:.6e}", self.source)


def _measured_key(kernel: str, shape, config) -> str:
    return f"{kernel}|{','.join(map(str, shape))}|{','.join(map(str, config))}"


class CalibrationTable:
    """Append-mostly table of measured-vs-predicted rows.

    Each row: ``{kernel, shape, config, model, predicted_cycles,
    frequency_hz, predicted_s, measured_s, interpret, repeats, ts}``.
    Re-recording the same (kernel, shape, config, model, interpret) cell
    replaces the old row -- measurements supersede, they do not
    accumulate. ``path=None`` keeps the table purely in memory."""

    def __init__(self, path: Optional[object] = None) -> None:
        self.path = Path(path) if path is not None else None
        self.rows: List[dict] = []
        # store.py-style health counters
        self.corrupt_payloads = 0
        self.version_mismatches = 0
        self.stale_tmps = 0
        if self.path is not None and self.path.exists():
            self._load()

    # -------------------------------------------------------------- #
    def _load(self) -> None:
        try:
            payload = json.loads(self.path.read_text())
            if not isinstance(payload, dict):
                raise ValueError("payload is not an object")
            if payload.get("version") != CALIBRATION_VERSION:
                self.version_mismatches += 1
                log.warning(
                    "calibration table %s: version %r != %d; starting "
                    "empty (file will be rewritten on flush)",
                    self.path, payload.get("version"), CALIBRATION_VERSION,
                )
                return
            rows = payload.get("rows")
            if not isinstance(rows, list):
                raise ValueError("rows is not a list")
            self.rows = [r for r in rows if self._row_ok(r)]
            dropped = len(rows) - len(self.rows)
            if dropped:
                self.corrupt_payloads += dropped
        except (OSError, ValueError):
            self.corrupt_payloads += 1
            log.warning(
                "calibration table %s: corrupt payload; starting empty",
                self.path,
            )

    @staticmethod
    def _row_ok(r) -> bool:
        try:
            return (
                isinstance(r, dict)
                and isinstance(r["kernel"], str)
                and float(r["predicted_s"]) > 0.0
                and float(r["measured_s"]) > 0.0
            )
        except (KeyError, TypeError, ValueError):
            return False

    # -------------------------------------------------------------- #
    def record(
        self,
        kernel: str,
        shape: Sequence[int],
        config: BlockConfig,
        model: Sequence[object],
        predicted_cycles: float,
        frequency_hz: float,
        measured_s: float,
        *,
        interpret: bool = True,
        repeats: int = 1,
    ) -> dict:
        row = {
            "kernel": str(kernel),
            "shape": [int(s) for s in shape],
            "config": [int(c) for c in config],
            "model": [repr(p) for p in model],
            "predicted_cycles": float(predicted_cycles),
            "frequency_hz": float(frequency_hz),
            "predicted_s": float(predicted_cycles) / float(frequency_hz),
            "measured_s": float(measured_s),
            "interpret": bool(interpret),
            "repeats": int(repeats),
            "ts": time.time(),
        }
        cell = (row["kernel"], row["shape"], row["config"], row["model"],
                row["interpret"])
        self.rows = [
            r for r in self.rows
            if (r["kernel"], r["shape"], r["config"], r["model"],
                r.get("interpret", True)) != cell
        ]
        self.rows.append(row)
        return row

    def _select(
        self, kernel: Optional[str], interpret: Optional[bool]
    ) -> List[dict]:
        out = []
        for r in self.rows:
            if kernel is not None and r["kernel"] != kernel:
                continue
            if interpret is not None and bool(r.get("interpret", True)) != interpret:
                continue
            out.append(r)
        return out

    # -------------------------------------------------------------- #
    def scale_for(
        self,
        kernel: Optional[str] = None,
        *,
        interpret: bool = True,
    ) -> Optional[CalibrationScale]:
        """Geometric-mean measured/predicted seconds over the matching
        rows (``kernel=None`` pools every kernel). Geomean, not mean:
        ratios compose multiplicatively and a geomean is insensitive to
        which side of the ratio you average. Returns ``None`` when no
        usable rows exist -- callers then simply leave the model
        uncalibrated."""
        rows = self._select(kernel, interpret)
        logs = [
            math.log(r["measured_s"] / r["predicted_s"])
            for r in rows
            if r["predicted_s"] > 0.0 and r["measured_s"] > 0.0
        ]
        if not logs:
            return None
        mode = "interpret" if interpret else "device"
        return CalibrationScale(
            scale=math.exp(sum(logs) / len(logs)),
            n_records=len(logs),
            source=f"{mode}:{kernel or '*'}",
        )

    def model_error_report(
        self,
        kernel: Optional[str] = None,
        *,
        interpret: bool = True,
    ) -> List[dict]:
        """Residual model error per (kernel, shape) AFTER applying this
        table's scale: ``error_pct = 100 * (scale*predicted_s -
        measured_s) / measured_s``. The per-kernel scale is used when that
        kernel has rows, the pooled scale otherwise."""
        report = []
        kernels = sorted({r["kernel"] for r in self._select(kernel, interpret)})
        for k in kernels:
            cal = self.scale_for(k, interpret=interpret) or self.scale_for(
                None, interpret=interpret
            )
            s = cal.scale if cal else 1.0
            for r in self._select(k, interpret):
                err = 100.0 * (s * r["predicted_s"] - r["measured_s"]) / r[
                    "measured_s"
                ]
                report.append(
                    {
                        "kernel": k,
                        "shape": list(r["shape"]),
                        "config": list(r["config"]),
                        "predicted_s": r["predicted_s"],
                        "measured_s": r["measured_s"],
                        "scale": s,
                        "error_pct": err,
                        "abs_error_pct": abs(err),
                        "interpret": bool(r.get("interpret", True)),
                    }
                )
        return report

    # -------------------------------------------------------------- #
    def _lock(self):
        """Advisory flock on ``<table>.lock`` (constant file, never
        unlinked -- same rationale as the ResultStore directory lock)."""
        import contextlib

        @contextlib.contextmanager
        def cm():
            if fcntl is None or self.path is None:
                yield
                return
            with open(self.path.with_name(self.path.name + ".lock"), "w") as lf:
                fcntl.flock(lf, fcntl.LOCK_EX)
                try:
                    yield
                finally:
                    fcntl.flock(lf, fcntl.LOCK_UN)

        return cm()

    def flush(self) -> int:
        """Atomically write the table (writer-unique tmp + rename under
        the lock, stale ``.ctmp`` scratch cleaned). No-op in-memory."""
        if self.path is None:
            return 0
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = {"version": CALIBRATION_VERSION, "rows": self.rows}
        with self._lock():
            now = time.time()
            for tmp in self.path.parent.glob(f".{self.path.name}.*.ctmp"):
                try:
                    if fcntl is None and now - tmp.stat().st_mtime < 60.0:
                        continue
                    tmp.unlink()  # crashed writer's scratch
                    self.stale_tmps += 1
                except OSError:
                    pass
            tmp = self.path.with_name(
                f".{self.path.name}.{os.getpid()}.{uuid.uuid4().hex[:8]}.ctmp"
            )
            tmp.write_text(json.dumps(payload, separators=(",", ":")))
            tmp.replace(self.path)
        return len(self.rows)

    def stats_dict(self) -> dict:
        return {
            "rows": len(self.rows),
            "kernels": sorted({r["kernel"] for r in self.rows}),
            "corrupt_payloads": self.corrupt_payloads,
            "version_mismatches": self.version_mismatches,
            "stale_tmps": self.stale_tmps,
        }


# ---------------------------------------------------------------------- #
# measurement
# ---------------------------------------------------------------------- #
def measure_kernel(
    space: KernelSpace,
    shape: Sequence[int],
    config: BlockConfig,
    *,
    interpret: bool = True,
    repeats: int = 3,
    seed: int = 0,
) -> float:
    """Best-of-``repeats`` wall seconds for one kernel launch at
    ``config`` (after one untimed warmup to exclude trace/compile time).
    Best-of-N, not mean: scheduling noise only ever ADDS time, so the
    minimum is the least-noisy estimator of the kernel itself."""
    import jax

    inputs = space.example_inputs(shape, seed=seed)
    out = space.run(inputs, config, interpret=interpret)  # warmup
    jax.block_until_ready(out)
    best = math.inf
    for _ in range(max(1, int(repeats))):
        t0 = time.perf_counter()
        out = space.run(inputs, config, interpret=interpret)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate_kernel(
    space: KernelSpace,
    shapes: Sequence[Sequence[int]],
    table: Optional[CalibrationTable] = None,
    *,
    model: Optional[object] = None,
    interpret: bool = True,
    repeats: int = 3,
    **plan_kwargs,
) -> CalibrationTable:
    """Plan, predict, measure, and record each shape; returns the table.

    Each shape goes through the unified :func:`~repro.codesign.planner.
    plan` path (so calibration benchmarks exactly the BlockConfig the
    kernel would launch), the model's predicted cost for the legalized
    config is read off the plan, and the measured time lands next to it
    in the table. Caller owns ``table.flush()``."""
    from repro.codesign.planner import _resolve_model, plan

    table = table if table is not None else CalibrationTable()
    cm = _resolve_model(space, model)
    for shape in shapes:
        p = plan(space, shape, model=cm, **plan_kwargs)
        cost = p.cost
        if cost is None:
            from repro.codesign.planner import predict_cost

            cost = predict_cost(space, shape, p.config, cm)
        measured = measure_kernel(
            space, shape, p.config, interpret=interpret, repeats=repeats
        )
        table.record(
            space.name,
            shape,
            p.config,
            cm.store_key_parts(),
            cost.latency_cycles,
            cost.frequency_hz,
            measured,
            interpret=interpret,
            repeats=repeats,
        )
    return table
