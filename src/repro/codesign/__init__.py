"""Unified HW-SW co-design layer: one planner + calibration loop for all
Pallas kernels (docs/codesign.md).

Before this package, each kernel package hand-rolled its own copy of the
kernel<->mapper bridge (``plan_tiles`` / ``plan_blocks`` / ``plan_chunk``
with duplicated ``_round_up``/``_fix`` repair and divergent VMEM-budget
conventions) and no measured kernel performance ever flowed back into the
cost models. Now:

  * :class:`KernelSpace` (``space.py``) is the one abstraction a kernel
    registers: its mapping ``Problem``, ``Constraints``, a ``decode`` that
    reads the C1 temporal tile out of a Union mapping, a ``legalize``
    repair that turns any candidate into a valid BlockSpec, safe defaults,
    and the shared :data:`DEFAULT_VMEM_BUDGET` convention.
  * :func:`plan` (``planner.py``) is the single search path all kernels
    tile through: it drives the existing ``union_opt`` /
    ``EvaluationEngine`` machinery and caches finished plans in a
    :class:`~repro.core.cost.store.ResultStore` under a
    constraints-inclusive space key, so warm plan queries answer in O(ms)
    without invoking a mapper search.
  * ``calibrate.py`` closes the loop: it benchmarks the emitted kernel per
    (kernel, shape, BlockConfig), records measured time next to the
    model's predicted cycles in a versioned, corruption-tolerant
    :class:`CalibrationTable`, and produces the
    :class:`~repro.core.cost.base.CostModel` calibration hook
    (``set_calibration``) that rescales predictions and reports per-kernel
    x shape model error.
"""

from repro.codesign.space import (  # noqa: F401
    DEFAULT_VMEM_BUDGET,
    KernelSpace,
    all_spaces,
    get_space,
    register_space,
    repair_tile,
    round_up,
)
from repro.codesign.planner import (  # noqa: F401
    PLAN_SEARCH_ERRORS,
    PLANNER_VERSION,
    Plan,
    get_plan_store,
    plan,
    plan_space_key,
    planner_stats,
    predict_cost,
    reset_planner_stats,
    set_plan_store,
)
from repro.codesign.calibrate import (  # noqa: F401
    CALIBRATION_VERSION,
    CalibrationScale,
    CalibrationTable,
    calibrate_kernel,
    measure_kernel,
)
