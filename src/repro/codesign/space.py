"""KernelSpace: the per-kernel half of the co-design contract.

A kernel package defines ONE :class:`KernelSpace` subclass and registers a
singleton instance. The space owns everything the unified planner
(:func:`repro.codesign.planner.plan`) needs to turn a shape into a legal
BlockConfig:

  * ``problem(shape)``      -- the Union :class:`Problem` whose C1 temporal
                               tile IS the kernel's BlockSpec,
  * ``constraints(shape)``  -- mapper constraints (MXU alignment, ...),
  * ``arch(vmem_budget)``   -- the cluster hierarchy mapped onto
                               (``tpu_chip`` by default); legality rule R3
                               at C1 makes every legal mapping a valid
                               BlockSpec within the VMEM budget,
  * ``decode(mapping, shape)``   -- read the BlockConfig out of the C1
                               (innermost-level) temporal tile,
  * ``legalize(config, shape, vmem_budget)`` -- repair ANY candidate into
                               a launchable config (divisor tiles, MXU
                               floors, working-set rules) -- this subsumes
                               the three historical per-kernel ``_fix``
                               copies,
  * ``default_config(shape)``    -- the no-search fallback seed (always
                               run through ``legalize``),
  * ``example_inputs``/``run``   -- the calibration hooks ``calibrate.py``
                               uses to benchmark the emitted kernel.

The **VMEM budget convention** is unified here: every space defaults to
:data:`DEFAULT_VMEM_BUDGET` (8 MiB -- half of the chip's 16 MiB usable
VMEM, leaving room for double buffering), replacing the three divergent
per-kernel conventions (flash_attention's inline ``8 MiB``, ssd_scan's
``vmem_budget`` kwarg, matmul's implicit ``tpu_chip()`` default).

``BlockConfig`` is a plain ``Tuple[int, ...]`` in ``decode_dims`` order --
it is stored in plan caches and calibration tables, so it stays a
JSON-friendly value type.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from repro.core.architecture import Architecture, tpu_chip
from repro.core.constraints import Constraints
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace
from repro.core.problem import Problem

BlockConfig = Tuple[int, ...]

#: The one VMEM tile budget every kernel space plans under by default:
#: half of the chip's 16 MiB usable VMEM, so a double-buffered pipeline
#: (the Pallas default) fits two tiles. Kernel-specific overrides go
#: through ``KernelSpace.vmem_budget`` or the ``vmem_budget=`` parameter
#: of :func:`repro.codesign.planner.plan` -- never through inline
#: literals.
DEFAULT_VMEM_BUDGET = 8 * (1 << 20)


def round_up(x: int, m: int) -> int:
    """Round ``x`` up to a multiple of ``m`` (the single shared copy of
    the helper each kernel ``ops.py`` used to duplicate)."""
    return (x + m - 1) // m * m


def repair_tile(
    b: int,
    dim: int,
    default: int,
    *,
    min_tile: int = 128,
    cap: Optional[int] = None,
) -> int:
    """The shared tile-repair rule (historical ``_fix``): keep ``b`` when
    it is an MXU-worthy exact divisor (``b >= min_tile``, ``dim % b == 0``,
    optionally ``b <= cap``); otherwise fall back to the largest divisor of
    ``dim`` reachable from ``min(default, dim)`` by halving. Always returns
    a legal divisor tile >= 1, for any dim >= 1 (odd, non-pow2, < 128)."""
    if b >= min_tile and dim % b == 0 and (cap is None or b <= cap):
        return int(b)
    d = min(default, dim)
    while d > 1 and dim % d != 0:
        d //= 2
    return max(int(d), 1)


class KernelSpace:
    """Base class of the per-kernel co-design contract (see module doc).

    Subclasses set the class attributes and implement the abstract
    methods; instances are stateless singletons registered via
    :func:`register_space`."""

    #: registry key; also the kernel label in plan caches + calibration
    name: str = "kernel"
    #: problem dims whose C1 temporal tile forms the BlockConfig, in order
    decode_dims: Tuple[str, ...] = ()
    #: unified VMEM budget (see DEFAULT_VMEM_BUDGET)
    vmem_budget: int = DEFAULT_VMEM_BUDGET
    #: default planner knobs (overridable per plan() call)
    mapper: str = "heuristic"
    cost_model: str = "timeloop"
    metric: str = "latency"
    search_budget: int = 400  # heuristic climb steps

    # ------------------------------------------------------------------ #
    # mapping space
    # ------------------------------------------------------------------ #
    def problem(self, shape: Sequence[int]) -> Problem:
        raise NotImplementedError

    def constraints(self, shape: Sequence[int]) -> Constraints:
        return Constraints()

    def arch(self, vmem_budget: Optional[int] = None) -> Architecture:
        return tpu_chip(
            vmem_tile_budget=int(vmem_budget or self.vmem_budget)
        )

    # ------------------------------------------------------------------ #
    # mapping -> BlockConfig
    # ------------------------------------------------------------------ #
    def decode(self, mapping: Mapping, shape: Sequence[int]) -> BlockConfig:
        """Read the BlockConfig from the C1 (innermost) temporal tile."""
        leaf = mapping.levels[-1]
        return tuple(int(leaf.tt(d)) for d in self.decode_dims)

    def legalize(
        self,
        config: BlockConfig,
        shape: Sequence[int],
        vmem_budget: Optional[int] = None,
    ) -> BlockConfig:
        raise NotImplementedError

    def default_config(self, shape: Sequence[int]) -> BlockConfig:
        """No-search seed; the planner always legalizes it before use."""
        return tuple(0 for _ in self.decode_dims)

    # ------------------------------------------------------------------ #
    # BlockConfig -> canonical mapping (for cost prediction)
    # ------------------------------------------------------------------ #
    def block_tiles(
        self, shape: Sequence[int], config: BlockConfig
    ) -> Dict[str, int]:
        """Problem-dim -> C1 temporal tile for a given BlockConfig (dims
        omitted here stay fully resident, tile == full extent)."""
        return dict(zip(self.decode_dims, config))

    def canonical_mapping(
        self,
        shape: Sequence[int],
        config: BlockConfig,
        arch: Optional[Architecture] = None,
    ) -> Tuple[Problem, Mapping, Architecture]:
        """The mapping a BlockConfig denotes on this space's hierarchy:
        full problem at the outermost level, the block tile at every level
        below (the Pallas grid iterates full/block steps; the block is
        VMEM-resident). This is what the calibration layer evaluates to
        get the model's predicted cycles for the exact launched config."""
        problem = self.problem(shape)
        arch = arch or self.arch()
        tiles = self.block_tiles(shape, config)
        chains: Dict[str, Tuple[int, ...]] = {}
        for d, full in problem.dims.items():
            t = int(tiles.get(d, full))
            if t <= 0 or full % t != 0:
                raise ValueError(
                    f"{self.name}: block tile {t} does not divide dim "
                    f"{d}={full} (legalize first)"
                )
            chain = [int(full), int(full)]
            for _ in range(arch.n_levels - 1):
                chain += [t, t]
            chains[d] = tuple(chain)
        space = MapSpace(problem, arch, None)
        return problem, space._chain_to_mapping(chains), arch

    # ------------------------------------------------------------------ #
    # calibration hooks (optional; NotImplementedError disables
    # measurement for this space)
    # ------------------------------------------------------------------ #
    def example_inputs(self, shape: Sequence[int], seed: int = 0):
        """Representative inputs for benchmarking at ``shape``."""
        raise NotImplementedError

    def run(self, inputs, config: BlockConfig, interpret: bool = True):
        """Execute the kernel on ``inputs`` with the given BlockConfig;
        return the (unblocked) jax output(s) for ``block_until_ready``."""
        raise NotImplementedError


# ---------------------------------------------------------------------- #
# registry
# ---------------------------------------------------------------------- #
_REGISTRY: Dict[str, KernelSpace] = {}


def register_space(space: KernelSpace) -> KernelSpace:
    """Register a kernel's space singleton (idempotent by name)."""
    _REGISTRY[space.name] = space
    return space


def get_space(name: str) -> KernelSpace:
    if name not in _REGISTRY:
        all_spaces()  # trigger kernel-package registration
    return _REGISTRY[name]


def all_spaces() -> Dict[str, KernelSpace]:
    """All registered spaces, importing the in-repo kernel packages first
    (they register their spaces at import time). Lazy so that the
    codesign core stays importable without jax."""
    import importlib

    for mod in (
        "repro.kernels.matmul.ops",
        "repro.kernels.flash_attention.ops",
        "repro.kernels.ssd_scan.ops",
    ):
        try:
            importlib.import_module(mod)
        except ImportError:  # pragma: no cover - jax-free environment
            pass
    return dict(_REGISTRY)
