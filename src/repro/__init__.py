"""Union-JAX: unified HW-SW co-design ecosystem (Jeong et al., 2021) as a
production multi-pod JAX training/serving framework.

Public API highlights:
  repro.core.problem.Problem            -- unified workload abstraction
  repro.core.architecture.Architecture  -- cluster-target hardware abstraction
  repro.core.mapping.Mapping            -- cluster-target loop-centric mapping
  repro.core.mappers                    -- plug-and-play mappers
  repro.core.cost                       -- plug-and-play cost models
  repro.configs                         -- assigned architectures + paper workloads
  repro.launch                          -- mesh / dryrun / train / serve
"""

__version__ = "1.0.0"
