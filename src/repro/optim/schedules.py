"""LR schedules."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(peak_lr: float, warmup_steps: int):
    def fn(step):
        return peak_lr * jnp.minimum(1.0, step / max(1, warmup_steps))

    return fn


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * jnp.minimum(1.0, step / max(1, warmup_steps))
        t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn
