"""Sharded optimizers (pure JAX, optax-style interface).

Optimizer state mirrors the parameter pytree, so GSPMD shards it with the
same PartitionSpecs as the parameters (ZeRO-style when FSDP specs are on).
Master weights are kept in f32 when params are bf16 (mixed-precision
training); updates are computed in f32 and cast back.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw(
    lr: Callable | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, master):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mh = m_new / bc1
            vh = v_new / bc2
            p_new = master - lr_t * (mh / (jnp.sqrt(vh) + eps) + weight_decay * master)
            return m_new, v_new, p_new

        m, v, master = state["m"], state["v"], state["master"]
        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(m)
        flat_v = treedef.flatten_up_to(v)
        flat_ma = treedef.flatten_up_to(master)
        outs = [upd(g, mm, vv, ma) for g, mm, vv, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
        new_m = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
        new_master = jax.tree.unflatten(treedef, [o[2] for o in outs])
        new_params = jax.tree.map(lambda p, ma: ma.astype(p.dtype), params, new_master)
        return new_params, {"step": step, "m": new_m, "v": new_v, "master": new_master}

    return Optimizer(init, update)


def lion(
    lr: Callable | float = 1e-4,
    b1: float = 0.9,
    b2: float = 0.99,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
) -> Optimizer:
    """Lion: sign-momentum optimizer -- 1/3 the optimizer memory of Adam
    (one f32 moment instead of two + no bias correction)."""
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr_t = lr_fn(step)

        def upd(g, m, master):
            g = g.astype(jnp.float32)
            u = jnp.sign(b1 * m + (1 - b1) * g)
            p_new = master - lr_t * (u + weight_decay * master)
            m_new = b2 * m + (1 - b2) * g
            return m_new, p_new

        m, master = state["m"], state["master"]
        new_m = jax.tree.map(lambda g, mm, ma: upd(g, mm, ma)[0], grads, m, master)
        new_master = jax.tree.map(lambda g, mm, ma: upd(g, mm, ma)[1], grads, m, master)
        new_params = jax.tree.map(lambda p, ma: ma.astype(p.dtype), params, new_master)
        return new_params, {"step": step, "m": new_m, "master": new_master}

    return Optimizer(init, update)


def sgd(lr: Callable | float = 1e-2, momentum: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["m"], grads
        )
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
            params, new_m,
        )
        return new_params, {"step": step, "m": new_m}

    return Optimizer(init, update)
