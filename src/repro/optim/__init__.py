from repro.optim.optimizers import adamw, lion, sgd, Optimizer, clip_by_global_norm  # noqa: F401
from repro.optim.schedules import cosine_schedule, linear_warmup  # noqa: F401
