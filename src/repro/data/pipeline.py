"""Deterministic, resumable, sharded data pipeline.

Design constraints for the 1000+-node posture:
  * **Deterministic-resumable**: ``batch(step)`` is a pure function of
    (seed, step) -- restoring from a checkpoint at step k replays exactly
    the batches k, k+1, ... with no data-loader state to checkpoint.
  * **Sharded placement**: each batch is placed as a global
    jax.Array under the mesh's batch sharding, so per-host the pipeline
    only materializes its local shard (``jax.make_array_from_callback``).
  * **Prefetch**: a background thread keeps ``prefetch`` batches ahead so
    host-side batch assembly overlaps device compute.

Two sources: ``SyntheticLM`` (seeded Zipf-ish token stream -- used by the
examples and tests; no dataset gate on this container) and
``TokenFileDataset`` (memory-mapped flat token file, the production path).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    prefetch: int = 2


class SyntheticLM:
    """Seeded synthetic LM token stream with a learnable structure
    (repeated n-grams + Zipf marginals) so a ~100M model's loss visibly
    drops within a few hundred steps."""

    def __init__(self, vocab: int, seed: int = 0, ngram: int = 3) -> None:
        self.vocab = vocab
        self.seed = seed
        self.ngram = ngram
        # fixed random n-gram successor table: token -> deterministic next
        rng = np.random.default_rng(seed)
        self._succ = rng.integers(0, vocab, size=(vocab,), dtype=np.int32)
        self._zipf_p = 1.0 / np.arange(1, vocab + 1)
        self._zipf_p /= self._zipf_p.sum()

    def batch(self, step: int, batch: int, seq: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((batch, seq), np.int32)
        # start tokens ~ Zipf; with p=0.8 follow the successor table
        # (predictable), else resample (noise floor)
        toks[:, 0] = rng.choice(self.vocab, size=batch, p=self._zipf_p)
        follow = rng.random((batch, seq)) < 0.8
        fresh = rng.choice(self.vocab, size=(batch, seq), p=self._zipf_p)
        for t in range(1, seq):
            toks[:, t] = np.where(
                follow[:, t], self._succ[toks[:, t - 1]], fresh[:, t]
            )
        return {"tokens": toks}


class TokenFileDataset:
    """Memory-mapped flat token file (int32/int16/uint16). Batch ``step``
    reads a deterministic strided window per sample -- seekable, so resume
    is again (seed, step)-pure."""

    def __init__(self, path: str | Path, vocab: int, dtype=np.int32, seed: int = 0):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, batch: int, seq: int) -> Dict[str, np.ndarray]:
        n = len(self.tokens) - (seq + 1)
        rng = np.random.default_rng((self.seed, step))
        starts = rng.integers(0, n, size=batch)
        out = np.stack([self.tokens[s : s + seq] for s in starts]).astype(np.int32)
        return {"tokens": out % self.vocab}


def _place(batch_np: Dict[str, np.ndarray], mesh, specs) -> Dict:
    """Build global jax.Arrays for a host-local numpy batch."""
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch_np.items()}

    out = {}
    for k, arr in batch_np.items():
        sh = NamedSharding(mesh, specs[k]) if specs and k in specs else NamedSharding(mesh, P())
        out[k] = jax.make_array_from_callback(arr.shape, sh, lambda idx, a=arr: a[idx])
    return out


def make_pipeline(
    source,
    batch: int,
    seq: int,
    *,
    mesh=None,
    specs: Optional[Dict] = None,
    start_step: int = 0,
    data_cfg: DataConfig = DataConfig(),
    extra_fn=None,  # hook: batch_np -> batch_np (labels, frontends, ...)
) -> Iterator[Dict]:
    """Prefetching iterator of sharded batches, starting at start_step."""
    q: "queue.Queue" = queue.Queue(maxsize=max(1, data_cfg.prefetch))
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            b = source.batch(step, batch, seq)
            if extra_fn is not None:
                b = extra_fn(b)
            try:
                q.put((step, b), timeout=1.0)
            except queue.Full:
                continue
            step += 1

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    try:
        while True:
            step, b = q.get()
            yield _place(b, mesh, specs)
    finally:
        stop.set()
