from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    SyntheticLM,
    TokenFileDataset,
    make_pipeline,
)
