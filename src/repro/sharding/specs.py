"""PartitionSpec rules for every architecture family.

These rules ARE a Union mapping projected onto the mesh levels: the
spatial tile at the 'pod'/'data' levels is the batch split (DP), the
spatial tile at the 'model' level is the head/expert/ff split (TP/EP),
and FSDP shards the weight's remaining big dim over 'data' (ZeRO-3).
``repro/sharding/auto.py`` produces the same structures from an explicit
Union ``Mapping`` found by a mapper; this module encodes the
paper-faithful defaults used as the §Perf baseline.

Divisibility-guarded: any dim not divisible by its mesh axis size falls
back to replication (e.g. starcoder2's 4 KV heads on a 16-way model axis
-> KV cache shards over sequence instead).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class ShardingRules:
    """Knobs for the sharding strategy (hillclimbed in §Perf)."""

    fsdp: bool = True  # shard params' non-TP dim over 'data' (train)
    fsdp_min_elems: int = 65536  # replicate small tensors
    # weight-gathered serving: at inference, ALSO shard weights over 'data'
    # when the TP-sharded weights alone would exceed this budget (qwen2-moe's
    # 60 experts cannot shard over a 16-way model axis; qwen1.5-110b's
    # TP-sharded weights are 13.75 GB before any KV cache). Costs an
    # all-gather per layer -- decode is bandwidth-bound anyway.
    inference_weight_budget: int = 8 * (1 << 30)
    # Megatron-style sequence parallelism on the residual stream: the
    # per-layer remat carries shard over 'model', which is what lets the
    # 110B train cell fit (86 GB -> 5.4 GB of saved activations per chip).
    seq_shard_activations: bool = True
    shard_cache_heads: bool = True  # prefer head-sharding of KV caches
    expert_axis: str = "model"  # EP axis
    tp_axis: str = "model"
    dp_over_pod: bool = True  # batch also split over 'pod'
    # pure-FSDP (ZeRO-3) mode: the 'model' axis joins DATA parallelism and
    # TP is disabled. Trades the per-layer TP activation all-reduces for
    # per-unit parameter all-gathers -- wins when 2*act_bytes*layers >
    # 3*param_bytes (the qwen1.5-110b train_4k hillclimb, SPerf).
    fsdp_only: bool = False
    # explicit expert parallelism: route MoE layers through the shard_map
    # all-to-all dispatch (models/moe_ep.py) instead of GSPMD scatters --
    # the SPerf MoE hillclimb. Default off = paper-faithful GSPMD baseline.
    ep_shardmap: bool = False
    # remat policy for the scanned unit stack: 'full' (recompute all,
    # collectives included) or 'save_block_outputs' (keep the all-reduced
    # per-block residual contributions; bwd recompute skips collectives)
    remat_policy: str = "full"


# dense-param orientation sets (keys are the owning module names)
_COL = {
    "wq", "wk", "wv", "gate", "up", "in_z", "in_x", "in_dt", "lm_head",
    "kv_up", "kv_down", "w_i", "w_f", "wx", "ffn_up", "l1",
}
_ROW = {"wo", "down", "out_proj", "ffn_down", "l2", "frontend_proj"}
_REPL = {"router", "in_B", "in_C"}


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh: Mesh, rules: ShardingRules):
    pool = ("pod", "data", "model") if rules.fsdp_only else ("pod", "data")
    axes = [a for a in pool if a in mesh.axis_names]
    if not rules.dp_over_pod:
        axes = [a for a in axes if a != "pod"]
    return tuple(axes)


def _maybe(axis: Optional[str], dim: int, sizes: Dict[str, int]) -> Optional[str]:
    if axis is None or axis not in sizes:
        return None
    return axis if dim % sizes[axis] == 0 else None


def _maybe_dp(axes: Tuple[str, ...], dim: int, sizes: Dict[str, int]):
    if not axes:
        return None
    n = int(np.prod([sizes[a] for a in axes]))
    return axes if dim % n == 0 else None


def _maybe_any(ax, dim: int, sizes: Dict[str, int]):
    """_maybe for either a single axis name or a tuple of axes."""
    if ax is None:
        return None
    if isinstance(ax, tuple):
        return _maybe_dp(ax, dim, sizes)
    return _maybe(ax, dim, sizes)


# --------------------------------------------------------------------- #
# parameter specs
# --------------------------------------------------------------------- #
def param_specs(
    params_shape,  # pytree of ShapeDtypeStruct (or arrays)
    cfg: ModelConfig,
    mesh: Mesh,
    rules: ShardingRules = ShardingRules(),
    for_training: bool = True,
) -> Dict:
    sizes = _axis_sizes(mesh)
    tp = None if rules.fsdp_only else rules.tp_axis
    fsdp_ax = "data" if (rules.fsdp and for_training and "data" in sizes) else None
    if rules.fsdp_only:
        fsdp_ax = tuple(a for a in ("data", "model") if a in sizes) or None
    if not for_training and "data" in sizes:
        # weight-gathered serving for models whose TP-sharded weights
        # exceed the per-chip budget (see ShardingRules). Expert banks
        # whose expert count does not divide the model axis (qwen2-moe's
        # 60 on a 16-way axis) stay REPLICATED under pure TP -- account
        # for that when estimating per-chip weight residency.
        tp_n = max(1, sizes.get(tp, 1))
        e = cfg.n_routed_experts
        expert_p = (
            (cfg.n_layers - cfg.first_k_dense) * e * 3 * cfg.d_model * cfg.d_expert
            if e else 0
        )
        dense_p = cfg.num_params() - expert_p
        eff = dense_p / tp_n + expert_p / (tp_n if (e and e % tp_n == 0) else 1)
        if 2 * eff > rules.inference_weight_budget:
            fsdp_ax = "data"

    def leaf_spec(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        shape = leaf.shape
        stacked = keys and keys[0] == "units"  # leading unit axis from scan
        off = 1 if stacked else 0
        body = shape[off:]
        name = keys[-1]
        owner = keys[-2] if name in ("w", "b") and len(keys) >= 2 else name

        def wrap(*spec_body):
            return P(*([None] * off), *spec_body)

        big = leaf.size >= rules.fsdp_min_elems

        # ---- embeddings & head ---------------------------------------- #
        if name == "embed":
            return wrap(_maybe(tp, body[0], sizes), _maybe_any(fsdp_ax, body[1], sizes) if big else None)
        # ---- norm scales / small vectors ------------------------------- #
        if len(body) == 1:
            if owner in _COL and name == "b":
                return wrap(_maybe(tp, body[0], sizes))
            if name in ("A_log", "D", "dt_bias", "conv_x_b"):
                return wrap(_maybe(tp, body[0], sizes))
            return wrap(None)
        # ---- MoE expert banks (E, d, de) / (E, de, d) ------------------- #
        if owner in ("w_gate", "w_up", "w_down") or name in ("w_gate", "w_up", "w_down"):
            e_ax = (None if rules.fsdp_only
                    else _maybe(rules.expert_axis, body[0], sizes))
            d_ax = _maybe_any(fsdp_ax, body[1], sizes) if big else None
            return wrap(e_ax, d_ax, None)
        # ---- depthwise convs (W, C) ------------------------------------ #
        if name.startswith("conv_") and name.endswith("_w"):
            ch_ax = _maybe(tp, body[1], sizes) if name == "conv_x_w" else None
            return wrap(None, ch_ax)
        if name == "conv_w":
            return wrap(None, _maybe(tp, body[1], sizes))
        # ---- sLSTM recurrent (4, nh, hd, hd) ---------------------------- #
        if name == "r":
            return wrap(None, None, _maybe(tp, body[2], sizes), None)
        # ---- dense weights ---------------------------------------------- #
        if owner in _COL:
            col = _maybe(tp, body[-1], sizes)
            row = _maybe_any(fsdp_ax, body[0], sizes) if (big and col != fsdp_ax) else None
            return wrap(row, *([None] * (len(body) - 2)), col)
        if owner in _ROW:
            row = _maybe(tp, body[0], sizes)
            col = _maybe_any(fsdp_ax, body[-1], sizes) if (big and row != fsdp_ax) else None
            return wrap(row, *([None] * (len(body) - 2)), col)
        if owner in _REPL:
            return wrap(*([None] * len(body)))
        # default: replicate
        return wrap(*([None] * len(body)))

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


# --------------------------------------------------------------------- #
# batch / cache / state specs
# --------------------------------------------------------------------- #
def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                rules: ShardingRules = ShardingRules()) -> Dict:
    dp = dp_axes(mesh, rules)
    seq_ax = (rules.tp_axis if rules.seq_shard_activations else None)
    if rules.fsdp_only:
        seq_ax = None  # 'model' already consumed by the batch axis
    sizes = _axis_sizes(mesh)
    # divisibility guard: when the global batch cannot split over the full
    # dp pool (fsdp_only prefill: batch 32 on 256 chips), keep batch on
    # (pod, data) and move 'model' back to the sequence axis
    if _maybe_dp(dp, shape.global_batch, sizes) is None:
        narrower = tuple(a for a in dp if a != rules.tp_axis)
        if rules.fsdp_only and _maybe_dp(narrower, shape.global_batch, sizes):
            dp, seq_ax = narrower, rules.tp_axis
        else:
            dp = None

    def tok_spec(ndim: int) -> P:
        extra = [None] * (ndim - 2)
        return P(dp if dp else None, seq_ax, *extra)

    specs: Dict = {}
    if cfg.frontend == "audio_stub":
        specs["frames"] = tok_spec(3)
        specs["labels"] = tok_spec(2)
    else:
        specs["tokens"] = tok_spec(2)
        if cfg.frontend == "vision_stub" and shape.kind in ("train", "prefill"):
            specs["patch_embeds"] = tok_spec(3)
    return specs


def cache_specs(cache_shape, cfg: ModelConfig, mesh: Mesh,
                rules: ShardingRules = ShardingRules()) -> Dict:
    sizes = _axis_sizes(mesh)
    tp = None if rules.fsdp_only else rules.tp_axis
    dp = dp_axes(mesh, rules)

    def leaf_spec(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        shape = leaf.shape
        stacked = keys and keys[0] == "units"
        off = 1 if stacked else 0
        body = shape[off:]
        name = keys[-1]

        def wrap(*spec_body):
            return P(*([None] * off), *spec_body)

        bdp = _maybe_dp(dp, body[0], sizes)
        # batch-1 long-context decode: the batch axis cannot shard, so the
        # cache SEQUENCE axis takes the dp axes instead (sequence parallelism
        # over the ring) -- this is what keeps the 500k cells per-chip small
        seq_dp = None if bdp else _maybe_dp(dp, body[1] if len(body) > 1 else 0, sizes)
        if name in ("k", "v"):
            # (b, S, hkv, hd): heads over model if divisible, else sequence
            if rules.shard_cache_heads and body[2] % sizes.get(tp, 1) == 0:
                return wrap(bdp, seq_dp, tp, None)
            return wrap(bdp, seq_dp or _maybe(tp, body[1], sizes), None, None)
        if name in ("ckv", "krope"):
            return wrap(bdp, seq_dp or _maybe(tp, body[1], sizes), None)
        if name in ("conv", "conv_x", "conv_B", "conv_C"):
            return wrap(bdp, None, _maybe(tp, body[2], sizes))
        if name == "state":  # (b, nh, hp, n)
            return wrap(bdp, _maybe(tp, body[1], sizes), None, None)
        if name == "C":  # (b, nh, dk, dv)
            if body[1] % sizes.get(tp, 1) == 0:
                return wrap(bdp, tp, None, None)
            return wrap(bdp, None, _maybe(tp, body[2], sizes), None)
        if name in ("n", "c", "h"):  # (b, nh, dk)
            if body[1] % sizes.get(tp, 1) == 0:
                return wrap(bdp, tp, None)
            return wrap(bdp, None, _maybe(tp, body[2], sizes))
        if name == "m":  # (b, nh)
            return wrap(bdp, _maybe(tp, body[1], sizes))
        return wrap(bdp, *([None] * (len(body) - 1)))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


def state_specs(state_shape, cfg: ModelConfig, mesh: Mesh,
                rules: ShardingRules = ShardingRules()) -> Dict:
    """Train-state specs: optimizer moments/master mirror the param specs."""
    pspecs = param_specs(state_shape["params"], cfg, mesh, rules, for_training=True)
    out = {"params": pspecs, "opt": {}}
    for k, sub in state_shape["opt"].items():
        if k == "step":
            out["opt"][k] = P()
        else:
            out["opt"][k] = param_specs(sub, cfg, mesh, rules, for_training=True)
    return out


def named(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
