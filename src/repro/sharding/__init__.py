from repro.sharding.specs import (  # noqa: F401
    ShardingRules,
    param_specs,
    batch_specs,
    cache_specs,
    state_specs,
)
