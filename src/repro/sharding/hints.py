"""Activation-sharding hints (with_sharding_constraint) for model code.

Model code is mesh-agnostic; the launcher installs a hint context
(dp axes / tp axis / sp axis + mesh axis sizes) and the model calls
``shard_hint(x, "dp", None, "tp")`` at the few places where GSPMD's
propagation would otherwise replicate something large (logits, MoE
dispatch buffers, long activations). Outside a mesh context (CPU smoke
tests) hints are no-ops. Divisibility-guarded per dim.
"""

from __future__ import annotations

import contextlib
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"enabled": False, "dp": None, "tp": None, "sp": None, "sizes": {}}


def set_hints(dp=None, tp=None, sp=None, sizes: Optional[Dict[str, int]] = None) -> None:
    _STATE.update(enabled=True, dp=dp, tp=tp, sp=sp, sizes=dict(sizes or {}))


def clear_hints() -> None:
    _STATE.update(enabled=False, dp=None, tp=None, sp=None, sizes={})


@contextlib.contextmanager
def hints(dp=None, tp=None, sp=None, sizes: Optional[Dict[str, int]] = None):
    old = dict(_STATE)
    set_hints(dp, tp, sp, sizes)
    try:
        yield
    finally:
        _STATE.clear()
        _STATE.update(old)


def hints_from_mesh(mesh, rules=None) -> None:
    """Install hints matching a mesh + ShardingRules."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fsdp_only = rules is not None and getattr(rules, "fsdp_only", False)
    pool = ("pod", "data", "model") if fsdp_only else ("pod", "data")
    dp = tuple(a for a in pool if a in sizes)
    tp = "model" if ("model" in sizes and not fsdp_only) else None
    sp = tp if (rules is not None and getattr(rules, "seq_shard_activations", False)) else None
    _STATE["mesh"] = mesh
    _STATE["ep_shardmap"] = bool(rules is not None and getattr(rules, "ep_shardmap", False))
    set_hints(dp=dp if dp else None, tp=tp, sp=sp, sizes=sizes)


def _resolve(token):
    if token is None:
        return None
    if isinstance(token, str) and token in ("dp", "tp", "sp"):
        return _STATE[token]
    return token  # literal axis name or tuple


def shard_hint(x, *pattern):
    """pattern entries: 'dp' | 'tp' | 'sp' | None | literal axis name."""
    if not _STATE["enabled"]:
        return x
    sizes = _STATE["sizes"]
    spec_entries = []
    used: set = set()
    for dim, token in zip(x.shape, pattern):
        ax = _resolve(token)
        if ax is None:
            spec_entries.append(None)
            continue
        axes = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,)) if a not in used)
        if not axes:
            spec_entries.append(None)
            continue
        n = math.prod(sizes.get(a, 1) for a in axes)
        if n > 0 and dim % n == 0:
            used.update(axes)
            spec_entries.append(axes if len(axes) > 1 else axes[0])
        else:
            spec_entries.append(None)
    spec_entries += [None] * (x.ndim - len(spec_entries))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec_entries))
    except Exception:
        return x
