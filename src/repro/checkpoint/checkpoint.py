"""Sharded, atomic, elastic checkpointing.

Layout of one checkpoint:

    <dir>/step_000000123.tmp-<nonce>/   (write)
        manifest.json                   {step, leaf index, shapes, dtypes}
        000000.npy ... NNNNNN.npy       one file per pytree leaf
    <dir>/step_000000123/               (atomic rename when complete)

Properties needed at 1000+-node scale:
  * **Atomicity**: writers fill a tmp dir and ``os.rename`` it into place;
    a crash mid-save never corrupts the latest checkpoint. Restore only
    looks at completed dirs.
  * **Elasticity**: leaves are saved UNSHARDED (gathered) with their tree
    path as the key; ``restore(..., shardings=...)`` re-places them under
    ANY new mesh/sharding -- restart on 2 pods what was saved on 1. (The
    multi-host generalization shards files per process; single-process
    here, noted in DESIGN.md.)
  * **Async save**: ``CheckpointManager(async_save=True)`` snapshots to
    host memory synchronously (cheap) and writes in a background thread,
    overlapping the next training steps.
  * **GC**: keep the most recent ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"

# numpy cannot natively save/load ml_dtypes extension types; store them as
# same-width unsigned ints and record the logical dtype in the manifest
_EXT_DTYPES = {"bfloat16": (np.uint16, jnp.bfloat16)}


def _to_native(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[name][0]), name
    return arr, name


def _from_native(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[dtype_name][1])
    return arr


def _leaf_paths(tree) -> List[str]:
    paths_and_leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in paths_and_leaves]


def save(directory: str | Path, step: int, tree, *, extra: Optional[Dict] = None) -> Path:
    """Write one complete checkpoint; returns the final path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:09d}"
    tmp = directory / f"step_{step:09d}.tmp-{uuid.uuid4().hex[:8]}"
    tmp.mkdir(parents=True)
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    index = []
    for i, (path, leaf) in enumerate(paths_and_leaves):
        arr = np.asarray(jax.device_get(leaf))
        native, dtype_name = _to_native(arr)
        fname = f"{i:06d}.npy"
        np.save(tmp / fname, native)
        index.append(
            {
                "key": jax.tree_util.keystr(path),
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
            }
        )
    manifest = {
        "step": int(step),
        "leaves": index,
        "time": time.time(),
        "extra": extra or {},
    }
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic completion
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and ".tmp-" not in p.name:
            if (p / _MANIFEST).exists():
                steps.append(int(p.name[len("step_"):]))
    return max(steps) if steps else None


def restore(
    directory: str | Path,
    target_tree,
    *,
    step: Optional[int] = None,
    shardings=None,
):
    """Restore into the structure of ``target_tree`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: matching pytree of NamedShardings
    for elastic re-placement on the current mesh; None = default placement.
    Returns (tree, step, extra)."""
    directory = Path(directory)
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    cdir = directory / f"step_{step:09d}"
    manifest = json.loads((cdir / _MANIFEST).read_text())
    by_key = {e["key"]: e for e in manifest["leaves"]}

    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if shardings is not None
        else [None] * len(paths_and_leaves)
    )
    out_leaves = []
    for (path, ref), sh in zip(paths_and_leaves, shard_leaves):
        key = jax.tree_util.keystr(path)
        if key not in by_key:
            raise KeyError(f"checkpoint {cdir} missing leaf {key}")
        entry = by_key[key]
        arr = _from_native(np.load(cdir / entry["file"]), entry["dtype"])
        want_shape = tuple(ref.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != target {want_shape}")
        arr = arr.astype(ref.dtype)
        if sh is not None:
            out_leaves.append(
                jax.make_array_from_callback(arr.shape, sh, lambda idx, a=arr: a[idx])
            )
        else:
            out_leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out_leaves), step, manifest.get("extra", {})


class CheckpointManager:
    """Save policy + async writes + GC."""

    def __init__(
        self,
        directory: str | Path,
        *,
        keep: int = 3,
        every: int = 100,
        async_save: bool = True,
    ) -> None:
        self.directory = Path(directory)
        self.keep = keep
        self.every = every
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---------------------------------------------------------------- #
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every == 0

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, *, extra: Optional[Dict] = None) -> None:
        self.wait()  # one outstanding async save at a time
        # snapshot to host now so later training steps can't mutate donated
        # buffers under the writer
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()/save()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def restore_latest(self, target_tree, *, shardings=None):
        return restore(self.directory, target_tree, shardings=shardings)

    def _gc(self) -> None:
        if not self.directory.exists():
            return
        steps = sorted(
            p for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_") and ".tmp-" not in p.name
        )
        for p in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(p, ignore_errors=True)
        # orphaned tmp dirs from crashed writers
        for p in self.directory.iterdir():
            if ".tmp-" in p.name and time.time() - p.stat().st_mtime > 3600:
                shutil.rmtree(p, ignore_errors=True)
