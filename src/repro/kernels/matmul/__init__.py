from repro.kernels.matmul.ops import (  # noqa: F401
    matmul,
    plan_tiles,
    tiles_from_mapping,
)
