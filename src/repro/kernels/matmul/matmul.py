"""Union-tiled MXU matmul kernel.

Grid = (M/bm, N/bn, K/bk) with K innermost (the revolving accumulator
dimension). Per grid step the kernel multiplies a (bm, bk) x (bk, bn)
VMEM-resident pair on the MXU, accumulating into an f32 VMEM scratch that
is flushed to the output block on the last K step.

In Union terms (DESIGN.md Sec. 2): the C2 "GridStep" level's temporal
trips are the grid; the C1 "VMEM" level's temporal tile (bm, bn, bk) is
the BlockSpec; legality rule R3 (footprint <= VMEM) is what makes the
mapping compilable. ``ops.plan_tiles`` produces (bm, bn, bk) by running
Union-opt on the GEMM Problem over the ``tpu_chip()`` hierarchy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    x: jnp.ndarray,  # (M, K)
    y: jnp.ndarray,  # (K, N)
    *,
    bm: int,
    bn: int,
    bk: int,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    M, K = x.shape
    K2, N = y.shape
    assert K == K2, f"inner dims mismatch: {K} vs {K2}"
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"shape ({M},{N},{K}) not divisible by tiles ({bm},{bn},{bk}); "
        "pad in ops.matmul"
    )
    out_dtype = out_dtype or x.dtype
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        name="union_matmul",
    )(x, y)
