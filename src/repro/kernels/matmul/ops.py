"""Public matmul op: padding + Union tile planning + custom vjp.

``plan_tiles(M, N, K)`` runs Union-opt (heuristic mapper x Timeloop-like
cost model, MXU-aligned constraints) on the GEMM Problem over the
``tpu_chip()`` hierarchy and reads the C1/VMEM-level temporal tile as the
BlockSpec -- the paper's mapping IS the program (DESIGN.md Sec. 2).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import kernels as _cfg
from repro.core.architecture import tpu_chip
from repro.core.constraints import mxu_aligned
from repro.core.mapping import Mapping
from repro.core.optimizer import union_opt
from repro.core.problem import Problem
from repro.kernels.matmul.matmul import matmul_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def tiles_from_mapping(mapping: Mapping, problem: Problem) -> Tuple[int, int, int]:
    """Read (bm, bn, bk) from the innermost (VMEM) level temporal tile."""
    leaf = mapping.levels[-1]
    return leaf.tt("m"), leaf.tt("n"), leaf.tt("k")


@functools.lru_cache(maxsize=512)
def plan_tiles(
    M: int, N: int, K: int, *, mapper: str = "heuristic", budget: int = 400
) -> Tuple[int, int, int]:
    """Union-opt the GEMM (M,N,K) onto one TPU chip; return (bm, bn, bk)."""
    problem = Problem.gemm(M, N, K)
    arch = tpu_chip()
    cons = mxu_aligned(["m", "n", "k"], 128)
    try:
        sol = union_opt(
            problem, arch, mapper=mapper, cost_model="timeloop",
            metric="latency", constraints=cons, climb_steps=budget,
        )
        bm, bn, bk = tiles_from_mapping(sol.mapping, problem)
    except Exception:
        bm = bn = bk = 0
    # fall back to safe MXU-aligned defaults if the mapper degenerated
    # (e.g. trivial mapping with tile 1): clamp into [128, dim]
    def _fix(b: int, dim: int, default: int) -> int:
        if b >= 128 and dim % b == 0:
            return b
        d = min(default, dim)
        while dim % d != 0:
            d //= 2
        return max(d, 1)

    bm = _fix(bm, M, 256)
    bn = _fix(bn, N, 256)
    bk = _fix(bk, K, 512)
    return bm, bn, bk


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _matmul(x, y, tiles, out_dtype, interpret):
    bm, bn, bk = tiles
    return matmul_pallas(
        x, y, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=interpret
    )


def _matmul_fwd(x, y, tiles, out_dtype, interpret):
    return _matmul(x, y, tiles, out_dtype, interpret), (x, y)


def _matmul_bwd(tiles, out_dtype, interpret, res, g):
    x, y = res
    g = g.astype(x.dtype)
    # dX = g @ Y^T ; dY = X^T @ g -- re-plan tiles for the transposed shapes
    M, K = x.shape
    _, N = y.shape
    tx = plan_tiles(M, K, N)
    ty = plan_tiles(K, N, M)
    dx = _matmul(g, y.T, tx, x.dtype, interpret)
    dy = _matmul(x.T, g, ty, y.dtype, interpret)
    return dx, dy


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    tiles: Optional[Tuple[int, int, int]] = None,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Union-tiled matmul for arbitrary (even non-128-aligned) shapes.

    Leading batch dims of ``x`` are flattened into M. Pads M/N/K up to
    the tile grid and slices the result back.
    """
    interpret = _cfg.interpret_default() if interpret is None else interpret
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    M = 1
    for s in lead:
        M *= s
    K = x.shape[-1]
    K2, N = y.shape
    assert K == K2, f"matmul inner dim mismatch {K} vs {K2}"
    x2 = x.reshape(M, K)
    tiles = tiles or plan_tiles(_round_up(M, 128), _round_up(N, 128), _round_up(K, 128))
    bm, bn, bk = tiles
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    if (Mp, Kp) != (M, K):
        x2 = jnp.pad(x2, ((0, Mp - M), (0, Kp - K)))
    yp = jnp.pad(y, ((0, Kp - K), (0, Np - N))) if (Kp, Np) != (K, N) else y
    out = _matmul(x2, yp, (bm, bn, bk), out_dtype, interpret)
    return out[:M, :N].reshape(*lead, N)
