"""Public matmul op: padding + unified co-design planning + custom vjp.

Tile planning goes through the shared co-design layer (docs/codesign.md):
:class:`MatmulSpace` registers the GEMM ``Problem``, MXU-aligned
``Constraints``, and the ``legalize`` repair with
``repro.codesign``, and ``plan_tiles`` is a thin wrapper over the single
``codesign.plan`` path (heuristic mapper x Timeloop-like cost model over
the ``tpu_chip()`` hierarchy, C1/VMEM temporal tile read back as the
BlockSpec -- the paper's mapping IS the program, DESIGN.md Sec. 2).
Finished plans are cached in the planner's ResultStore, so warm queries
skip the mapper search entirely.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import codesign
from repro import kernels as _cfg
from repro.codesign import KernelSpace, repair_tile, round_up
from repro.core.constraints import mxu_aligned
from repro.core.mapping import Mapping
from repro.core.problem import Problem
from repro.kernels.matmul.matmul import matmul_pallas


def tiles_from_mapping(mapping: Mapping, problem: Problem) -> Tuple[int, int, int]:
    """Read (bm, bn, bk) from the innermost (VMEM) level temporal tile."""
    leaf = mapping.levels[-1]
    return leaf.tt("m"), leaf.tt("n"), leaf.tt("k")


class MatmulSpace(KernelSpace):
    """Co-design space of the tiled GEMM kernel: shape = (M, N, K),
    BlockConfig = (bm, bn, bk)."""

    name = "matmul"
    decode_dims = ("m", "n", "k")
    search_budget = 400

    def problem(self, shape):
        M, N, K = shape
        return Problem.gemm(M, N, K)

    def constraints(self, shape):
        return mxu_aligned(["m", "n", "k"], 128)

    def legalize(self, config, shape, vmem_budget=None):
        bm, bn, bk = config
        M, N, K = shape
        # safe MXU-aligned defaults if the mapper degenerated (e.g.
        # trivial mapping with tile 1): clamp into [128, dim]
        return (
            repair_tile(bm, M, 256),
            repair_tile(bn, N, 256),
            repair_tile(bk, K, 512),
        )

    def example_inputs(self, shape, seed: int = 0):
        M, N, K = shape
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        return (
            jax.random.normal(kx, (M, K), jnp.float32),
            jax.random.normal(ky, (K, N), jnp.float32),
        )

    def run(self, inputs, config, interpret: bool = True):
        x, y = inputs
        return matmul(x, y, tiles=tuple(config), interpret=interpret)


MATMUL_SPACE = codesign.register_space(MatmulSpace())


@functools.lru_cache(maxsize=512)
def plan_tiles(
    M: int, N: int, K: int, *, mapper: str = "heuristic", budget: int = 400
) -> Tuple[int, int, int]:
    """Plan the GEMM (M,N,K) via ``codesign.plan``; return (bm, bn, bk)."""
    return codesign.plan(
        MATMUL_SPACE, (M, N, K), mapper=mapper, budget=budget
    ).config


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _matmul(x, y, tiles, out_dtype, interpret):
    bm, bn, bk = tiles
    return matmul_pallas(
        x, y, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=interpret
    )


def _matmul_fwd(x, y, tiles, out_dtype, interpret):
    return _matmul(x, y, tiles, out_dtype, interpret), (x, y)


def _matmul_bwd(tiles, out_dtype, interpret, res, g):
    x, y = res
    g = g.astype(x.dtype)
    # dX = g @ Y^T ; dY = X^T @ g -- re-plan tiles for the transposed shapes
    M, K = x.shape
    _, N = y.shape
    tx = plan_tiles(M, K, N)
    ty = plan_tiles(K, N, M)
    dx = _matmul(g, y.T, tx, x.dtype, interpret)
    dy = _matmul(x.T, g, ty, y.dtype, interpret)
    return dx, dy


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    tiles: Optional[Tuple[int, int, int]] = None,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Union-tiled matmul for arbitrary (even non-128-aligned) shapes.

    Leading batch dims of ``x`` are flattened into M. Pads M/N/K up to
    the tile grid and slices the result back.
    """
    interpret = _cfg.interpret_default() if interpret is None else interpret
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    M = 1
    for s in lead:
        M *= s
    K = x.shape[-1]
    K2, N = y.shape
    assert K == K2, f"matmul inner dim mismatch {K} vs {K2}"
    x2 = x.reshape(M, K)
    tiles = tiles or plan_tiles(round_up(M, 128), round_up(N, 128), round_up(K, 128))
    bm, bn, bk = tiles
    Mp, Np, Kp = round_up(M, bm), round_up(N, bn), round_up(K, bk)
    if (Mp, Kp) != (M, K):
        x2 = jnp.pad(x2, ((0, Mp - M), (0, Kp - K)))
    yp = jnp.pad(y, ((0, Kp - K), (0, Np - N))) if (Kp, Np) != (K, N) else y
    out = _matmul(x2, yp, (bm, bn, bk), out_dtype, interpret)
    return out[:M, :N].reshape(*lead, N)
