"""Flash attention (causal / bidirectional, GQA-native) Pallas TPU kernel.

Layout: q (B, Hq, Sq, D), k/v (B, Hkv, Skv, D). Grid = (B, Hq, Sq/bq,
Skv/bk) with the KV axis innermost; online-softmax running max / sum /
accumulator live in VMEM scratch across the KV sweep.

GQA is native: the K/V BlockSpec index_map folds the q-head onto its KV
group (``h // group``), so KV heads are never materialized ``Hq/Hkv``
times in HBM (the jnp reference path must ``jnp.repeat``; see
models/layers.py).

Causality is handled two ways, in Union mapping terms both at the C2
grid level: fully-masked KV blocks are skipped via ``pl.when`` (no MXU
work), and only diagonal blocks pay the element mask. A (1,1) SMEM
``kv_len`` input masks the valid cache prefix for decode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    kvlen_ref,  # (1, 2) SMEM: [valid KV prefix, q position offset]
    q_ref,  # (1, 1, bq, d)
    k_ref,  # (1, 1, bk, d)
    v_ref,  # (1, 1, bk, dv)
    o_ref,  # (1, 1, bq, dv)
    m_ref,  # (bq, 128) f32 scratch -- running max (broadcast over lanes)
    l_ref,  # (bq, 128) f32 scratch -- running denominator
    acc_ref,  # (bq, dv) f32 scratch
    *,
    scale: float,
    causal: bool,
    bq: int,
    bk: int,
    n_kv: int,
):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kvlen_ref[0, 0]
    q_offset = kvlen_ref[0, 1]
    q_pos0 = q_offset + i * bq  # global position of this q block's first row

    # Skip KV blocks that are entirely masked: block start beyond both the
    # causal frontier and the valid cache prefix.
    causal_live = (q_pos0 + bq - 1 >= j * bk) if causal else True
    live = jnp.logical_and(causal_live, j * bk < kv_len)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0]  # (bq, d)
        k = k_ref[0, 0]  # (bk, d)
        v = v_ref[0, 0]  # (bk, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        qpos = q_pos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]  # (bq,)
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=1)
        m_next = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_next)  # rescale factor for old state
        p = jnp.exp(s - m_next[:, None])  # (bq, bk)
        l_next = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_next[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_next[:, None], l_ref.shape)

    @pl.when(j == n_kv - 1)
    def _flush():
        l = l_ref[:, 0]
        # fully-masked rows (decode padding) produce l == 0 -> emit zeros
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Skv, D)
    v: jnp.ndarray,  # (B, Hkv, Skv, Dv)
    *,
    causal: bool,
    scale: float,
    q_offset=0,  # int or traced scalar: global position of q[0]
    kv_len: Optional[jnp.ndarray] = None,  # scalar int32; None => Skv
    bq: int = 512,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, Dv = v.shape
    assert Hq % Hkv == 0, f"GQA heads {Hq} % {Hkv} != 0"
    group = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (
        f"seq ({Sq},{Skv}) not divisible by blocks ({bq},{bk}); pad in ops"
    )
    grid = (B, Hq, Sq // bq, Skv // bk)
    kvl = jnp.stack(
        [
            jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32),
            jnp.asarray(q_offset, jnp.int32),
        ]
    ).reshape(1, 2)
    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        bq=bq,
        bk=bk,
        n_kv=grid[3],
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
        name="union_flash_attention",
    )(kvl, q, k, v)
