"""Public flash-attention op: (b,s,h,d) layout adapter, padding, decode path.

Block sizes come from the shared co-design layer (docs/codesign.md):
:class:`FlashAttentionSpace` registers the per-head attention score
Problem (einsum ``qd,kd->qk``) with ``repro.codesign``, and
``plan_blocks`` is a thin wrapper over the single ``codesign.plan`` path.
The C1 temporal tile (bq, bk) must satisfy rule R3 with the f32 score
block + q/k/v/acc blocks resident -- same legality machinery (and now the
same planner, plan cache, and VMEM-budget convention) as the matmul
kernel.

Gradients: forward runs the Pallas kernel; backward recomputes through the
jnp oracle (ref.py) under ``jax.vjp`` -- numerically identical math. A
fused backward kernel is a further TPU optimization left on the table and
recorded in EXPERIMENTS.md SPerf.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import codesign
from repro import kernels as _cfg
from repro.codesign import KernelSpace, repair_tile, round_up
from repro.core.constraints import mxu_aligned
from repro.core.problem import Problem
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


class FlashAttentionSpace(KernelSpace):
    """Co-design space of the flash-attention kernel: shape =
    (Sq, Skv, D) per head, BlockConfig = (bq, bk)."""

    name = "flash_attention"
    decode_dims = ("q", "k")
    search_budget = 200

    def problem(self, shape):
        Sq, Skv, D = shape
        return Problem.from_einsum(
            "attn_scores", "qd,kd->qk", {"q": Sq, "k": Skv, "d": D}, "GEMM"
        )

    def constraints(self, shape):
        return mxu_aligned(["q", "k"], 128)

    def legalize(self, config, shape, vmem_budget=None):
        bq, bk = config
        Sq, Skv, _D = shape
        # blocks above 1024 blow the f32 score block past rule R3 even
        # when the mapper's coarser model admits them: cap, then repair
        # into divisor tiles
        return (
            repair_tile(bq, Sq, 512, cap=1024),
            repair_tile(bk, Skv, 512, cap=1024),
        )

    def example_inputs(self, shape, seed: int = 0):
        Sq, Skv, D = shape
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
        return (
            jax.random.normal(kq, (1, Sq, 1, D), jnp.float32),
            jax.random.normal(kk, (1, Skv, 1, D), jnp.float32),
            jax.random.normal(kv, (1, Skv, 1, D), jnp.float32),
        )

    def run(self, inputs, config, interpret: bool = True):
        q, k, v = inputs
        return flash_attention(
            q, k, v, causal=False, blocks=tuple(config), interpret=interpret
        )


FLASH_ATTENTION_SPACE = codesign.register_space(FlashAttentionSpace())


@functools.lru_cache(maxsize=256)
def plan_blocks(Sq: int, Skv: int, D: int) -> Tuple[int, int]:
    """Plan the per-head score GEMM (Sq x Skv x D) via ``codesign.plan``;
    return (bq, bk)."""
    return codesign.plan(FLASH_ATTENTION_SPACE, (Sq, Skv, D)).config


# ------------------------------------------------------------------ #
# custom-vjp core over the padded (B, H, S, D) layout.  ``meta`` is a
# float32 (2,) array [kv_len, q_offset] so traced decode positions stay
# differentiable-dtype (zero cotangent) without being static.
# ------------------------------------------------------------------ #
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _fa(q, k, v, meta, causal, scale, blocks, interpret):
    bq, bk = blocks
    return flash_attention_pallas(
        q, k, v,
        causal=causal, scale=scale,
        q_offset=meta[1].astype(jnp.int32), kv_len=meta[0].astype(jnp.int32),
        bq=bq, bk=bk, interpret=interpret,
    )


def _fa_fwd(q, k, v, meta, causal, scale, blocks, interpret):
    return _fa(q, k, v, meta, causal, scale, blocks, interpret), (q, k, v, meta)


def _fa_bwd(causal, scale, blocks, interpret, res, g):
    q, k, v, meta = res
    kvl = meta[0].astype(jnp.int32)
    qo = meta[1].astype(jnp.int32)

    def f(q, k, v):
        return attention_ref(
            q, k, v, causal=causal, scale=scale, q_offset=qo, kv_len=kvl
        )

    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, jnp.zeros_like(meta)


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(
    q: jnp.ndarray,  # (b, Sq, hq, d) -- model layout (see models/layers.py)
    k: jnp.ndarray,  # (b, Skv, hkv, d)
    v: jnp.ndarray,  # (b, Skv, hkv, dv)
    *,
    causal: bool,
    q_offset=0,
    kv_len: Optional[jnp.ndarray] = None,
    sm_scale: Optional[float] = None,
    blocks: Optional[Tuple[int, int]] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Drop-in for models.layers.mha's math on TPU. Handles GQA natively
    and pads Sq/Skv up to the block grid (padded KV is masked via kv_len)."""
    interpret = _cfg.interpret_default() if interpret is None else interpret
    b, Sq, hq, d = q.shape
    _, Skv, hkv, dv = v.shape
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    bq, bk = blocks or plan_blocks(round_up(Sq, 128), round_up(Skv, 128), d)
    bq, bk = min(bq, round_up(Sq, 8)), min(bk, round_up(Skv, 8))
    Sqp, Skvp = round_up(Sq, bq), round_up(Skv, bk)
    qt = jnp.swapaxes(q, 1, 2)  # (b, hq, Sq, d)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if Sqp != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skvp != Skv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
    meta = jnp.stack(
        [
            jnp.asarray(Skv if kv_len is None else kv_len, jnp.float32),
            jnp.asarray(q_offset, jnp.float32),
        ]
    )
    out = _fa(qt, kt, vt, meta, causal, scale, (bq, bk), interpret)
    return jnp.swapaxes(out[:, :, :Sq], 1, 2)  # (b, Sq, hq, dv)
