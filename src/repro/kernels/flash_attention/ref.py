"""Pure-jnp oracle for flash attention (materializes the score matrix)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Skv, D)
    v: jnp.ndarray,  # (B, Hkv, Skv, Dv)
    *,
    causal: bool,
    scale: float,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, Dv = v.shape
    g = Hq // Hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with every key masked (decode padding): emit zeros like the kernel
    any_live = mask.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
    return jnp.where(any_live, out, 0.0).astype(q.dtype)
