"""Public chunked-SSD op: Pallas intra-chunk kernel + jnp inter-chunk scan.

Signature matches models.ssm._ssd_chunked so the model can swap it in on
TPU. Chunk sizing goes through the shared co-design layer
(docs/codesign.md): :class:`SsdScanSpace` registers the intra-chunk score
GEMM with ``repro.codesign`` and ``plan_chunk`` is a thin wrapper over
the single ``codesign.plan`` path. The space's ``legalize`` is BINDING --
it encodes the kernel's exact working-set rule (the same Union R3
legality rule the matmul planner uses: cl*cl f32 scores + operands within
the unified VMEM budget) and picks the largest power-of-two chunk that
satisfies it, regardless of what the mapper proposed, so the policy
"maximize the chunk under R3" stays exact.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import codesign
from repro import kernels as _cfg
from repro.codesign import DEFAULT_VMEM_BUDGET, KernelSpace
from repro.core.problem import Problem
from repro.kernels.ssd_scan.ssd_scan import ssd_intra_chunk_pallas

#: proxy extent of the chunk dims in the search Problem = the largest
#: chunk ``legalize`` can pick, so every candidate tile is a divisor
_MAX_CHUNK = 1024


class SsdScanSpace(KernelSpace):
    """Co-design space of the chunked-SSD kernel: shape = (hp, n),
    BlockConfig = (cl,) -- the chunk length."""

    name = "ssd_scan"
    decode_dims = ("l",)
    search_budget = 200

    def problem(self, shape):
        hp, n = shape
        # intra-chunk score GEMM C . B^T over the state dim: the chunk
        # appears as both free dims of the cl x cl score block
        return Problem.from_einsum(
            "ssd_scores",
            "ln,mn->lm",
            {"l": _MAX_CHUNK, "m": _MAX_CHUNK, "n": n},
            "GEMM",
        )

    def legalize(self, config, shape, vmem_budget=None):
        """BINDING repair: largest power-of-two chunk cl with the kernel
        working set in VMEM -- cl*cl scores + L (2x) + cl*(hp + 2n + 2)
        operands, all f32. The mapper's proposal is intentionally ignored
        (the policy is maximize-chunk-under-R3, not argmin of a model)."""
        hp, n = shape
        budget = int(vmem_budget or self.vmem_budget)
        cl = _MAX_CHUNK
        while cl > 64:
            ws = 4 * (2 * cl * cl + cl * (hp + 2 * n + 2) + n * hp)
            if ws <= budget:
                return (cl,)
            cl //= 2
        return (64,)

    def block_tiles(self, shape, config):
        # the chunk is BOTH free dims of the score block (n stays full)
        (cl,) = config
        return {"l": cl, "m": cl}

    def example_inputs(self, shape, seed: int = 0):
        hp, n = shape
        b, l, nh = 1, 256, 1
        kx, ka, kb, kc = jax.random.split(jax.random.PRNGKey(seed), 4)
        return (
            jax.random.normal(kx, (b, l, nh, hp), jnp.float32),
            -jnp.abs(jax.random.normal(ka, (b, l, nh), jnp.float32)) * 0.1,
            jax.random.normal(kb, (b, l, nh, n), jnp.float32),
            jax.random.normal(kc, (b, l, nh, n), jnp.float32),
        )

    def run(self, inputs, config, interpret: bool = True):
        x, dA, B, C = inputs
        (cl,) = config
        chunk = min(int(cl), x.shape[1])
        return ssd_chunked(x, dA, B, C, chunk=chunk, interpret=interpret)


SSD_SCAN_SPACE = codesign.register_space(SsdScanSpace())


@functools.lru_cache(maxsize=64)
def plan_chunk(hp: int, n: int, vmem_budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Plan the chunk length via ``codesign.plan`` (legalize is binding:
    largest power-of-two cl whose working set fits ``vmem_budget``)."""
    return codesign.plan(
        SSD_SCAN_SPACE, (hp, n), vmem_budget=vmem_budget
    ).config[0]


def ssd_chunked(
    x: jnp.ndarray,  # (b, l, nh, hp) dt-scaled inputs (f32 or bf16)
    dA: jnp.ndarray,  # (b, l, nh)
    B: jnp.ndarray,  # (b, l, nh, n)
    C: jnp.ndarray,  # (b, l, nh, n)
    chunk: Optional[int] = None,
    init_state: Optional[jnp.ndarray] = None,  # (b, nh, hp, n)
    *,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (b,l,nh,hp) f32, final_state (b,nh,hp,n) f32).

    Differentiable: forward runs the Pallas intra-chunk kernel; backward
    recomputes through the jnp oracle (ref.py) under ``jax.vjp``.
    """
    interpret = _cfg.interpret_default() if interpret is None else interpret
    b, l, nh, hp = x.shape
    n = B.shape[-1]
    chunk = chunk or min(plan_chunk(hp, n), l)
    s0 = (
        init_state
        if init_state is not None
        else jnp.zeros((b, nh, hp, n), jnp.float32)
    )
    return _ssd(x, dA, B, C, s0, chunk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dA, B, C, s0, chunk, interpret):
    return _ssd_impl(x, dA, B, C, s0, chunk, interpret)


def _ssd_fwd(x, dA, B, C, s0, chunk, interpret):
    return _ssd(x, dA, B, C, s0, chunk, interpret), (x, dA, B, C, s0)


def _ssd_bwd(chunk, interpret, res, g):
    from repro.kernels.ssd_scan.ref import ssd_chunked_ref

    x, dA, B, C, s0 = res
    _, vjp = jax.vjp(
        lambda *a: ssd_chunked_ref(*a[:4], chunk=chunk, init_state=a[4]),
        x, dA, B, C, s0,
    )
    return vjp(g)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def _ssd_impl(
    x, dA, B, C, init_state, chunk, interpret
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, l, nh, hp = x.shape
    n = B.shape[-1]
    chunk = chunk or min(plan_chunk(hp, n), l)
    assert l % chunk == 0, f"seq {l} % chunk {chunk} != 0"
    nc = l // chunk

    # (b, l, nh, *) -> (b, nh, nc, cl, *): head-major so each grid step is
    # one contiguous (cl, *) VMEM block
    def to_blocks(t, feat):
        t = t.astype(jnp.float32)
        if feat:
            return t.reshape(b, nc, chunk, nh, -1).transpose(0, 3, 1, 2, 4)
        return t.reshape(b, nc, chunk, nh).transpose(0, 3, 1, 2)

    xb, dab = to_blocks(x, True), to_blocks(dA, False)
    bb, cb = to_blocks(B, True), to_blocks(C, True)

    y_diag, S_c, dte = ssd_intra_chunk_pallas(xb, dab, bb, cb, interpret=interpret)

    # inter-chunk recurrence (cheap, O(nc) elementwise+add)
    chunk_decay = dte[:, :, :, -1]  # (b, nh, nc) = exp(full-chunk decay)
    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, nh, hp, n), jnp.float32)
    )

    def step(S_prev, inp):
        S_new, dec = inp  # (b, nh, n, hp), (b, nh)
        S_next = S_prev * dec[:, :, None, None] + S_new
        return S_next, S_prev  # emit the state ENTERING this chunk

    xs = (S_c.transpose(2, 0, 1, 3, 4), chunk_decay.transpose(2, 0, 1))
    final_nhp, S_in = jax.lax.scan(step, s0.transpose(0, 1, 3, 2), xs)
    S_in = S_in.transpose(1, 2, 0, 3, 4)  # (b, nh, nc, n, hp)

    # inter-chunk contribution: y_off[l] = (C_l . S_in) * exp(cum_l)
    y_off = jnp.einsum("bhcln,bhcnp,bhcl->bhclp", cb, S_in, dte)
    y = (y_diag + y_off).transpose(0, 2, 3, 1, 4).reshape(b, l, nh, hp)
    return y, final_nhp.transpose(0, 1, 3, 2)
