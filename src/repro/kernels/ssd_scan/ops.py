"""Public chunked-SSD op: Pallas intra-chunk kernel + jnp inter-chunk scan.

Signature matches models.ssm._ssd_chunked so the model can swap it in on
TPU. ``plan_chunk`` sizes the chunk with the same Union R3 legality rule
used by the matmul planner (cl*cl f32 scores + operands within VMEM).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import kernels as _cfg
from repro.core.architecture import TPU_V5E
from repro.kernels.ssd_scan.ssd_scan import ssd_intra_chunk_pallas


@functools.lru_cache(maxsize=64)
def plan_chunk(hp: int, n: int, vmem_budget: int = 8 * (1 << 20)) -> int:
    """Largest power-of-two chunk cl with the kernel working set in VMEM:
    cl*cl scores + L (2x) + cl*(hp + 2n + 2) operands, all f32."""
    cl = 1024
    while cl > 64:
        ws = 4 * (2 * cl * cl + cl * (hp + 2 * n + 2) + n * hp)
        if ws <= vmem_budget:
            return cl
        cl //= 2
    return 64


def ssd_chunked(
    x: jnp.ndarray,  # (b, l, nh, hp) dt-scaled inputs (f32 or bf16)
    dA: jnp.ndarray,  # (b, l, nh)
    B: jnp.ndarray,  # (b, l, nh, n)
    C: jnp.ndarray,  # (b, l, nh, n)
    chunk: Optional[int] = None,
    init_state: Optional[jnp.ndarray] = None,  # (b, nh, hp, n)
    *,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (b,l,nh,hp) f32, final_state (b,nh,hp,n) f32).

    Differentiable: forward runs the Pallas intra-chunk kernel; backward
    recomputes through the jnp oracle (ref.py) under ``jax.vjp``.
    """
    interpret = _cfg.interpret_default() if interpret is None else interpret
    b, l, nh, hp = x.shape
    n = B.shape[-1]
    chunk = chunk or min(plan_chunk(hp, n), l)
    s0 = (
        init_state
        if init_state is not None
        else jnp.zeros((b, nh, hp, n), jnp.float32)
    )
    return _ssd(x, dA, B, C, s0, chunk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dA, B, C, s0, chunk, interpret):
    return _ssd_impl(x, dA, B, C, s0, chunk, interpret)


def _ssd_fwd(x, dA, B, C, s0, chunk, interpret):
    return _ssd(x, dA, B, C, s0, chunk, interpret), (x, dA, B, C, s0)


def _ssd_bwd(chunk, interpret, res, g):
    from repro.kernels.ssd_scan.ref import ssd_chunked_ref

    x, dA, B, C, s0 = res
    _, vjp = jax.vjp(
        lambda *a: ssd_chunked_ref(*a[:4], chunk=chunk, init_state=a[4]),
        x, dA, B, C, s0,
    )
    return vjp(g)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def _ssd_impl(
    x, dA, B, C, init_state, chunk, interpret
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, l, nh, hp = x.shape
    n = B.shape[-1]
    chunk = chunk or min(plan_chunk(hp, n), l)
    assert l % chunk == 0, f"seq {l} % chunk {chunk} != 0"
    nc = l // chunk

    # (b, l, nh, *) -> (b, nh, nc, cl, *): head-major so each grid step is
    # one contiguous (cl, *) VMEM block
    def to_blocks(t, feat):
        t = t.astype(jnp.float32)
        if feat:
            return t.reshape(b, nc, chunk, nh, -1).transpose(0, 3, 1, 2, 4)
        return t.reshape(b, nc, chunk, nh).transpose(0, 3, 1, 2)

    xb, dab = to_blocks(x, True), to_blocks(dA, False)
    bb, cb = to_blocks(B, True), to_blocks(C, True)

    y_diag, S_c, dte = ssd_intra_chunk_pallas(xb, dab, bb, cb, interpret=interpret)

    # inter-chunk recurrence (cheap, O(nc) elementwise+add)
    chunk_decay = dte[:, :, :, -1]  # (b, nh, nc) = exp(full-chunk decay)
    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, nh, hp, n), jnp.float32)
    )

    def step(S_prev, inp):
        S_new, dec = inp  # (b, nh, n, hp), (b, nh)
        S_next = S_prev * dec[:, :, None, None] + S_new
        return S_next, S_prev  # emit the state ENTERING this chunk

    xs = (S_c.transpose(2, 0, 1, 3, 4), chunk_decay.transpose(2, 0, 1))
    final_nhp, S_in = jax.lax.scan(step, s0.transpose(0, 1, 3, 2), xs)
    S_in = S_in.transpose(1, 2, 0, 3, 4)  # (b, nh, nc, n, hp)

    # inter-chunk contribution: y_off[l] = (C_l . S_in) * exp(cum_l)
    y_off = jnp.einsum("bhcln,bhcnp,bhcl->bhclp", cb, S_in, dte)
    y = (y_diag + y_off).transpose(0, 2, 3, 1, 4).reshape(b, l, nh, hp)
    return y, final_nhp.transpose(0, 1, 3, 2)
