"""Pure-jnp oracle for the chunked SSD scan (standalone; also cross-checked
against models.ssm._ssd_chunked and the O(1)-state recurrence in tests)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def ssd_recurrent_ref(
    x: jnp.ndarray,  # (b, l, nh, hp) dt-scaled inputs
    dA: jnp.ndarray,  # (b, l, nh) log decay per step
    B: jnp.ndarray,  # (b, l, nh, n)
    C: jnp.ndarray,  # (b, l, nh, n)
    init_state: Optional[jnp.ndarray] = None,  # (b, nh, hp, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token recurrence: S_t = exp(dA_t) S_{t-1} + x_t B_t^T;
    y_t = S_t C_t. The slowest, most obviously-correct form."""
    b, l, nh, hp = x.shape
    n = B.shape[-1]
    S = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, nh, hp, n), jnp.float32)
    )
    ys = []
    for t in range(l):
        S = S * jnp.exp(dA[:, t].astype(jnp.float32))[:, :, None, None] + jnp.einsum(
            "bhp,bhn->bhpn", x[:, t].astype(jnp.float32), B[:, t].astype(jnp.float32)
        )
        ys.append(jnp.einsum("bhpn,bhn->bhp", S, C[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1), S


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked_ref(
    x: jnp.ndarray,  # (b, l, nh, hp)
    dA: jnp.ndarray,  # (b, l, nh)
    B: jnp.ndarray,  # (b, l, nh, n)
    C: jnp.ndarray,  # (b, l, nh, n)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunkwise-parallel form, mathematically equal to ssd_recurrent_ref."""
    b, l, nh, hp = x.shape
    n = B.shape[-1]
    assert l % chunk == 0
    nc = l // chunk
    xr = x.reshape(b, nc, chunk, nh, hp).astype(jnp.float32)
    dAr = dA.reshape(b, nc, chunk, nh).astype(jnp.float32)
    Br = B.reshape(b, nc, chunk, nh, n).astype(jnp.float32)
    Cr = C.reshape(b, nc, chunk, nh, n).astype(jnp.float32)

    Lmat = jnp.exp(_segsum(dAr.transpose(0, 1, 3, 2)))  # (b, nc, nh, cl, cl)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cr, Br)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores * Lmat, xr)

    cum = jnp.cumsum(dAr, axis=2)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    S_c = jnp.einsum("bcshn,bcsh,bcshp->bchpn", Br, decay_to_end, xr)

    chunk_decay = jnp.exp(cum[:, :, -1, :])
    S = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, nh, hp, n), jnp.float32)
    )
    S_ins = []
    for c in range(nc):
        S_ins.append(S)
        S = S * chunk_decay[:, c][:, :, None, None] + S_c[:, c]
    S_in = jnp.stack(S_ins, axis=1)  # (b, nc, nh, hp, n)

    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cr, S_in, jnp.exp(cum))
    return (y_diag + y_off).reshape(b, l, nh, hp), S
