"""Mamba-2 chunked SSD intra-chunk Pallas kernel.

The SSD (state-space dual) algorithm splits the sequence into chunks; the
quadratic *intra-chunk* work (the decay-masked C.B^T score matmul and the
chunk-state outer product) dominates compute and is MXU-shaped -- that is
the kernel. The O(nc) inter-chunk recurrence and the rank-1 elementwise
decay algebra are cheap and stay in jnp (ops.py), mirroring how the paper
keeps the coarse-grained schedule outside the accelerator cost model.

Per grid step (b, h, c) the kernel computes, entirely in VMEM:
  L     = exp(segsum(dA_chunk))               (cl, cl) lower-triangular
  scores = (C @ B^T) * L                      (cl, cl)
  y_diag = scores @ x                         (cl, hp)
  S_c    = B^T @ (x * exp(cum_end - cum))     (n, hp)   chunk-final state

Union mapping view: chunk length `cl` is the C1 temporal tile of the
sequence dim; rule R3 (cl*cl f32 scores + operands <= VMEM) bounds it,
which is why ops.plan_chunk consults the same legality machinery.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, 1, 1, cl, hp) f32  -- dt-scaled inputs
    da_ref,  # (1, 1, 1, cl)     f32  -- per-step log decay (<= 0)
    b_ref,  # (1, 1, 1, cl, n)  f32
    c_ref,  # (1, 1, 1, cl, n)  f32
    y_ref,  # (1, 1, 1, cl, hp) f32  out: intra-chunk output
    s_ref,  # (1, 1, 1, n, hp)  f32  out: chunk-final state contribution
    dte_ref,  # (1, 1, 1, cl)   f32  out: exp(cum) in-chunk growth factors
):
    x = x_ref[0, 0, 0]  # (cl, hp)
    dA = da_ref[0, 0, 0]  # (cl,)
    B = b_ref[0, 0, 0]  # (cl, n)
    C = c_ref[0, 0, 0]  # (cl, n)
    cl = x.shape[0]

    cum = jnp.cumsum(dA)  # (cl,) inclusive
    # segsum: L[i, j] = exp(sum_{k=j+1..i} dA_k) for j <= i else 0
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (cl, cl) = C @ B^T
    y_ref[0, 0, 0] = jax.lax.dot(
        scores * L, x, preferred_element_type=jnp.float32
    )

    decay_to_end = jnp.exp(cum[-1] - cum)  # (cl,)
    s_ref[0, 0, 0] = jax.lax.dot_general(
        B, x * decay_to_end[:, None],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (n, hp) = B^T @ (x * dte)
    dte_ref[0, 0, 0] = jnp.exp(cum)


def ssd_intra_chunk_pallas(
    x: jnp.ndarray,  # (b, nh, nc, cl, hp) f32, dt-scaled
    dA: jnp.ndarray,  # (b, nh, nc, cl) f32
    B: jnp.ndarray,  # (b, nh, nc, cl, n) f32
    C: jnp.ndarray,  # (b, nh, nc, cl, n) f32
    *,
    interpret: bool = False,
):
    b, nh, nc, cl, hp = x.shape
    n = B.shape[-1]
    grid = (b, nh, nc)
    idx5 = lambda i, h, c: (i, h, c, 0, 0)
    idx4 = lambda i, h, c: (i, h, c, 0)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, cl, hp), idx5),
            pl.BlockSpec((1, 1, 1, cl), idx4),
            pl.BlockSpec((1, 1, 1, cl, n), idx5),
            pl.BlockSpec((1, 1, 1, cl, n), idx5),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, cl, hp), idx5),
            pl.BlockSpec((1, 1, 1, n, hp), idx5),
            pl.BlockSpec((1, 1, 1, cl), idx4),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, nc, cl, hp), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, nc, n, hp), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, nc, cl), jnp.float32),
        ],
        interpret=interpret,
        name="union_ssd_intra_chunk",
    )(x, dA, B, C)
