"""Pallas TPU kernels for the compute hot-spots, tiled by Union mappings.

Each kernel directory has three files:
  <name>.py -- the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py    -- the jit'd public wrapper (padding, tile selection, vjp)
  ref.py    -- the pure-jnp oracle the kernel is validated against

The co-design closure (DESIGN.md Sec. 2): BlockSpec tile sizes are not
hand-picked constants -- they come from a Union mapping of the operator's
Problem onto the ``tpu_chip()`` cluster hierarchy (HBM -> grid-step ->
VMEM+MXU), found by Union-opt under MXU-alignment constraints. Rule R3
(tile footprint <= VMEM) makes every legal mapping a valid BlockSpec.

``set_interpret(True)`` routes all kernels through interpret mode (Python
execution of the kernel body) for CPU validation; on TPU leave it False.
"""

_INTERPRET = False
_USE_PALLAS = False


def set_interpret(value: bool) -> None:
    global _INTERPRET
    _INTERPRET = bool(value)


def interpret_default() -> bool:
    return _INTERPRET


def enable_pallas(value: bool = True, *, interpret: bool | None = None) -> None:
    """Route model attention/SSD through the Pallas kernels.

    On CPU pass interpret=True (kernel bodies execute in Python); on TPU
    leave interpret unset/False for compiled kernels.
    """
    global _USE_PALLAS
    _USE_PALLAS = bool(value)
    if interpret is not None:
        set_interpret(interpret)


def pallas_enabled() -> bool:
    return _USE_PALLAS
