"""Mapping-as-a-service: a crash-safe, deadline-enforced query daemon.

Union's pitch is that mappings are REUSABLE ARTIFACTS: once a (problem,
arch, metric) space has been searched, the answer should be served, not
recomputed. The sweep side of that story is ``repro.core.sweep_exec``
(concurrent groups, journal + resume, fault injection); this module is
the daemon half -- a long-running process that accepts mapping queries
over local HTTP and answers

* from the **answer journal** in O(ms) when warm (a previously answered
  query replays its journaled solution record verbatim -- byte-identical
  across restarts and kill -9 by construction), or
* by a **bounded search** on miss, warm-started from the store's
  nearest-neighbor space and flushed store-ahead-of-journal exactly like
  the sweep executor.

Robustness is the product, not a feature:

* **Backpressure** -- a bounded admission queue; a full queue sheds the
  request with HTTP 429 + ``Retry-After`` (``shed`` counter, live
  ``queue_depth`` in ``/metrics``) instead of queueing unboundedly.
* **Per-query deadlines** -- the cold search runs in budget slices, each
  under :func:`~repro.runtime.fault_tolerance.call_with_deadline`; a
  missed deadline returns the best incumbent found so far flagged
  ``budget_exhausted`` (never an error), falling back to one
  deterministic candidate when no slice finished.
* **Circuit breaker** -- a service-wide
  :class:`~repro.runtime.fault_tolerance.CircuitBreaker` wraps the jax
  engine backend: consecutive jax failures open the circuit (queries run
  the bit-identical numpy path), the deterministic probe schedule admits
  half-open probes, and a clean jax query closes it again -- the
  stateful, recoverable form of the sweep executor's one-way
  degradation.
* **Nearest-neighbor warm start** -- a cold query seeds the engine's
  incumbent from the best stored cost of the content-nearest space
  (same model + arch, scaled by the iteration-space ratio with slack),
  so admission prunes from candidate #1; a too-optimistic seed is
  detected (no survivor) and the slice re-runs unseeded
  (``seed_misfires``).
* **Crash safety** -- every completed search flushes the ResultStore
  BEFORE its journal record (the sweep executor's ordering), the daemon
  drains gracefully on SIGTERM (stop accepting, finish + journal
  in-flight queries, flush, exit 0), and a kill -9'd daemon restarted on
  the same state directory answers previously-answered queries from the
  journal with zero re-search.

Deterministic fault injection reuses the ``UNION_FAULT_SPEC`` grammar
(see ``repro.core.sweep_exec``), with the group index reinterpreted as
the QUERY ORDINAL (0-based arrival order of cold searches):

    jaxfail:Q        query Q's engine sees a jax failure -> breaker
                     records it, engine degrades to numpy mid-search
    slow:Q@K:S       query Q sleeps S seconds before budget slice K --
                     deadline-with-partial-result paths fire
                     deterministically

HTTP API (all JSON; see ``docs/mapping_service.md`` for the schemas):

    POST /v1/mapping   {problem, arch, metric?, mapper?, budget?,
                        deadline_s?}  ->  answer envelope
    GET  /metrics      service counters + breaker/store/journal stats
    GET  /healthz      {"ok": true, "draining": false}

Run it: ``python -m repro.serve.mapping_service --state-dir DIR``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import logging
import math
import os
import queue
import random
import signal
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

from repro.core.architecture import (
    chiplet_accelerator,
    cloud_accelerator,
    edge_accelerator,
    tpu_chip,
)
from repro.core.cost.engine import EvaluationEngine
from repro.core.cost.store import (
    ResultStore,
    SweepJournal,
    _canon_arch,
    _canon_problem,
    _problem_features,
)
from repro.core.mappers import MAPPER_REGISTRY
from repro.core.mappers.base import SearchResult
from repro.core.mapspace import MapSpace
from repro.core.optimizer import COST_MODEL_REGISTRY
from repro.core.problem import Problem
from repro.core.sweep_exec import FaultSpec, result_to_record
from repro.runtime.fault_tolerance import (
    CallTimeoutError,
    CircuitBreaker,
    call_with_deadline,
)

log = logging.getLogger("repro.serve")

QUERY_VERSION = 1

# first slice is small so SOME incumbent exists within milliseconds even
# under a tight deadline; later slices amortize mapper/setup overhead
_FIRST_SLICE = 64
_SLICE = 256
# distinct Philox/sample streams per slice (re-sampling slice 0's stream
# would only produce memo hits and waste the budget)
_SLICE_SEED_STRIDE = 100003


class QueryError(ValueError):
    """A query is malformed (unknown kind/mapper/metric, bad sizes)."""


# --------------------------------------------------------------------- #
# Query parsing
# --------------------------------------------------------------------- #
_METRICS = ("edp", "latency", "energy")


def _parse_problem(spec) -> Problem:
    if not isinstance(spec, dict):
        raise QueryError("problem must be an object")
    kind = str(spec.get("kind", "gemm")).lower()
    name = str(spec.get("name", kind))
    wb = int(spec.get("word_bytes", 2))
    try:
        if kind == "gemm":
            return Problem.gemm(
                int(spec["m"]), int(spec["n"]), int(spec["k"]),
                name=name, word_bytes=wb,
            )
        if kind == "conv2d":
            return Problem.conv2d(
                int(spec.get("n", 1)), int(spec["k"]), int(spec["c"]),
                int(spec["x"]), int(spec["y"]), int(spec["r"]),
                int(spec["s"]), stride=int(spec.get("stride", 1)),
                name=name, word_bytes=wb,
            )
        if kind == "mttkrp":
            return Problem.mttkrp(
                int(spec["i"]), int(spec["j"]), int(spec["k"]),
                int(spec["l"]), name=name, word_bytes=wb,
            )
    except QueryError:
        raise
    except Exception as e:
        raise QueryError(f"bad problem spec ({type(e).__name__}: {e})") from None
    raise QueryError(f"unknown problem kind {kind!r}")


def _parse_arch(spec):
    if spec is None:
        return edge_accelerator()
    if not isinstance(spec, dict):
        raise QueryError("arch must be an object")
    kind = str(spec.get("kind", "edge")).lower()
    try:
        if kind == "edge":
            aspect = spec.get("aspect", (16, 16))
            return edge_accelerator(aspect=(int(aspect[0]), int(aspect[1])))
        if kind == "cloud":
            aspect = spec.get("aspect", (32, 64))
            return cloud_accelerator(aspect=(int(aspect[0]), int(aspect[1])))
        if kind == "chiplet":
            return chiplet_accelerator(
                n_chiplets=int(spec.get("n_chiplets", 16))
            )
        if kind == "tpu":
            return tpu_chip()
    except QueryError:
        raise
    except Exception as e:
        raise QueryError(f"bad arch spec ({type(e).__name__}: {e})") from None
    raise QueryError(f"unknown arch kind {kind!r}")


def query_fingerprint(cost_model, problem, arch, metric: str,
                      mapper_name: str, mapper_kw: dict, budget: int) -> str:
    """Stable content fingerprint of one mapping query.

    Built on the store's canonical problem/arch forms, so two queries
    that differ only in display names (which never affect costs) share
    one journal answer. The DEADLINE is deliberately excluded: it shapes
    how long a cold search may run, not what the converged answer is,
    and only complete (non-exhausted) answers are journaled.
    """
    desc = json.dumps(
        {
            "version": QUERY_VERSION,
            "model": [repr(p) for p in cost_model.store_key_parts()],
            "problem": _canon_problem(problem),
            "arch": _canon_arch(arch),
            "metric": metric,
            "mapper": [mapper_name, sorted(mapper_kw.items())],
            "budget": int(budget),
        },
        sort_keys=True,
        default=repr,
    )
    return hashlib.sha256(desc.encode()).hexdigest()[:24]


class _ParsedQuery:
    __slots__ = (
        "problem", "arch", "cost_model", "metric", "mapper_name",
        "mapper_kw", "budget", "deadline_s", "fingerprint",
    )

    def __init__(self, q: dict, default_deadline_s: Optional[float]) -> None:
        if not isinstance(q, dict):
            raise QueryError("query must be a JSON object")
        self.problem = _parse_problem(q.get("problem"))
        self.arch = _parse_arch(q.get("arch"))
        metric = str(q.get("metric", "edp"))
        if metric not in _METRICS:
            raise QueryError(f"unknown metric {metric!r} (want {_METRICS})")
        self.metric = metric
        model = str(q.get("model", "timeloop"))
        if model not in COST_MODEL_REGISTRY:
            raise QueryError(f"unknown cost model {model!r}")
        self.cost_model = COST_MODEL_REGISTRY[model]()
        mspec = q.get("mapper") or {}
        if isinstance(mspec, str):
            mspec = {"name": mspec}
        if not isinstance(mspec, dict):
            raise QueryError("mapper must be a name or an object")
        self.mapper_name = str(mspec.get("name", "random"))
        if self.mapper_name not in MAPPER_REGISTRY:
            raise QueryError(
                f"unknown mapper {self.mapper_name!r} "
                f"(want one of {sorted(MAPPER_REGISTRY)})"
            )
        kw = dict(mspec.get("kw") or {})
        budget = q.get("budget", kw.get("samples", 512))
        try:
            self.budget = max(1, int(budget))
        except (TypeError, ValueError):
            raise QueryError(f"bad budget {budget!r}") from None
        self.mapper_kw = kw
        d = q.get("deadline_s", default_deadline_s)
        self.deadline_s = None if d is None else float(d)
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise QueryError("deadline_s must be positive (or null)")
        self.fingerprint = query_fingerprint(
            self.cost_model, self.problem, self.arch, self.metric,
            self.mapper_name, self.mapper_kw, self.budget,
        )


# --------------------------------------------------------------------- #
# Search-result merging across budget slices
# --------------------------------------------------------------------- #
def _merge_results(a: Optional[SearchResult], b: Optional[SearchResult],
                   metric: str) -> Optional[SearchResult]:
    """Fold slice ``b`` into running result ``a``: keep the better
    incumbent, sum every counter, concatenate trajectories with ``b``'s
    eval indices rebased past ``a``'s -- the record a sliced search
    journals is one coherent SearchResult."""
    if a is None:
        return b
    if b is None:
        return a
    better = b if b.best_metric < a.best_metric else a
    traj = list(a.trajectory) + [
        (i + a.considered, v) for i, v in b.trajectory
    ]
    return SearchResult(
        best_mapping=better.best_mapping,
        best_cost=better.best_cost,
        metric=metric,
        evaluated=a.evaluated + b.evaluated,
        elapsed_s=a.elapsed_s + b.elapsed_s,
        trajectory=traj,
        cache_hits=a.cache_hits + b.cache_hits,
        pruned=a.pruned + b.pruned,
        analyzed=a.analyzed + b.analyzed,
        store_hits=a.store_hits + b.store_hits,
        considered=a.considered + b.considered,
        fused_dispatches=a.fused_dispatches + b.fused_dispatches,
        backend_fallbacks=a.backend_fallbacks + b.backend_fallbacks,
        n_traces=a.n_traces + b.n_traces,
        device_syncs=a.device_syncs + b.device_syncs,
        admit_s=a.admit_s + b.admit_s,
        score_s=a.score_s + b.score_s,
    )


def _slice_plan(total: int) -> List[int]:
    sizes = [min(_FIRST_SLICE, total)]
    rem = total - sizes[0]
    while rem > 0:
        s = min(_SLICE, rem)
        sizes.append(s)
        rem -= s
    return sizes


# --------------------------------------------------------------------- #
# The service
# --------------------------------------------------------------------- #
class MappingService:
    """The daemon's engine room, usable in-process (tests drive
    :meth:`handle_query` directly) or behind the HTTP front
    (:func:`serve`/``main``).

    One ``state_dir`` holds everything a restart needs: the ResultStore
    space files (+ ``_meta.json`` for nearest-neighbor lookup) and the
    answer journal ``answers.journal`` (a :class:`SweepJournal` keyed by
    query fingerprint, always opened with ``resume=True`` -- the journal
    IS the service's memory). Cold searches are serialized by a search
    lock (one ResultStore handle, deterministic store traffic); warm
    journal answers bypass it entirely, so a slow cold search never
    blocks the O(ms) warm path beyond one worker.
    """

    def __init__(
        self,
        state_dir: str,
        *,
        backend: str = "numpy",
        deadline_s: Optional[float] = 5.0,
        queue_cap: int = 8,
        workers: int = 2,
        store_cap: Optional[int] = None,
        breaker_threshold: int = 2,
        probe_interval: int = 2,
        seed_slack: float = 4.0,
        fault_spec: Optional[str] = None,
    ) -> None:
        self.state_dir = str(state_dir)
        os.makedirs(self.state_dir, exist_ok=True)
        # read-refresh: a concurrently running sweep's flushes become
        # visible to this long-lived process without a restart
        self.store = ResultStore(
            self.state_dir, max_entries_per_space=store_cap, refresh=True
        )
        self.journal = SweepJournal(
            os.path.join(self.state_dir, "answers.journal"), resume=True
        )
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.default_deadline_s = deadline_s
        self.queue_cap = int(queue_cap)
        self.n_workers = max(1, int(workers))
        self.seed_slack = float(seed_slack)
        self.breaker = CircuitBreaker(
            failure_threshold=breaker_threshold,
            probe_interval=probe_interval,
            label="jax-backend",
        )
        self.fault = FaultSpec.parse(
            fault_spec if fault_spec is not None
            else os.environ.get("UNION_FAULT_SPEC")
        )
        self.jobs: "queue.Queue" = queue.Queue(maxsize=self.queue_cap)
        self.draining = False
        self._search_lock = threading.Lock()
        self._state_lock = threading.Lock()
        self._workers: List[threading.Thread] = []
        self._search_seq = 0  # cold-search arrival ordinal (fault-spec id)
        # ---- counters (all under _state_lock)
        self.queries = 0
        self.store_hits = 0        # answered from the journal, zero search
        self.searches = 0          # cold searches run
        self.partials = 0          # budget_exhausted answers
        self.fallback_answers = 0  # deadline hit before any slice finished
        self.shed = 0              # 429s from the full admission queue
        self.errors = 0            # malformed queries
        self.seeded = 0            # cold searches warm-started from a neighbor
        self.seed_misfires = 0     # seeds that pruned everything (retried)
        self.neighbor_hits = 0
        self.neighbor_misses = 0
        self.neighbor_distance_sum = 0.0

    # ------------------------------------------------------------- #
    # Worker pool + drain
    # ------------------------------------------------------------- #
    def start_workers(self) -> None:
        for i in range(self.n_workers):
            t = threading.Thread(
                target=self._worker_loop, name=f"mapsvc-w{i}", daemon=True
            )
            t.start()
            self._workers.append(t)

    def _worker_loop(self) -> None:
        while True:
            job = self.jobs.get()
            if job is None:
                self.jobs.task_done()
                return
            try:
                job.result = self.handle_query(job.query)
            except Exception as e:  # noqa: BLE001 -- envelope, never crash
                log.exception("query failed")
                job.result = {
                    "ok": False,
                    "error": f"{type(e).__name__}: {e}",
                }
            finally:
                job.event.set()
                self.jobs.task_done()

    def drain(self) -> None:
        """Graceful shutdown: stop admitting (callers see ``draining``),
        finish + journal every queued and in-flight query, stop the
        workers, flush the store. Idempotent."""
        self.draining = True
        self.jobs.join()  # every admitted job answered (and journaled)
        for _ in self._workers:
            self.jobs.put(None)
        for t in self._workers:
            t.join(timeout=10.0)
        self._workers = []
        self.store.flush()
        self.journal.flush()

    # ------------------------------------------------------------- #
    # Query handling
    # ------------------------------------------------------------- #
    def handle_query(self, q: dict) -> dict:
        t0 = time.perf_counter()
        try:
            parsed = _ParsedQuery(q, self.default_deadline_s)
        except QueryError as e:
            with self._state_lock:
                self.errors += 1
            return {"ok": False, "error": str(e)}
        with self._state_lock:
            self.queries += 1
        env = self._answer(parsed)
        env["elapsed_ms"] = round((time.perf_counter() - t0) * 1e3, 3)
        return env

    def _warm_answer(self, fp: str) -> Optional[dict]:
        rec = self.journal.get_task(fp)
        if rec is None:
            return None
        with self._state_lock:
            self.store_hits += 1
        return {
            "ok": True,
            "source": "store",
            "fingerprint": fp,
            "budget_exhausted": False,
            "seeded": False,
            "neighbor": None,
            "record": rec,
        }

    def _answer(self, parsed: _ParsedQuery) -> dict:
        env = self._warm_answer(parsed.fingerprint)
        if env is not None:
            return env
        with self._search_lock:
            # a queued duplicate may have been answered while we waited
            env = self._warm_answer(parsed.fingerprint)
            if env is not None:
                return env
            return self._search(parsed)

    # ------------------------------------------------------------- #
    def _neighbor_seed(self, parsed: _ParsedQuery, skey: str):
        """(seed value, info dict) from the nearest registered space, or
        (None, None). The neighbor's best metric is scaled by the
        iteration-space (MAC) ratio -- linear for latency/energy,
        quadratic for EDP -- never scaled DOWN below the neighbor's own
        best, and widened by ``seed_slack``: a conservative upper bound
        for "what should this space be able to beat"."""
        nb = self.store.nearest_space(
            parsed.cost_model, parsed.problem, parsed.arch, exclude=skey
        )
        if nb is None:
            with self._state_lock:
                self.neighbor_misses += 1
            return None, None
        nskey, dist = nb
        base = self.store.best_in_space(nskey, parsed.metric)
        meta = self.store.space_meta(nskey)
        if base is None or base <= 0.0 or meta is None:
            with self._state_lock:
                self.neighbor_misses += 1
            return None, None
        ratio = _problem_features(parsed.problem)["macs"] / max(
            float(meta.get("macs", 1.0)), 1.0
        )
        scale = ratio * ratio if parsed.metric == "edp" else ratio
        seed = base * max(scale, 1.0) * self.seed_slack
        if not math.isfinite(seed) or seed <= 0.0:
            with self._state_lock:
                self.neighbor_misses += 1
            return None, None
        with self._state_lock:
            self.neighbor_hits += 1
            self.neighbor_distance_sum += float(dist)
        return seed, {
            "skey": nskey,
            "distance": round(float(dist), 6),
            "seed": seed,
        }

    def _make_engine(self, parsed: _ParsedQuery) -> Tuple[EvaluationEngine, bool]:
        """Fresh engine for one cold search, backend gated by the
        breaker: jax only when configured AND the circuit admits it
        (closed, or this call is the half-open probe)."""
        use_jax = self.backend == "jax" and self.breaker.allow()
        engine = EvaluationEngine(
            parsed.cost_model,
            parsed.problem,
            parsed.arch,
            metric=parsed.metric,
            backend="jax" if use_jax else "numpy",
            store=self.store,
            breaker=self.breaker if self.backend == "jax" else None,
        )
        return engine, use_jax

    def _fallback_result(self, parsed: _ParsedQuery, space: MapSpace,
                         engine: EvaluationEngine, t0: float) -> SearchResult:
        """Deadline exhausted before any slice finished: score ONE
        deterministic candidate so the answer still carries an incumbent
        (flagged, never an error)."""
        with self._state_lock:
            self.fallback_answers += 1
        engine.seed_incumbent = None
        g = space.random_genome(random.Random(0))
        cost = engine.evaluate(g)
        return SearchResult(
            best_mapping=g.to_mapping(),
            best_cost=cost,
            metric=parsed.metric,
            evaluated=1,
            elapsed_s=time.monotonic() - t0,
            trajectory=[(1, cost.metric(parsed.metric))],
            considered=1,
        )

    def _search(self, parsed: _ParsedQuery) -> dict:
        with self._state_lock:
            ordinal = self._search_seq
            self._search_seq += 1
            self.searches += 1
        engine, used_jax = self._make_engine(parsed)
        ctx = engine._ctx
        prior_jax_flag = ctx._jax_failed
        if ordinal in self.fault.jaxfail:
            # same choke point run_group poisons; restored in finally so
            # the process-global context cache stays clean
            ctx._jax_failed = True
        skey = engine._store_skey
        self.store.register_space_meta(
            skey, parsed.cost_model, parsed.problem, parsed.arch
        )
        seed, seed_info = self._neighbor_seed(parsed, skey)
        if seed is not None:
            with self._state_lock:
                self.seeded += 1
        space = MapSpace(parsed.problem, parsed.arch)
        t0 = time.monotonic()
        try:
            best, exhausted = self._run_slices(
                parsed, space, engine, seed, ordinal, t0
            )
            if best is None or best.best_mapping is None:
                best = self._fallback_result(parsed, space, engine, t0)
                exhausted = True
            if (
                used_jax
                and engine.backend == "jax"
                and engine.stats.backend_fallbacks == 0
            ):
                # clean jax completion: closes a half-open probe, resets
                # the consecutive-failure count when already closed
                # (failures are recorded by the engine's breaker hook)
                self.breaker.record_success()
        finally:
            if ordinal in self.fault.jaxfail:
                ctx._jax_failed = prior_jax_flag
            engine.close()
        record = result_to_record(best)
        if not exhausted:
            # store-ahead-of-journal, the sweep executor's crash ordering:
            # scored Costs are never lost, at worst the answer is
            # re-derived warm from the store after a crash
            self.store.flush()
            self.journal.record_group(
                parsed.fingerprint, {parsed.fingerprint: record}
            )
        else:
            with self._state_lock:
                self.partials += 1
            self.store.flush()  # partial work is still real scored work
        return {
            "ok": True,
            "source": "search",
            "fingerprint": parsed.fingerprint,
            "budget_exhausted": exhausted,
            "seeded": seed is not None,
            "neighbor": seed_info,
            "backend": engine.backend,
            "record": record,
        }

    def _run_slices(self, parsed: _ParsedQuery, space: MapSpace,
                    engine: EvaluationEngine, seed: Optional[float],
                    ordinal: int, t0: float):
        """The bounded cold search: the mapper's budget in slices, each
        under the remaining deadline. Returns ``(best, exhausted)``."""
        metric = parsed.metric
        best: Optional[SearchResult] = None
        exhausted = False

        def remaining() -> Optional[float]:
            if parsed.deadline_s is None:
                return None
            return parsed.deadline_s - (time.monotonic() - t0)

        if parsed.mapper_name != "random":
            # population/structured mappers own their schedule: one shot
            # under the full deadline (partial-result slicing is the
            # random mapper's contract; see docs/mapping_service.md)
            kw = dict(parsed.mapper_kw)
            mp = MAPPER_REGISTRY[parsed.mapper_name](**kw)
            engine.seed_incumbent = seed
            slow = self.fault.slow_s(ordinal, 0)
            try:
                best = call_with_deadline(
                    lambda: (time.sleep(slow) if slow > 0 else None)
                    or mp.search(space, engine.cost_model, metric, engine=engine),
                    remaining(),
                    label=f"query{ordinal}",
                )
            except CallTimeoutError:
                return None, True
            if best is not None and best.best_mapping is None and seed is not None:
                # seed pruned everything: one unseeded retry
                with self._state_lock:
                    self.seed_misfires += 1
                engine.seed_incumbent = None
                mp = MAPPER_REGISTRY[parsed.mapper_name](**kw)
                try:
                    best = call_with_deadline(
                        lambda: mp.search(
                            space, engine.cost_model, metric, engine=engine
                        ),
                        remaining(),
                        label=f"query{ordinal}.retry",
                    )
                except CallTimeoutError:
                    return None, True
            return best, False

        kw = dict(parsed.mapper_kw)
        base_seed = int(kw.pop("seed", 0))
        kw.pop("samples", None)
        for si, size in enumerate(_slice_plan(parsed.budget)):
            rem = remaining()
            if rem is not None and rem <= 0:
                exhausted = True
                break
            slow = self.fault.slow_s(ordinal, si)
            engine.seed_incumbent = (
                best.best_metric if best is not None and best.best_mapping
                is not None else seed
            )
            mp = MAPPER_REGISTRY["random"](
                samples=size, seed=base_seed + si * _SLICE_SEED_STRIDE, **kw
            )
            try:
                res = call_with_deadline(
                    lambda mp=mp, slow=slow: (
                        time.sleep(slow) if slow > 0 else None
                    )
                    or mp.search(space, engine.cost_model, metric, engine=engine),
                    rem,
                    label=f"query{ordinal}.slice{si}",
                )
            except CallTimeoutError:
                exhausted = True
                break
            if res.best_mapping is None and engine.seed_incumbent is not None:
                # warm-start misfire: the seed bounded out every candidate
                # in this slice; re-run it unseeded (same sample stream --
                # this time candidates admit normally)
                with self._state_lock:
                    self.seed_misfires += 1
                engine.seed_incumbent = None
                mp = MAPPER_REGISTRY["random"](
                    samples=size, seed=base_seed + si * _SLICE_SEED_STRIDE,
                    **kw,
                )
                rem = remaining()
                if rem is not None and rem <= 0:
                    exhausted = True
                    break
                try:
                    res = call_with_deadline(
                        lambda mp=mp: mp.search(
                            space, engine.cost_model, metric, engine=engine
                        ),
                        rem,
                        label=f"query{ordinal}.slice{si}.retry",
                    )
                except CallTimeoutError:
                    exhausted = True
                    break
            best = _merge_results(best, res, metric)
        return best, exhausted

    # ------------------------------------------------------------- #
    def metrics(self) -> dict:
        with self._state_lock:
            m = {
                "queries": self.queries,
                "store_hits": self.store_hits,
                "searches": self.searches,
                "partials": self.partials,
                "fallback_answers": self.fallback_answers,
                "shed": self.shed,
                "errors": self.errors,
                "seeded": self.seeded,
                "seed_misfires": self.seed_misfires,
                "neighbor_hits": self.neighbor_hits,
                "neighbor_misses": self.neighbor_misses,
                "neighbor_distance_avg": round(
                    self.neighbor_distance_sum / self.neighbor_hits, 6
                ) if self.neighbor_hits else 0.0,
                "queue_depth": self.jobs.qsize(),
                "queue_cap": self.queue_cap,
                "draining": self.draining,
                "backend": self.backend,
            }
        m["breaker"] = self.breaker.stats_dict()
        m["store"] = self.store.stats_dict()
        m["journal"] = self.journal.stats_dict()
        return m


# --------------------------------------------------------------------- #
# HTTP front
# --------------------------------------------------------------------- #
class _Job:
    __slots__ = ("query", "event", "result")

    def __init__(self, query: dict) -> None:
        self.query = query
        self.event = threading.Event()
        self.result: Optional[dict] = None


def _make_handler(service: MappingService):
    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def log_message(self, fmt, *args):  # noqa: A003 - silence stdlib
            log.debug("http: " + fmt, *args)

        def _send(self, code: int, payload: dict,
                  headers: Optional[Dict[str, str]] = None) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            for k, v in (headers or {}).items():
                self.send_header(k, v)
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):  # noqa: N802 - stdlib casing
            if self.path == "/healthz":
                self._send(200, {"ok": True, "draining": service.draining})
            elif self.path == "/metrics":
                self._send(200, service.metrics())
            else:
                self._send(404, {"ok": False, "error": "not found"})

        def do_POST(self):  # noqa: N802 - stdlib casing
            if self.path != "/v1/mapping":
                self._send(404, {"ok": False, "error": "not found"})
                return
            if service.draining:
                self._send(
                    503,
                    {"ok": False, "error": "draining"},
                    {"Retry-After": "5"},
                )
                return
            try:
                n = int(self.headers.get("Content-Length", 0))
                q = json.loads(self.rfile.read(n) or b"{}")
            except Exception:
                self._send(400, {"ok": False, "error": "bad JSON body"})
                return
            job = _Job(q)
            try:
                service.jobs.put_nowait(job)
            except queue.Full:
                # explicit backpressure: shed with Retry-After instead of
                # queueing unboundedly and timing every caller out
                with service._state_lock:
                    service.shed += 1
                self._send(
                    429,
                    {
                        "ok": False,
                        "error": "admission queue full",
                        "queue_depth": service.jobs.qsize(),
                    },
                    {"Retry-After": "1"},
                )
                return
            # generous wall-clock guard: the worker enforces the real
            # per-query deadline and ALWAYS sets the event
            wait_s = (service.default_deadline_s or 30.0) * 4 + 60.0
            if not job.event.wait(wait_s):
                self._send(504, {"ok": False, "error": "worker stalled"})
                return
            env = job.result or {"ok": False, "error": "no result"}
            self._send(200 if env.get("ok") else 400, env)

    return Handler


def serve(service: MappingService, host: str = "127.0.0.1", port: int = 0):
    """Bind the HTTP front and start the worker pool; returns the
    (already listening, not yet serving) server -- call
    ``serve_forever`` on it (typically in a thread)."""
    httpd = ThreadingHTTPServer((host, port), _make_handler(service))
    httpd.daemon_threads = True
    service.start_workers()
    return httpd


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="mapping-as-a-service daemon (docs/mapping_service.md)"
    )
    ap.add_argument("--state-dir", required=True,
                    help="ResultStore + answer-journal directory")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks an ephemeral port (see --ready-file)")
    ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"))
    ap.add_argument("--deadline-s", type=float, default=5.0,
                    help="default per-query deadline (<=0 disables)")
    ap.add_argument("--queue-cap", type=int, default=8)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--store-cap", type=int, default=None)
    ap.add_argument("--breaker-threshold", type=int, default=2)
    ap.add_argument("--probe-interval", type=int, default=2)
    ap.add_argument("--fault-spec", default=None,
                    help="overrides UNION_FAULT_SPEC (jaxfail:Q / slow:Q@K:S)")
    ap.add_argument("--ready-file", default=None,
                    help="write {port, pid} JSON here once listening")
    args = ap.parse_args(argv)

    logging.basicConfig(
        level=logging.INFO,
        format="%(asctime)s %(name)s %(levelname)s %(message)s",
    )
    service = MappingService(
        args.state_dir,
        backend=args.backend,
        deadline_s=args.deadline_s if args.deadline_s > 0 else None,
        queue_cap=args.queue_cap,
        workers=args.workers,
        store_cap=args.store_cap,
        breaker_threshold=args.breaker_threshold,
        probe_interval=args.probe_interval,
        fault_spec=args.fault_spec,
    )
    httpd = serve(service, args.host, args.port)
    host, port = httpd.server_address[:2]
    log.info("mapping service listening on %s:%d (state %s)",
             host, port, args.state_dir)
    stop = threading.Event()

    def on_signal(signum, frame):  # noqa: ARG001
        log.warning("signal %d: draining", signum)
        stop.set()

    signal.signal(signal.SIGTERM, on_signal)
    signal.signal(signal.SIGINT, on_signal)

    # handlers are live before the ready file appears: a supervisor that
    # signals the instant it sees readiness still gets the graceful drain
    if args.ready_file:
        tmp = args.ready_file + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"port": port, "pid": os.getpid()}, f)
        os.replace(tmp, args.ready_file)

    th = threading.Thread(target=httpd.serve_forever, daemon=True,
                          name="mapsvc-http")
    th.start()
    stop.wait()
    # graceful drain: reject new queries, answer + journal everything
    # already admitted, flush, exit 0 -- a SIGKILL instead of this path
    # loses at most the in-flight search (re-run warm after restart),
    # never a journaled answer
    service.draining = True
    service.drain()
    httpd.shutdown()
    th.join(timeout=5.0)
    log.info("drained; final metrics: %s", json.dumps(service.metrics()))
    return 0


if __name__ == "__main__":
    sys.exit(main())
