"""Serving-tier daemons built on the search stack.

``mapping_service`` is the mapping-as-a-service daemon: mapping queries
(problem, arch, metric, mapper, budget) answered from the persistent
:class:`~repro.core.cost.store.ResultStore` + answer journal in O(ms)
when warm, bounded deadline-enforced search on miss. See
``docs/mapping_service.md``.
"""

from repro.serve.mapping_service import (  # noqa: F401
    MappingService,
    QueryError,
    query_fingerprint,
)
