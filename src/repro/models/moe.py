"""Mixture-of-Experts FFN: shared + routed experts, top-k gating,
capacity-based dispatch (GShard/Switch-style) with load-balance aux loss.

Dispatch is index-based (cumsum positions + scatter into an (E, C, d)
buffer) rather than a dense (T, E, C) one-hot einsum, so the biggest
intermediate is (T, E) -- this is what keeps the 1M-token train_4k cells
compilable. Experts carry a leading E axis so expert parallelism is plain
GSPMD sharding of that axis over the 'model' mesh axis.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import DTYPE, act_fn, dense, init_dense
from repro.sharding.hints import shard_hint

Params = Dict[str, jnp.ndarray]


def init_moe(key, cfg: ModelConfig) -> Params:
    d, e, de = cfg.d_model, cfg.n_routed_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": init_dense(ks[0], d, e),
        # routed experts: stacked (E, d, de) / (E, de, d)
        "w_gate": (jax.random.normal(ks[1], (e, d, de), jnp.float32) * scale).astype(DTYPE),
        "w_up": (jax.random.normal(ks[2], (e, d, de), jnp.float32) * scale).astype(DTYPE),
        "w_down": (jax.random.normal(ks[3], (e, de, d), jnp.float32) / math.sqrt(de)).astype(DTYPE),
    }
    if cfg.n_shared_experts:
        dsh = cfg.n_shared_experts * de
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": init_dense(kss[0], d, dsh),
            "up": init_dense(kss[1], d, dsh),
            "down": init_dense(kss[2], dsh, d),
        }
    return p


def moe_apply(
    p: Params, cfg: ModelConfig, x: jnp.ndarray, *, dropless: bool = False
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (y, aux_loss).

    ``dropless=True`` sizes the expert buffers at T*k so no assignment is
    ever dropped -- the decode/serving path uses this (capacity dropping
    is a training-throughput tradeoff; dropping tokens at decode would
    corrupt generation)."""
    b, s, d = x.shape
    e, k = cfg.n_routed_experts, cfg.top_k
    T = b * s
    xt = x.reshape(T, d)
    logits = dense(p["router"], xt).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch): E * sum_e f_e * P_e ----- #
    onehot_top1 = jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32)
    f_e = jnp.mean(onehot_top1, axis=0)
    P_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * P_e) * cfg.router_aux_coef

    # ---- capacity-based dispatch -------------------------------------- #
    if dropless:
        C = T * k
    else:
        C = max(1, int(math.ceil(T * k * cfg.capacity_factor / e)))
    flat_e = eidx.reshape(T * k)  # expert of each assignment (row-major: all
    flat_g = gate_vals.reshape(T * k)  # k slots of token 0, then token 1, ...)
    tok_of = jnp.repeat(jnp.arange(T), k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = slot < C
    slot_c = jnp.where(keep, slot, C)  # overflow -> parked slot C (dropped)

    buf = jnp.zeros((e, C + 1, d), x.dtype)
    buf = buf.at[flat_e, slot_c].add(xt[tok_of])
    buf = buf[:, :C]  # (E, C, d)
    buf = shard_hint(buf, "tp", None, None)  # expert-parallel dispatch buffer

    # ---- expert computation (EP-shardable einsums over leading E) ------ #
    f = act_fn(cfg.act)
    h = f(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_up"]
    )
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E, C, d)

    # ---- combine -------------------------------------------------------- #
    out_padded = jnp.concatenate([out, jnp.zeros((e, 1, d), out.dtype)], axis=1)
    gathered = out_padded[flat_e, slot_c]  # (T*k, d); parked slot reads zeros
    weighted = gathered * (flat_g * keep.astype(jnp.float32)).astype(gathered.dtype)[:, None]
    y = jnp.zeros((T, d), x.dtype).at[tok_of].add(weighted)

    if "shared" in p:
        sh = p["shared"]
        y = y + dense(sh["down"], f(dense(sh["gate"], xt)) * dense(sh["up"], xt))
    return y.reshape(b, s, d), aux
