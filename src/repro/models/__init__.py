"""Model substrate: composable JAX model definitions for all assigned
architecture families (dense / moe / hybrid / ssm / vlm / audio)."""

from repro.models.model import (  # noqa: F401
    init_params,
    forward,
    loss_fn,
    init_cache,
    decode_step,
)
