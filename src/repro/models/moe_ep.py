"""Expert-parallel MoE via shard_map + all-to-all (the SPerf MoE hillclimb).

WHY: the baseline moe_apply relies on GSPMD to shard the dispatch
scatter/gather. GSPMD cannot reason about data-dependent scatters onto an
expert-sharded buffer, so it REPLICATES the dispatch buffer and the expert
einsums on every chip -- the dry-run measured ~50-100x the active FLOPs on
the MoE cells (EXPERIMENTS.md SPerf). The production pattern -- explicit
all-to-all between token-sharded and expert-sharded layouts -- cannot be
expressed as sharding constraints; it needs per-device code. This module
is that pattern in jax-native form (shard_map + lax.all_to_all), exactly
the "map the paper's communication pattern onto jax constructs" adaptation
called for in DESIGN.md.

Layout contract (matches the activation sharding the launcher installs):
  tokens: batch over the dp axes, sequence over the tp axis
  experts: padded to a multiple of tp_n, sharded over the tp axis
Per device: route local tokens -> bucket by owning device (fixed capacity)
-> all_to_all -> local-expert capacity dispatch -> compute -> all_to_all
back -> gate-weighted combine. Empty slots carry zeros and are harmless
(gateless SwiGLU maps 0 -> 0). Capacity drops occur (a) into each
destination bucket and (b) within the owner's local dispatch -- same
semantics class as the baseline's single capacity rule.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import act_fn, dense
from repro.sharding import hints as hints_mod

Params = Dict[str, jnp.ndarray]


def ep_available(cfg: ModelConfig, x: jnp.ndarray) -> bool:
    st = hints_mod._STATE
    if not (st.get("enabled") and st.get("tp") and st.get("mesh") is not None):
        return False
    sizes = st["sizes"]
    tp_n = sizes.get(st["tp"], 1)
    dp = st.get("dp") or ()
    dp_n = math.prod(sizes.get(a, 1) for a in (dp if isinstance(dp, tuple) else (dp,)))
    b, s, _ = x.shape
    return tp_n > 1 and b % max(1, dp_n) == 0 and s % tp_n == 0


def _capacity_dispatch(xt, eids, n_buckets, cap):
    """Assign slot-within-bucket for each row; returns (buf, slot, keep).

    xt: (N, d) rows; eids: (N,) bucket ids. buf: (n_buckets, cap, d);
    overflow rows park at slot==cap (dropped).
    """
    N, d = xt.shape
    onehot = jax.nn.one_hot(eids, n_buckets, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, eids[:, None], axis=1)[:, 0]
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap)
    buf = jnp.zeros((n_buckets, cap + 1, d), xt.dtype)
    buf = buf.at[eids, slot_c].add(xt)
    return buf[:, :cap], slot_c, keep


def moe_apply_ep(
    p: Params, cfg: ModelConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in for moe_apply (same params pytree, same (y, aux) contract)."""
    st = hints_mod._STATE
    mesh = st["mesh"]
    tp = st["tp"]
    sizes = st["sizes"]
    tp_n = sizes[tp]
    dp = st.get("dp") or ()
    dp = dp if isinstance(dp, tuple) else (dp,)
    all_axes = tuple(a for a in mesh.axis_names)

    e, k = cfg.n_routed_experts, cfg.top_k
    e_pad = (e + tp_n - 1) // tp_n * tp_n
    e_loc = e_pad // tp_n
    b, s, d = x.shape
    f = act_fn(cfg.act)

    # pad the expert banks so E divides the tp axis (extra experts receive
    # -inf router logits and therefore no tokens)
    def pad_e(w):
        return jnp.pad(w, ((0, e_pad - e),) + ((0, 0),) * (w.ndim - 1))

    wg, wu, wd = pad_e(p["w_gate"]), pad_e(p["w_up"]), pad_e(p["w_down"])
    wr = p["router"]["w"]

    T_loc = (b * s) // (math.prod(sizes.get(a, 1) for a in dp) * tp_n)
    cap_send = max(1, int(math.ceil(T_loc * k * cfg.capacity_factor / tp_n)))
    cap_own = max(1, int(math.ceil(tp_n * cap_send * cfg.capacity_factor / e_loc)))

    def body(x_blk, wr_, wg_, wu_, wd_):
        b_l, s_l, _ = x_blk.shape
        T = b_l * s_l
        xt = x_blk.reshape(T, d)
        logits = (xt @ wr_).astype(jnp.float32)  # (T, e) real experts only
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eidx = jax.lax.top_k(probs, k)  # (T, k) over REAL experts
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

        # aux loss over the GLOBAL batch (pmean across every mesh axis)
        onehot_top1 = jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32)
        f_e = jnp.mean(onehot_top1, axis=0)
        P_e = jnp.mean(probs, axis=0)
        for ax in all_axes:
            f_e = jax.lax.pmean(f_e, ax)
            P_e = jax.lax.pmean(P_e, ax)
        aux = e * jnp.sum(f_e * P_e) * cfg.router_aux_coef

        flat_e = eidx.reshape(T * k)
        flat_g = gates.reshape(T * k)
        tok_of = jnp.repeat(jnp.arange(T), k)
        dest = flat_e // e_loc  # owning device along tp
        local_e = flat_e % e_loc

        # bucket rows by destination device (capacity cap_send each)
        send_x, slot1, keep1 = _capacity_dispatch(
            xt[tok_of], dest, tp_n, cap_send
        )
        # ship the local-expert id per slot the same way (as f32 payload)
        ebuf = jnp.zeros((tp_n, cap_send + 1), jnp.int32)
        ebuf = ebuf.at[dest, slot1].max(
            jnp.where(keep1, local_e, 0).astype(jnp.int32)
        )
        send_e = ebuf[:, :cap_send]

        recv_x = jax.lax.all_to_all(send_x, tp, 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, tp, 0, 0, tiled=False)
        T_r = tp_n * cap_send
        rx = recv_x.reshape(T_r, d)
        re = recv_e.reshape(T_r)

        # local-expert capacity dispatch + expert FFNs
        buf, slot2, keep2 = _capacity_dispatch(rx, re, e_loc, cap_own)
        h = f(jnp.einsum("ecd,edf->ecf", buf, wg_)) * jnp.einsum(
            "ecd,edf->ecf", buf, wu_
        )
        out = jnp.einsum("ecf,efd->ecd", h, wd_)  # (e_loc, cap_own, d)

        # route results back to the original rows
        out_pad = jnp.concatenate(
            [out, jnp.zeros((e_loc, 1, d), out.dtype)], axis=1
        )
        back = out_pad[re, slot2]  # (T_r, d); dropped rows read zeros
        back = back.reshape(tp_n, cap_send, d)
        ret = jax.lax.all_to_all(back, tp, 0, 0, tiled=False)
        ret_pad = jnp.concatenate(
            [ret, jnp.zeros((tp_n, 1, d), ret.dtype)], axis=1
        )
        vals = ret_pad[dest, slot1]  # (T*k, d); parked slots read zeros
        w = (flat_g * keep1.astype(jnp.float32)).astype(vals.dtype)
        y = jnp.zeros((T, d), x_blk.dtype).at[tok_of].add(vals * w[:, None])
        return y.reshape(b_l, s_l, d), aux

    x_spec = P(dp if dp else None, tp, None)
    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), P(tp, None, None),
                  P(tp, None, None), P(tp, None, None)),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, wr, wg, wu, wd)

    # shared experts: plain dense compute outside the shard_map (token-
    # sharded GEMMs that GSPMD handles well)
    if "shared" in p:
        sh = p["shared"]
        xt = x.reshape(b * s, d)
        y = y + dense(sh["down"], f(dense(sh["gate"], xt)) * dense(sh["up"], xt)).reshape(b, s, d)
    return y, aux
