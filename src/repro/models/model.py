"""Model assembly: embedding/frontends + repeating-unit block stack + head.

Layers are stacked per *repeating unit* (cfg.block_pattern) and iterated
with ``jax.lax.scan`` over stacked parameters, so the HLO contains ONE
copy of the unit regardless of depth -- this is what keeps 80-layer
dry-run compiles tractable and is also the production-correct structure
for pipelining. ``first_k_dense`` prefix layers (DeepSeek) live outside
the scan.

Public API:
  init_params(cfg, key)                 -> params pytree (eval_shape-able)
  forward(cfg, params, batch)           -> (logits, aux_loss)
  loss_fn(cfg, params, batch)           -> scalar loss
  init_cache(cfg, batch, max_len)       -> decode cache pytree
  decode_step(cfg, params, cache, tok, pos) -> (logits, new_cache)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.sharding.hints import shard_hint
from repro.models.layers import (
    DTYPE,
    attention_apply,
    dense,
    init_attention,
    init_dense,
    init_mla,
    init_mlp,
    mla_apply,
    mlp_apply,
    rms_norm,
)

Params = Dict

# Scan-unroll knob for the unit stack. Production leaves this at 1 (one
# HLO copy of the unit; compile time O(1) in depth). The dry-run's
# structure-corrected cost pass sets it to the unit count on SMALL unit
# counts so ``compiled.cost_analysis()`` -- which counts a while-loop body
# ONCE, not x trip-count -- sees every unit (see launch/dryrun.py).
_SCAN_UNROLL = 1


def set_scan_unroll(n: int) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = max(1, int(n))


# ===================================================================== #
# init
# ===================================================================== #
def _init_block(key, cfg: ModelConfig, kind: str, moe_ffn: bool) -> Params:
    ks = jax.random.split(key, 4)
    if kind == "attn":
        p: Params = {"ln1": jnp.ones((cfg.d_model,), DTYPE)}
        p["attn"] = init_mla(ks[0], cfg) if cfg.use_mla else init_attention(ks[0], cfg)
        if cfg.d_ff or moe_ffn:
            p["ln2"] = jnp.ones((cfg.d_model,), DTYPE)
            if moe_ffn:
                p["moe"] = moe_mod.init_moe(ks[1], cfg)
            else:
                p["ffn"] = init_mlp(ks[1], cfg)
        return p
    if kind == "mamba2":
        return {"ln": jnp.ones((cfg.d_model,), DTYPE), "core": ssm_mod.init_mamba2(ks[0], cfg)}
    if kind == "mlstm":
        return {"ln": jnp.ones((cfg.d_model,), DTYPE), "core": ssm_mod.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"ln": jnp.ones((cfg.d_model,), DTYPE), "core": ssm_mod.init_slstm(ks[0], cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def _unit_moe(cfg: ModelConfig) -> bool:
    return cfg.n_routed_experts > 0


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    params: Params = {}
    d = cfg.d_model
    if cfg.frontend == "audio_stub":
        params["frontend_proj"] = init_dense(keys[0], cfg.d_frontend, d)
    else:
        params["embed"] = (
            jax.random.normal(keys[0], (cfg.vocab, d), jnp.float32) * 0.02
        ).astype(DTYPE)
        if cfg.frontend == "vision_stub":
            k1, k2 = jax.random.split(keys[1])
            params["frontend_proj"] = {
                "l1": init_dense(k1, cfg.d_frontend, d),
                "l2": init_dense(k2, d, d),
            }
    # prefix (dense) layers outside the scan
    n_prefix = cfg.first_k_dense
    if n_prefix:
        pks = jax.random.split(keys[2], n_prefix)
        params["prefix"] = [
            _init_block(pks[i], cfg, "attn", moe_ffn=False) for i in range(n_prefix)
        ]
    # scanned units
    n_scanned = cfg.n_layers - n_prefix
    assert n_scanned % len(cfg.block_pattern) == 0
    n_units = n_scanned // len(cfg.block_pattern)

    def init_unit(k):
        uks = jax.random.split(k, len(cfg.block_pattern))
        return {
            f"b{i}": _init_block(uks[i], cfg, kind, moe_ffn=_unit_moe(cfg))
            for i, kind in enumerate(cfg.block_pattern)
        }

    params["units"] = jax.vmap(init_unit)(jax.random.split(keys[3], n_units))
    params["final_norm"] = jnp.ones((d,), DTYPE)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[4], d, cfg.vocab)
    return params


# ===================================================================== #
# block application
# ===================================================================== #
def apply_block(
    cfg: ModelConfig,
    kind: str,
    p: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[Params],
    cache_len,
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        fn = mla_apply if cfg.use_mla else attention_apply
        a, new_cache = fn(p["attn"], cfg, h, positions, cache, cache_len)
        x = x + checkpoint_name(a, "block_out")
        if "moe" in p:
            h2 = rms_norm(x, p["ln2"], cfg.rms_eps)
            from repro.models import moe_ep
            from repro.sharding import hints as _h
            if (cache is None and _h._STATE.get("ep_shardmap")
                    and moe_ep.ep_available(cfg, h2)):
                f, aux = moe_ep.moe_apply_ep(p["moe"], cfg, h2)
            else:
                # decode (cache present) routes droplessly: capacity dropping
                # is a training-throughput tradeoff, not a serving behavior
                f, aux = moe_mod.moe_apply(p["moe"], cfg, h2,
                                           dropless=cache is not None)
            x = x + checkpoint_name(f, "block_out")
        elif "ffn" in p:
            h2 = rms_norm(x, p["ln2"], cfg.rms_eps)
            x = x + checkpoint_name(mlp_apply(p["ffn"], cfg, h2), "block_out")
        return x, new_cache, aux
    h = rms_norm(x, p["ln"], cfg.rms_eps)
    if kind == "mamba2":
        y, new_cache = ssm_mod.mamba2_apply(p["core"], cfg, h, cache)
    elif kind == "mlstm":
        y, new_cache = ssm_mod.mlstm_apply(p["core"], cfg, h, cache)
    elif kind == "slstm":
        y, new_cache = ssm_mod.slstm_apply(p["core"], cfg, h, cache)
    else:
        raise ValueError(kind)
    return x + checkpoint_name(y, "block_out"), new_cache, aux


# ===================================================================== #
# embedding / frontends
# ===================================================================== #
def embed_inputs(cfg: ModelConfig, params: Params, batch: Dict) -> Tuple[jnp.ndarray, int]:
    """Returns (x, text_start): x (b, S, d); text_start = index where text
    tokens begin (for VLM loss masking)."""
    if cfg.frontend == "audio_stub":
        x = dense(params["frontend_proj"], batch["frames"].astype(DTYPE))
        return x, 0
    tok = params["embed"][batch["tokens"]]  # (b, s_text, d)
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        fp = params["frontend_proj"]
        img = dense(fp["l2"], jax.nn.gelu(dense(fp["l1"], batch["patch_embeds"].astype(DTYPE))))
        x = jnp.concatenate([img, tok], axis=1)
        return x, img.shape[1]
    return tok, 0


def lm_logits(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = dense(params["lm_head"], x)
    # keep the vocab dim model-sharded: the single biggest activation
    return shard_hint(logits, "dp", None, "tp")


# ===================================================================== #
# forward / loss
# ===================================================================== #
_REMAT_POLICIES = {
    # full remat: save only the scan carry; bwd re-runs the whole unit
    # forward INCLUDING its TP collectives
    "full": None,
    # save each block's residual contribution (the all-reduced tensors):
    # bwd recompute re-runs matmuls but NOT the collectives that produced
    # the saved outputs -- the SPerf 110B hillclimb. Costs 2 x (tokens x d)
    # bf16 per unit of saved activations.
    "save_block_outputs": "save_block_outputs",
}


def forward(
    cfg: ModelConfig,
    params: Params,
    batch: Dict,
    *,
    remat: bool = True,
    remat_policy: str = "full",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x, _ = embed_inputs(cfg, params, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    aux = jnp.zeros((), jnp.float32)
    x = shard_hint(x, "dp", "sp", None)
    for blk in params.get("prefix", []):
        x, _, a = apply_block(cfg, "attn", blk, x, positions, None, None)
        aux = aux + a

    def unit_fn(carry, unit_params):
        x, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            x, _, a = apply_block(cfg, kind, unit_params[f"b{i}"], x, positions, None, None)
            x = shard_hint(x, "dp", "sp", None)
            aux = aux + a
        return (x, aux), None

    if remat and remat_policy == "save_block_outputs":
        from jax.ad_checkpoint import checkpoint_policies as _cp

        body = jax.checkpoint(
            unit_fn, policy=_cp.save_only_these_names("block_out")
        )
    elif remat:
        body = jax.checkpoint(unit_fn)
    else:
        body = unit_fn
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["units"], unroll=_SCAN_UNROLL)
    return lm_logits(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict, *, remat: bool = True,
            remat_policy: str = "full") -> jnp.ndarray:
    logits, aux = forward(cfg, params, batch, remat=remat, remat_policy=remat_policy)
    if cfg.frontend == "audio_stub" or cfg.encoder_only:
        labels = batch["labels"]
        lg = logits
    else:
        x0 = logits.shape[1] - batch["tokens"].shape[1]  # text start (VLM prefix)
        lg = logits[:, x0:-1]
        labels = batch["tokens"][:, 1:]
    lg32 = shard_hint(lg.astype(jnp.float32), "dp", None, "tp")
    lse = jax.scipy.special.logsumexp(lg32, axis=-1)
    tgt = jnp.take_along_axis(lg32, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - tgt) + aux


# ===================================================================== #
# decode
# ===================================================================== #
def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> Params:
    if kind == "attn":
        if cfg.use_mla:
            return {
                "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), DTYPE),
                "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), DTYPE),
            }
        return {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), DTYPE),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), DTYPE),
        }
    if kind == "mamba2":
        return ssm_mod.init_mamba2_cache(cfg, batch)
    if kind == "mlstm":
        return ssm_mod.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return ssm_mod.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    n_prefix = cfg.first_k_dense
    n_units = (cfg.n_layers - n_prefix) // len(cfg.block_pattern)
    unit_cache = {
        f"b{i}": _init_block_cache(cfg, kind, batch, max_len)
        for i, kind in enumerate(cfg.block_pattern)
    }
    cache: Params = {
        # stack per-unit caches PRESERVING init values: recurrent caches are
        # not all-zero (the m-stabilizers of sLSTM/mLSTM start at -1e30, and
        # zeroing them silently shifts the exp-gating floor)
        "units": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_units,) + a.shape).astype(a.dtype),
            unit_cache,
        )
    }
    if n_prefix:
        cache["prefix"] = [
            _init_block_cache(cfg, "attn", batch, max_len) for _ in range(n_prefix)
        ]
    return cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Params,
    tokens: jnp.ndarray,  # (b, 1) int32
    pos,  # scalar int32: number of tokens already in the cache
) -> Tuple[jnp.ndarray, Params]:
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    x = params["embed"][tokens]
    positions = pos + jnp.arange(1)
    new_cache: Params = {}
    if "prefix" in cache:
        new_prefix = []
        for blk, c in zip(params["prefix"], cache["prefix"]):
            x, nc, _ = apply_block(cfg, "attn", blk, x, positions, c, pos)
            new_prefix.append(nc)
        new_cache["prefix"] = new_prefix

    def unit_fn(x, pu_cu):
        pu, cu = pu_cu
        ncs = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, nc, _ = apply_block(cfg, kind, pu[f"b{i}"], x, positions, cu[f"b{i}"], pos)
            ncs[f"b{i}"] = nc
        return x, ncs

    x, new_units = jax.lax.scan(
        unit_fn, x, (params["units"], cache["units"]), unroll=_SCAN_UNROLL
    )
    new_cache["units"] = new_units
    logits = lm_logits(cfg, params, x)
    return logits[:, 0], new_cache
