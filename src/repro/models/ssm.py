"""SSM / recurrent blocks: Mamba-2 (SSD), mLSTM and sLSTM (xLSTM).

Training uses chunkwise-parallel forms (O(L) in sequence, MXU-friendly
intra-chunk einsums); decoding uses O(1)-state recurrent steps. The
chunkwise SSD intra-chunk contraction is the Pallas kernel target
(repro/kernels/ssd_scan); this module is the pure-jnp reference path that
the kernel is validated against, and the default path on CPU.

Stability notes: all gate math is f32; mLSTM uses the xLSTM exponential-
gating stabilizer (carried max-state m) in its chunkwise form, and the
property tests check chunked == recurrent.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import DTYPE, dense, init_dense, rms_norm

Params = Dict[str, jnp.ndarray]


# ===================================================================== #
# shared helpers
# ===================================================================== #
def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (j<=i).

    x: (..., L) -> (..., L, L) lower-triangular log-decay matrix.
    """
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, L, C), w: (W, C), b: (C,)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def conv_step(conv_state: jnp.ndarray, x_t: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """One causal-conv step. conv_state: (B, W-1, C); x_t: (B, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window, w) + b
    return window[:, 1:, :], y


# ===================================================================== #
# Mamba-2 (SSD)
# ===================================================================== #
def init_mamba2(key, cfg: ModelConfig) -> Params:
    """Projections are kept SEPARATE (z / x / B / C / dt) rather than one
    fused in_proj so each piece has a clean GSPMD sharding: x/z column-
    sharded over 'model' (head-parallel), B/C replicated (shared across
    heads within a group), dt head-sharded."""
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 8)
    return {
        "in_z": init_dense(ks[0], d, di),
        "in_x": init_dense(ks[1], d, di),
        "in_B": init_dense(ks[2], d, g * n),
        "in_C": init_dense(ks[3], d, g * n),
        "in_dt": init_dense(ks[4], d, nh),
        "conv_x_w": (jax.random.normal(ks[5], (cfg.conv_width, di), jnp.float32) * 0.1).astype(DTYPE),
        "conv_x_b": jnp.zeros((di,), DTYPE),
        "conv_B_w": (jax.random.normal(ks[6], (cfg.conv_width, g * n), jnp.float32) * 0.1).astype(DTYPE),
        "conv_B_b": jnp.zeros((g * n,), DTYPE),
        "conv_C_w": (jax.random.normal(ks[7], (cfg.conv_width, g * n), jnp.float32) * 0.1).astype(DTYPE),
        "conv_C_b": jnp.zeros((g * n,), DTYPE),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": jnp.ones((di,), DTYPE),
        "out_proj": init_dense(ks[0], di, d),
    }


def _ssd_chunked(
    x: jnp.ndarray,  # (b, l, nh, hp)  (already includes dt scaling)
    dA: jnp.ndarray,  # (b, l, nh)      log decay per step (<= 0)
    B: jnp.ndarray,  # (b, l, nh, n)
    C: jnp.ndarray,  # (b, l, nh, n)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # (b, nh, hp, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunkwise SSD (Mamba-2 minimal). Returns (y, final_state)."""
    b, l, nh, hp = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, f"seq {l} % chunk {chunk} != 0"
    nc = l // chunk
    xr = x.reshape(b, nc, chunk, nh, hp).astype(jnp.float32)
    dAr = dA.reshape(b, nc, chunk, nh).astype(jnp.float32)
    Br = B.reshape(b, nc, chunk, nh, n).astype(jnp.float32)
    Cr = C.reshape(b, nc, chunk, nh, n).astype(jnp.float32)

    # intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(dAr.transpose(0, 1, 3, 2)))  # (b, nc, nh, cl, cl)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cr, Br)
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp", scores, Lmat, xr.transpose(0, 1, 2, 3, 4))

    # chunk-final states: S_c = sum_j exp(cum_end - cum_j) B_j x_j^T
    cum = jnp.cumsum(dAr, axis=2)  # (b, nc, cl, nh)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (b, nc, cl, nh)
    S_c = jnp.einsum("bcshn,bcsh,bcshp->bchpn", Br, decay_to_end, xr)

    # inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b, nc, nh)
    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, nh, hp, n), jnp.float32)
    )

    def step(carry, inp):
        S_prev = carry
        S_new, dec = inp  # (b, nh, hp, n), (b, nh)
        S_next = S_prev * dec[:, :, None, None] + S_new
        return S_next, S_prev  # emit the state ENTERING this chunk

    xs = (S_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    final, S_in = jax.lax.scan(step, s0, xs)
    S_in = S_in.transpose(1, 0, 2, 3, 4)  # (b, nc, nh, hp, n)

    # inter-chunk contribution: y_off_i = (C_i . S_in) * exp(cum_i)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cr, S_in, jnp.exp(cum))
    y = (y_diag + y_off).reshape(b, l, nh, hp)
    return y, final


def mamba2_apply(
    p: Params,
    cfg: ModelConfig,
    u: jnp.ndarray,  # (b, L, d)
    cache: Optional[Params] = None,  # {"conv": (b,W-1,convdim), "state": (b,nh,hp,n)}
    chunk: int = 256,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, L, d = u.shape
    di, g, n, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    hp = cfg.ssm_head_dim
    z = dense(p["in_z"], u)
    xs_r = dense(p["in_x"], u)
    B_r = dense(p["in_B"], u)
    C_r = dense(p["in_C"], u)
    dt_raw = dense(p["in_dt"], u)
    A = -jnp.exp(p["A_log"])  # (nh,)

    new_cache = None
    if cache is None:
        xs = jax.nn.silu(causal_conv1d(xs_r, p["conv_x_w"], p["conv_x_b"]))
        B = jax.nn.silu(causal_conv1d(B_r, p["conv_B_w"], p["conv_B_b"]))
        C = jax.nn.silu(causal_conv1d(C_r, p["conv_C_w"], p["conv_C_b"]))
        xh = xs.reshape(b, L, nh, hp)
        Bh = jnp.repeat(B.reshape(b, L, g, n), nh // g, axis=2)
        Ch = jnp.repeat(C.reshape(b, L, g, n), nh // g, axis=2)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b,L,nh)
        from repro import kernels as _k
        if _k.pallas_enabled():
            from repro.kernels.ssd_scan import ssd_chunked as _ssd_fast
            y, _ = _ssd_fast(
                xh.astype(jnp.float32) * dt[..., None], dt * A, Bh, Ch,
                chunk=min(chunk, L),
            )
        else:
            y, _ = _ssd_chunked(
                xh.astype(jnp.float32) * dt[..., None], dt * A, Bh, Ch,
                chunk=min(chunk, L),
            )
        y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    else:
        # single-token recurrent step; L == 1
        conv_x, x_t = conv_step(cache["conv_x"], xs_r[:, 0], p["conv_x_w"], p["conv_x_b"])
        conv_B, B_t = conv_step(cache["conv_B"], B_r[:, 0], p["conv_B_w"], p["conv_B_b"])
        conv_C, C_t = conv_step(cache["conv_C"], C_r[:, 0], p["conv_C_w"], p["conv_C_b"])
        x_t, B_t, C_t = jax.nn.silu(x_t), jax.nn.silu(B_t), jax.nn.silu(C_t)
        xh = x_t.reshape(b, nh, hp).astype(jnp.float32)
        Bh = jnp.repeat(B_t.reshape(b, g, n), nh // g, axis=1).astype(jnp.float32)
        Ch = jnp.repeat(C_t.reshape(b, g, n), nh // g, axis=1).astype(jnp.float32)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,nh)
        dA = jnp.exp(dt * A)  # (b,nh)
        state = cache["state"] * dA[:, :, None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt, xh, Bh
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + xh * p["D"][None, :, None]
        y = y[:, None]  # (b, 1, nh, hp)
        new_cache = {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "state": state}
    # gated RMSNorm + out projection
    y = y.reshape(b, L, di).astype(u.dtype)
    y = rms_norm(y, p["gate_norm"], cfg.rms_eps) * jax.nn.silu(z)
    return dense(p["out_proj"], y), new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int) -> Params:
    di, g, n, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    return {
        "conv_x": jnp.zeros((batch, cfg.conv_width - 1, di), DTYPE),
        "conv_B": jnp.zeros((batch, cfg.conv_width - 1, g * n), DTYPE),
        "conv_C": jnp.zeros((batch, cfg.conv_width - 1, g * n), DTYPE),
        "state": jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32),
    }


# ===================================================================== #
# mLSTM (xLSTM): matrix memory with exponential gating
# ===================================================================== #
def init_mlstm(key, cfg: ModelConfig) -> Params:
    d, di, nh = cfg.d_model, cfg.d_inner, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": init_dense(ks[0], d, 2 * di),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di), jnp.float32) * 0.1).astype(DTYPE),
        "conv_b": jnp.zeros((di,), DTYPE),
        "wq": init_dense(ks[2], di, di),
        "wk": init_dense(ks[3], di, di),
        "wv": init_dense(ks[4], di, di),
        "w_i": init_dense(ks[5], d, nh, bias=True),
        "w_f": init_dense(ks[6], d, nh, bias=True),
        "out_norm": jnp.ones((di,), DTYPE),
        "down": init_dense(ks[7], di, d),
    }


def _mlstm_chunked(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,  # (b, l, nh, dh)
    ilog: jnp.ndarray, flog: jnp.ndarray,  # (b, l, nh) raw i, log-sigmoid f
    chunk: int,
    init: Optional[Tuple] = None,  # (Cst, nst, m)
) -> Tuple[jnp.ndarray, Tuple]:
    b, l, nh, dh = q.shape
    nc = l // chunk
    sc = 1.0 / math.sqrt(dh)
    qr = (q.astype(jnp.float32) * sc).reshape(b, nc, chunk, nh, dh)
    kr = k.astype(jnp.float32).reshape(b, nc, chunk, nh, dh)
    vr = v.astype(jnp.float32).reshape(b, nc, chunk, nh, dh)
    ir = ilog.astype(jnp.float32).reshape(b, nc, chunk, nh)
    fr = flog.astype(jnp.float32).reshape(b, nc, chunk, nh)
    cf = jnp.cumsum(fr, axis=2)  # inclusive cumulative log-forget
    if init is None:
        Cst = jnp.zeros((b, nh, dh, dh), jnp.float32)
        nst = jnp.zeros((b, nh, dh), jnp.float32)
        mst = jnp.full((b, nh), -jnp.inf, jnp.float32)
    else:
        Cst, nst, mst = init

    # intra-chunk log weights: D[i,j] = cf_i - cf_j + ilog_j (j<=i)
    Dmat = _segsum(fr.transpose(0, 1, 3, 2)) + ir.transpose(0, 1, 3, 2)[:, :, :, None, :]
    # (b, nc, nh, cl, cl); -inf above diagonal
    m_intra = jnp.max(Dmat, axis=-1)  # (b, nc, nh, cl)

    def step(carry, inp):
        C_prev, n_prev, m_prev = carry
        qc, kc, vc, cfc, irc, Dm, m_in = inp
        # per-position stabilizer
        b_i = cfc.transpose(0, 2, 1) + m_prev[:, :, None]  # (b, nh, cl)
        m_i = jnp.maximum(b_i, m_in)  # (b, nh, cl)
        inter_scale = jnp.exp(b_i - m_i)  # (b, nh, cl)
        num_inter = jnp.einsum("blhd,bhde->bhle", qc, C_prev) * inter_scale[..., None]
        den_inter = jnp.einsum("blhd,bhd->bhl", qc, n_prev) * inter_scale
        W = jnp.einsum("blhd,bshd->bhls", qc, kc) * jnp.exp(Dm - m_i[..., None])
        num = num_inter + jnp.einsum("bhls,bshd->bhld", W, vc)
        den = den_inter + jnp.sum(W, axis=-1)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # chunk-boundary state update
        total = cfc[:, -1, :]  # (b, nh)
        gk = total[:, None, :] - cfc + irc  # (b, cl, nh)
        m_next = jnp.maximum(total + m_prev, jnp.max(gk, axis=1))
        scale_old = jnp.exp(total + m_prev - m_next)
        gke = jnp.exp(gk - m_next[:, None, :])
        C_new = C_prev * scale_old[:, :, None, None] + jnp.einsum(
            "blh,blhd,blhe->bhde", gke, kc, vc
        )
        n_new = n_prev * scale_old[:, :, None] + jnp.einsum("blh,blhd->bhd", gke, kc)
        return (C_new, n_new, m_next), h.transpose(0, 2, 1, 3)  # (b, cl, nh, dh)

    xs = (
        qr.transpose(1, 0, 2, 3, 4), kr.transpose(1, 0, 2, 3, 4),
        vr.transpose(1, 0, 2, 3, 4), cf.transpose(1, 0, 2, 3),
        ir.transpose(1, 0, 2, 3), Dmat.transpose(1, 0, 2, 3, 4),
        m_intra.transpose(1, 0, 2, 3),
    )
    carry, ys = jax.lax.scan(step, (Cst, nst, mst), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, nh, dh)
    return y, carry


def mlstm_apply(
    p: Params, cfg: ModelConfig, u: jnp.ndarray,
    cache: Optional[Params] = None, chunk: int = 256,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, L, d = u.shape
    di, nh = cfg.d_inner, cfg.n_heads
    dh = di // nh
    up = dense(p["up"], u)
    a, gate = jnp.split(up, 2, axis=-1)
    ilog = dense(p["w_i"], u).astype(jnp.float32)  # (b, L, nh)
    flog = jax.nn.log_sigmoid(dense(p["w_f"], u).astype(jnp.float32))
    new_cache = None
    if cache is None:
        c = jax.nn.silu(causal_conv1d(a, p["conv_w"], p["conv_b"]))
        q = dense(p["wq"], c).reshape(b, L, nh, dh)
        k = dense(p["wk"], c).reshape(b, L, nh, dh)
        v = dense(p["wv"], a).reshape(b, L, nh, dh)
        y, _ = _mlstm_chunked(q, k, v, ilog, flog, chunk=min(chunk, L))
    else:
        conv_state, c_t = conv_step(cache["conv"], a[:, 0], p["conv_w"], p["conv_b"])
        c_t = jax.nn.silu(c_t)
        q = (dense(p["wq"], c_t).reshape(b, nh, dh) / math.sqrt(dh)).astype(jnp.float32)
        k = dense(p["wk"], c_t).reshape(b, nh, dh).astype(jnp.float32)
        v = dense(p["wv"], a[:, 0]).reshape(b, nh, dh).astype(jnp.float32)
        i_t, f_t = ilog[:, 0], flog[:, 0]  # (b, nh)
        m_prev, C_prev, n_prev = cache["m"], cache["C"], cache["n"]
        m_new = jnp.maximum(f_t + m_prev, i_t)
        fp = jnp.exp(f_t + m_prev - m_new)
        ip = jnp.exp(i_t - m_new)
        C_new = C_prev * fp[:, :, None, None] + ip[:, :, None, None] * (
            k[:, :, :, None] * v[:, :, None, :]
        )
        n_new = n_prev * fp[:, :, None] + ip[:, :, None] * k
        num = jnp.einsum("bhd,bhde->bhe", q, C_new)
        den = jnp.einsum("bhd,bhd->bh", q, n_new)
        y = (num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None])[:, None]
        new_cache = {"conv": conv_state, "C": C_new, "n": n_new, "m": m_new}
    y = y.reshape(b, L, di).astype(u.dtype)
    y = rms_norm(y, p["out_norm"], cfg.rms_eps) * jax.nn.silu(gate)
    return dense(p["down"], y), new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Params:
    di, nh = cfg.d_inner, cfg.n_heads
    dh = di // nh
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), DTYPE),
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


# ===================================================================== #
# sLSTM (xLSTM): scalar memory, per-head block-diagonal recurrence
# ===================================================================== #
def init_slstm(key, cfg: ModelConfig) -> Params:
    d, nh = cfg.d_model, cfg.n_heads
    hd = d // nh
    ks = jax.random.split(key, 7)
    scale = 1.0 / math.sqrt(d)
    ffw = int(round(4 * d / 3 / 64)) * 64
    return {
        "wx": init_dense(ks[0], d, 4 * d, bias=True),  # z,i,f,o input paths
        "r": (jax.random.normal(ks[1], (4, nh, hd, hd), jnp.float32) * scale).astype(DTYPE),
        "out_norm": jnp.ones((d,), DTYPE),
        "ffn_up": init_dense(ks[2], d, ffw),
        "ffn_down": init_dense(ks[3], ffw, d),
    }


def _slstm_cell(carry, gx, r):
    """One sLSTM step. carry: (c, n, h, m) each (b, nh, hd) / m: (b, nh, hd).
    gx: (b, 4, nh, hd) precomputed input contributions; r: (4, nh, hd, hd)."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,ghde->bghe", h, r)  # (b, 4, nh, hd)
    z_r, i_r, f_r, o_r = [(gx[:, g] + rec[:, g]).astype(jnp.float32) for g in range(4)]
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    m_new = jnp.maximum(f_r + m, i_r)
    ip = jnp.exp(i_r - m_new)
    fp = jnp.exp(f_r + m - m_new)
    c_new = fp * c + ip * z
    n_new = fp * n + ip
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_apply(
    p: Params, cfg: ModelConfig, u: jnp.ndarray,
    cache: Optional[Params] = None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, L, d = u.shape
    nh = cfg.n_heads
    hd = d // nh
    gx = dense(p["wx"], u).reshape(b, L, 4, nh, hd)
    r = p["r"].astype(jnp.float32)
    if cache is None:
        zero = jnp.zeros((b, nh, hd), jnp.float32)
        carry0 = (zero, zero, zero, jnp.full((b, nh, hd), -1e30, jnp.float32))

        def step(carry, gx_t):
            new = _slstm_cell(carry, gx_t, r)
            return new, new[2]

        _, hs = jax.lax.scan(step, carry0, gx.transpose(1, 0, 2, 3, 4))
        y = hs.transpose(1, 0, 2, 3).reshape(b, L, d)
        new_cache = None
    else:
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
        new = _slstm_cell(carry, gx[:, 0], r)
        y = new[2].reshape(b, 1, d)
        new_cache = {"c": new[0], "n": new[1], "h": new[2], "m": new[3]}
    y = rms_norm(y.astype(u.dtype), p["out_norm"], cfg.rms_eps)
    y = dense(p["ffn_down"], jax.nn.gelu(dense(p["ffn_up"], y)))
    return y, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Params:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    zero = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": zero, "n": zero, "h": zero, "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}
