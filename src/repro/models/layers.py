"""Core layers: norms, RoPE, dense, chunked attention (GQA + MLA), MLP.

Everything is functional: ``init_*`` builds a params pytree, ``*_apply``
consumes it. Attention uses a q-chunked online-softmax-free formulation
(full softmax per q-chunk against all keys) so 32k-sequence cells never
materialize an SxS score tensor; the Pallas flash-attention kernel
(repro/kernels/flash_attention) is the TPU fast path for the same math and
is validated against these references.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]
DTYPE = jnp.bfloat16


# --------------------------------------------------------------------- #
# primitives
# --------------------------------------------------------------------- #
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def init_dense(key, d_in: int, d_out: int, bias: bool = False, scale: float = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(DTYPE)}
    if bias:
        p["b"] = jnp.zeros((d_out,), DTYPE)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def rope_cos_sin(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., S) int32 -> cos/sin (..., S, dim//2) f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (b, S, h, d); cos/sin: (b, S, d//2) or (S, d//2)."""
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- #
# chunked multi-head attention with GQA grouping
# --------------------------------------------------------------------- #
def _auto_q_chunk(B: int, Sq: int, Skv: int, hq: int,
                  budget: int = 1 << 31) -> int:
    """Pick the q-chunk (the chip-level temporal tile of the attention
    Problem's q dim) so the f32 score chunk fits the HBM budget PER CHIP --
    Union legality rule R3 applied at the HBM cluster level. Matters when
    heads cannot shard over 'model' (llava's 56 heads on a 16-way axis):
    the fallback keeps heads unsharded and shrinks the temporal tile
    instead."""
    from repro.sharding import hints as _h

    st = _h._STATE
    qc = 1024
    if not st["enabled"]:
        return qc
    sizes = st["sizes"]
    dp = st["dp"] or ()
    dpn = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dpn *= sizes.get(a, 1)
    tpn = sizes.get(st["tp"], 1) if st["tp"] else 1
    hq_loc = hq // tpn if hq % tpn == 0 else hq
    b_loc = B // dpn if B % dpn == 0 else B
    while qc > 128 and b_loc * qc * Skv * hq_loc * 4 > budget:
        qc //= 2
    return qc


def mha(
    q: jnp.ndarray,  # (b, Sq, hq, d)
    k: jnp.ndarray,  # (b, Skv, hkv, d)
    v: jnp.ndarray,  # (b, Skv, hkv, dv)
    *,
    causal: bool,
    q_offset=0,  # int or scalar array: global position of q[0]
    kv_len: Optional[jnp.ndarray] = None,  # valid cache length (decode)
    q_chunk: Optional[int] = None,
    sm_scale: Optional[float] = None,
) -> jnp.ndarray:
    b, Sq, hq, d = q.shape
    _, Skv, hkv, dv = v.shape
    g = hq // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    if q_chunk is None:
        q_chunk = _auto_q_chunk(b, Sq, Skv, hq)
    from repro import kernels as _k
    if _k.pallas_enabled():
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(
            q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
            sm_scale=scale,
        )
    # GQA: repeat KV heads to hq so the head axis stays flat and GSPMD can
    # shard it over 'model' even when hkv < mesh size (e.g. starcoder2 kv=4
    # on a 16-way TP axis). The repeat is sharded and cheap; the Pallas
    # flash kernel avoids it natively on TPU.
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    kpos = jnp.arange(Skv)

    def attend(qc: jnp.ndarray, qpos: jnp.ndarray) -> jnp.ndarray:
        # qc: (b, c, hq, d); qpos: (c,) global positions
        s = jnp.einsum("bchd,bkhd->bhck", qc, k, preferred_element_type=jnp.float32)
        s = s * scale
        mask = jnp.ones((qc.shape[1], Skv), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhck,bkhd->bchd", p, v)

    if Sq <= q_chunk:
        out = attend(q, q_offset + jnp.arange(Sq))
    else:
        assert Sq % q_chunk == 0, f"Sq={Sq} must divide q_chunk={q_chunk}"
        nq = Sq // q_chunk
        qs = q.reshape(b, nq, q_chunk, hq, d).transpose(1, 0, 2, 3, 4)

        def body(_, qi_i):
            qi, i = qi_i
            pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            return None, attend(qi, pos)

        _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nq)))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, Sq, hq, dv)
    return out.reshape(b, Sq, hq, dv)


# --------------------------------------------------------------------- #
# GQA attention layer (with optional qk-norm, bias, KV cache)
# --------------------------------------------------------------------- #
def init_attention(key, cfg: ModelConfig) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_dense(ks[0], d, hq * hd, cfg.qkv_bias),
        "wk": init_dense(ks[1], d, hkv * hd, cfg.qkv_bias),
        "wv": init_dense(ks[2], d, hkv * hd, cfg.qkv_bias),
        "wo": init_dense(ks[3], hq * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), DTYPE)
        p["k_norm"] = jnp.ones((hd,), DTYPE)
    return p


def attention_apply(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (b, S, d)
    positions: jnp.ndarray,  # (S,) global positions of x
    cache: Optional[Params] = None,  # {"k","v"}: (b, Smax, hkv, hd); decode only
    cache_len=None,  # filled length of the cache before this call
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, S, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, S, hq, hd)
    k = dense(p["wk"], x).reshape(b, S, hkv, hd)
    v = dense(p["wv"], x).reshape(b, S, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    if not cfg.encoder_only:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    new_cache = None
    if cache is not None:
        # decode: write new k/v at cache_len, attend over the whole cache
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_len, 0, 0))
        new_cache = {"k": ck, "v": cv}
        out = mha(q, ck, cv, causal=False, q_offset=cache_len,
                  kv_len=cache_len + S)
    else:
        out = mha(q, k, v, causal=not cfg.encoder_only, q_offset=0)
    y = dense(p["wo"], out.reshape(b, S, hq * hd))
    return y, new_cache


# --------------------------------------------------------------------- #
# MLA attention (DeepSeek-V2): latent-compressed KV cache
# --------------------------------------------------------------------- #
def init_mla(key, cfg: ModelConfig) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": init_dense(ks[0], d, h * (dn + dr)),
        "kv_down": init_dense(ks[1], d, r + dr),  # latent + shared rope key
        "kv_up": init_dense(ks[2], r, h * (dn + dv)),
        "wo": init_dense(ks[3], h * dv, d),
        "latent_norm": jnp.ones((r,), DTYPE),
    }


def mla_apply(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[Params] = None,  # {"ckv": (b,Smax,r), "krope": (b,Smax,dr)}
    cache_len=None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, S, d = x.shape
    h = cfg.n_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    q = dense(p["wq"], x).reshape(b, S, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    down = dense(p["kv_down"], x)
    ckv, k_rope = down[..., :r], down[..., r:]
    ckv = rms_norm(ckv, p["latent_norm"], cfg.rms_eps)
    cos, sin = rope_cos_sin(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope.reshape(b, S, 1, dr), cos, sin)

    new_cache = None
    if cache is not None:
        ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_len, 0))
        kr = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.reshape(b, S, dr).astype(cache["krope"].dtype), (0, cache_len, 0))
        new_cache = {"ckv": ckv, "krope": kr}
        k_rope = kr.reshape(b, -1, 1, dr)
        kv_len = cache_len + S
        q_offset = cache_len
        causal = False
    else:
        kv_len = None
        q_offset = 0
        causal = True
    # up-project latents to per-head keys/values
    kv = dense(p["kv_up"], ckv).reshape(b, ckv.shape[1], h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    # concat nope+rope parts; rope key is shared across heads (hkv=1 for it)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, ckv.shape[1], h, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = mha(q_full, k_full, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
              sm_scale=1.0 / math.sqrt(dn + dr))
    y = dense(p["wo"], out.reshape(b, S, h * dv))
    return y, new_cache


# --------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------- #
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act in ("silu",):  # gated (SwiGLU)
        return {
            "gate": init_dense(ks[0], d, ff),
            "up": init_dense(ks[1], d, ff),
            "down": init_dense(ks[2], ff, d),
        }
    return {"up": init_dense(ks[0], d, ff), "down": init_dense(ks[1], ff, d)}


def mlp_apply(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    f = act_fn(cfg.act)
    if "gate" in p:
        return dense(p["down"], f(dense(p["gate"], x)) * dense(p["up"], x))
    return dense(p["down"], f(dense(p["up"], x)))
