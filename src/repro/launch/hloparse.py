"""Parse compiled HLO text for collective traffic (the roofline's third term).

``cost_analysis()`` does not expose collective bytes, so we sum the output
array sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the (SPMD, per-device) compiled module and apply the
standard ring-algorithm byte conventions per collective type:

  all-gather        bytes_out x (n-1)/n      (each device receives the rest)
  all-reduce        bytes    x 2(n-1)/n      (reduce-scatter + all-gather)
  reduce-scatter    bytes_in x (n-1)/n  == bytes_out x (n-1)
  all-to-all        bytes    x (n-1)/n
  collective-permute bytes_out              (one hop)
"""

from __future__ import annotations

import re
import warnings
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ARRAY_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    raw_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    link_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    # dtype -> element count for arrays whose HLO dtype is not in
    # _DTYPE_BYTES; those elements are EXCLUDED from the byte sums above
    unknown_dtypes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())

    @property
    def skipped_bytes(self) -> float:
        """Lower-bound estimate (1 byte/element) of bytes excluded from the
        sums because the dtype was unknown."""
        return float(sum(self.unknown_dtypes.values()))

    def row(self) -> Dict[str, float]:
        out = {"collective_bytes": self.total_link_bytes}
        for k in _COLLECTIVES:
            out[f"{k}_count"] = self.counts.get(k, 0)
            out[f"{k}_bytes"] = self.link_bytes.get(k, 0.0)
        out["unknown_dtype_count"] = len(self.unknown_dtypes)
        out["skipped_bytes"] = self.skipped_bytes
        return out


def _shape_bytes(type_str: str, unknown: Optional[Dict[str, int]] = None) -> float:
    """Sum byte sizes of all arrays in an HLO result type string.

    Arrays with a dtype missing from ``_DTYPE_BYTES`` are excluded from
    the sum; their element counts accumulate into ``unknown`` (dtype ->
    elements) so callers can warn and report the skipped tally instead of
    silently undercounting."""
    total = 0.0
    for dt, dims in _ARRAY_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        if dt not in _DTYPE_BYTES:
            if unknown is not None:
                unknown[dt] = unknown.get(dt, 0) + n
            continue
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        _ngroups, gsize, _total = map(int, m.groups())
        return max(1, gsize)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        first = m.group(1)
        return max(1, len([x for x in first.split(",") if x.strip() != ""]))
    return default


def parse_collectives(hlo_text: str, default_group: int = 2) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=\s]+)\s+([\w\-]+)", ls)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-start") or opname.startswith(c + "."):
                base = c
                break
        if base is None:
            continue
        if opname.endswith("-done"):
            continue  # the -start op carries the shape
        unknown: Dict[str, int] = {}
        bytes_out = _shape_bytes(result_type, unknown)
        if bytes_out <= 0:
            # fallback: scan full line's result section
            unknown = {}
            bytes_out = _shape_bytes(ls.split("=", 1)[1].split("(", 1)[0], unknown)
        for dt, n in unknown.items():
            if dt not in stats.unknown_dtypes:
                warnings.warn(
                    f"hloparse: unknown HLO dtype {dt!r} in {base} result; "
                    f"excluding its elements from collective byte sums "
                    f"(tallied in CollectiveStats.row()['skipped_bytes'])",
                    stacklevel=2,
                )
            stats.unknown_dtypes[dt] += n
        n = _group_size(ls, default_group)
        if base == "all-gather":
            link = bytes_out * (n - 1) / max(1, n)
        elif base == "all-reduce":
            link = bytes_out * 2 * (n - 1) / max(1, n)
        elif base == "reduce-scatter":
            link = bytes_out * (n - 1)
        elif base == "all-to-all":
            link = bytes_out * (n - 1) / max(1, n)
        else:  # collective-permute
            link = bytes_out
        stats.counts[base] += 1
        stats.raw_bytes[base] += bytes_out
        stats.link_bytes[base] += link
    return stats
