"""Production training driver.

Wires every substrate layer together: config registry -> model -> sharded
step (pjit over the mesh) -> deterministic data pipeline -> checkpoint
manager (atomic, async, elastic) -> fault-tolerant runner (retry /
restore / straggler watchdog).

CPU-scale example (the examples/ scripts call this):

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b_smoke \\
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

On a real TPU slice the same entry point runs with --mesh pod,data,model
dimensions; the step function and shardings are identical to the ones the
multi-pod dry-run compiles for 512 chips.
"""

from __future__ import annotations

import argparse
import logging
import math
import time
from pathlib import Path

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import SHAPES, ShapeConfig, get_config
from repro.data import DataConfig, SyntheticLM, TokenFileDataset, make_pipeline
from repro.launch import steps as steps_mod
from repro.launch.specs import batch_struct, state_struct
from repro.optim.optimizers import adamw, lion
from repro.optim.schedules import cosine_schedule
from repro.runtime import FaultTolerantRunner, RunnerConfig
from repro.sharding.hints import hints_from_mesh
from repro.sharding.specs import ShardingRules, batch_specs, named, state_specs

log = logging.getLogger("repro.train")


def build_mesh(spec: str | None) -> Mesh | None:
    if not spec:
        return None
    dims = [int(x) for x in spec.split(",")]
    names = ("pod", "data", "model")[-len(dims):]
    devs = np.array(jax.devices()[: math.prod(dims)]).reshape(dims)
    return Mesh(devs, names)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--optimizer", choices=["adamw", "lion"], default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="", help="e.g. '2,16,16' or '1,4'")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default="synthetic", help="'synthetic' or a token file path")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--step-timeout", type=float, default=None)
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    mesh = build_mesh(args.mesh)
    rules = ShardingRules()
    if mesh is not None:
        hints_from_mesh(mesh, rules)

    lr = cosine_schedule(args.lr, args.warmup, args.steps)
    optimizer = {"adamw": adamw, "lion": lion}[args.optimizer](lr)
    step_fn = steps_mod.make_train_step(
        cfg, optimizer, remat=not args.no_remat, microbatches=args.microbatches
    )

    # ---- init / restore ------------------------------------------------ #
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    init = steps_mod.make_init_state(cfg, optimizer)
    if mesh is not None:
        st_specs = state_specs(state_struct(cfg, optimizer), cfg, mesh, rules)
        st_sh = named(st_specs, mesh)
        b_specs = batch_specs(cfg, shape, mesh, rules)
        with mesh:
            state = jax.jit(init, out_shardings=st_sh)(jax.random.PRNGKey(args.seed))
            jit_step = jax.jit(
                step_fn, in_shardings=(st_sh, named(b_specs, mesh)),
                out_shardings=(st_sh, None), donate_argnums=(0,),
            )
    else:
        st_sh = None
        b_specs = None
        state = jax.jit(init)(jax.random.PRNGKey(args.seed))
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(Path(args.ckpt_dir), every=args.ckpt_every)
        try:
            state, start_step, _ = ckpt.restore_latest(state, shardings=st_sh)
            log.info("restored checkpoint at step %d", start_step)
        except FileNotFoundError:
            pass

    # ---- data ----------------------------------------------------------- #
    if args.data == "synthetic":
        source = SyntheticLM(cfg.vocab, seed=args.seed)
    else:
        source = TokenFileDataset(args.data, cfg.vocab, seed=args.seed)
    pipe = make_pipeline(
        source, args.batch, args.seq, mesh=mesh, specs=b_specs,
        start_step=start_step, data_cfg=DataConfig(seed=args.seed),
    )

    def restore_fn():
        assert ckpt is not None
        st, step, _ = ckpt.restore_latest(state, shardings=st_sh)
        return st, step

    runner = FaultTolerantRunner(
        jit_step,
        RunnerConfig(step_timeout_s=args.step_timeout),
        checkpoint_manager=ckpt,
        restore_fn=restore_fn if ckpt else None,
    )

    # ---- loop ------------------------------------------------------------ #
    losses = []
    t0 = time.time()
    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        for step in range(start_step, args.steps):
            batch = next(pipe)
            state, metrics = runner.run_step(state, batch, step)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                log.info("step %-5d loss %.4f  (%.2f s/step avg)",
                         step, loss, dt / max(1, step - start_step + 1))
            if ckpt is not None and ckpt.should_save(step + 1):
                ckpt.save(step + 1, state, extra={"loss": loss})
    if not losses:  # resumed at/after the target step: nothing to do
        return {"first_loss": float("nan"), "last_loss": float("nan"), "steps": 0}
    if ckpt is not None:
        ckpt.save(args.steps, state, extra={"loss": losses[-1]})
        ckpt.wait()
    return {"first_loss": losses[0], "last_loss": losses[-1], "steps": len(losses)}


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    out = main()
    print(f"train done: loss {out['first_loss']:.4f} -> {out['last_loss']:.4f} "
          f"over {out['steps']} steps")
