import os
# constant_folding: avoids minute-long folds of huge iota/broadcast consts.
# convert-mover: stops XLA from widening the bf16 scan-residual stacks to
# f32 (it hoists the f32 converts that rms_norm applies into the
# dynamic-update-slice that saves the per-unit carry, doubling its bytes).
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=constant_folding,convert-mover"
)

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, lower + compile the cell's
step function for the single-pod 16x16 mesh AND the 2x16x16 multi-pod
mesh, record memory_analysis() (fits-in-HBM proof), cost_analysis()
(per-device FLOPs/bytes for the roofline), and the collective schedule
parsed from compiled HLO. Artifacts go to experiments/dryrun/*.json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --jobs-file cells.txt  # subset
"""

import argparse
import dataclasses
import json
import math
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES, get_config, runnable_cells
from repro.core.architecture import TPU_V5E
from repro.core.opstream import formula_model_flops
from repro.models import model as model_mod
from repro.launch.hloparse import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.sharding.specs import ShardingRules

# Per-chip HBM capacity from the modeled hardware description
# (repro.core.architecture.TPU_V5E, the attrs of tpu_chip()) so the
# fits-in-HBM proofs track the arch instead of a magic number. Override
# per call via the hbm_bytes= parameters or the --hbm-gib CLI flag.
HBM_PER_CHIP = int(TPU_V5E["hbm_bytes"])


def _sharded_nbytes(struct_tree, sharding_tree, sizes) -> int:
    """Exact per-device bytes of a pytree of ShapeDtypeStructs under the
    given NamedShardings (division by the mesh-axis product per leaf)."""
    total = 0
    structs = jax.tree.leaves(struct_tree)
    shards = jax.tree.leaves(
        sharding_tree, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)
    )
    for s, sh in zip(structs, shards):
        div = 1
        spec = sh.spec if hasattr(sh, "spec") else None
        if spec is not None:
            for entry in spec:
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    div *= sizes.get(ax, 1)
        total += (s.size * s.dtype.itemsize) // max(1, div)
    return total


def analytic_memory(arch: str, shape_name: str, mesh, args, in_sh,
                    microbatches: int = 1, rules=None,
                    hbm_bytes: int = 0) -> dict:
    """TPU-dtype-correct per-chip memory estimate. The CPU backend's
    float-normalization pass widens bf16 while-loop buffers to f32, so
    memory_analysis() OVERSTATES TPU residency; this estimate keeps bf16
    at 2 bytes and adds the activation terms analytically."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = math.prod(sizes.get(a, 1) for a in ("pod", "data"))
    tp = sizes.get("model", 1)
    args_bytes = sum(_sharded_nbytes(a, s, sizes) for a, s in zip(args, in_sh))
    B, S, d, V = shape.global_batch, shape.seq_len, cfg.d_model, cfg.vocab
    tok_local = B * S // dp
    act = 0
    def score_chunk_bytes(factor: int) -> int:
        # mirrors models.layers._auto_q_chunk: the q-chunk shrinks until the
        # f32 score chunk fits per-chip (llava: 56 heads unshardable on 16)
        hq_loc = max(1, cfg.n_heads // tp) if cfg.n_heads % tp == 0 else cfg.n_heads
        b_loc = B // dp if B % dp == 0 else B
        qc = min(1024, S)
        while qc > 128 and b_loc * qc * S * hq_loc * 4 > (1 << 31):
            qc //= 2
        return factor * max(1, b_loc) * qc * S * hq_loc * 4

    if shape.kind == "train":
        mb = max(1, microbatches)
        n_units = (cfg.n_layers - cfg.first_k_dense) // len(cfg.block_pattern)
        sp = tp if (S // 1) % tp == 0 else 1
        act += n_units * (B // min(B, dp)) * (B * S * d // (dp * sp) // (B // min(B, dp))) * 2 // mb  # carry stack bf16
        act += 2 * tok_local * max(1, V // tp) * 4 // mb  # fwd+bwd f32 logits
        act += score_chunk_bytes(2) // mb
        if mb > 1:  # f32 gradient accumulator (sharded like the params)
            act += cfg.num_params() * 4 // (dp * tp)
        if rules is not None and getattr(rules, "remat_policy", "full") == "save_block_outputs":
            # saved per-block residual contributions (bf16, seq-sharded)
            act += cfg.n_layers * (B * S // (dp * sp)) * d * 2 // mb
    elif shape.kind == "prefill":
        sp = tp if (B * S) % (dp * tp) == 0 else 1  # sequence sharding
        act += 12 * tok_local // sp * d * 2
        act += score_chunk_bytes(2)
        act += tok_local * max(1, V // tp) * 2
    else:  # decode
        act += 4 * (B // min(B, dp)) * max(1, V // tp) * 4
    total = args_bytes + act
    hbm = int(hbm_bytes) or HBM_PER_CHIP
    return {
        "args_bytes": int(args_bytes),
        "activation_bytes": int(act),
        "total_bytes": int(total),
        "hbm_per_chip": hbm,
        "fits_hbm": bool(total <= hbm),
    }


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS convention (6/2/2 x active params x tokens). One
    definition, shared with the whole-model op streams -- see
    ``repro.core.opstream.formula_model_flops``."""
    return formula_model_flops(get_config(arch), SHAPES[shape_name])


def corrected_costs(arch: str, shape_name: str, mesh, rules, remat: bool) -> dict:
    """Structure-corrected per-device FLOPs/bytes/collectives.

    XLA's ``cost_analysis()`` counts a while-loop body ONCE, not x trips,
    so the scanned unit stack is undercounted by ~n_units. Fix: compile
    the SAME cell at 1 and 2 scanned units with the scan fully unrolled
    (trip count 1 -> body counted exactly), then extrapolate linearly:

        cost(N) = cost(u1) + (N - 1) * (cost(u2) - cost(u1))

    The prefix (first_k_dense), embedding, head, loss, and batch-dependent
    terms live in cost(u1); the per-unit compute/bytes/collectives
    (including the per-unit gradient all-reduce) are the slope. Linearity
    holds because units are structurally identical.
    """
    cfg = get_config(arch)
    pat = len(cfg.block_pattern)
    n_units = (cfg.n_layers - cfg.first_k_dense) // pat
    shape = SHAPES[shape_name]
    donate = {"train": (0,), "decode": (1,), "prefill": ()}[shape.kind]
    meas = {}
    for u in (1, 2):
        cfg_u = dataclasses.replace(cfg, n_layers=cfg.first_k_dense + pat * u)
        model_mod.set_scan_unroll(u)
        try:
            fn, args, in_sh, out_sh = build_cell(
                arch, shape_name, mesh, rules, remat=remat, cfg=cfg_u
            )
            with mesh:
                compiled = jax.jit(
                    fn, in_shardings=in_sh, out_shardings=out_sh,
                    donate_argnums=donate,
                ).lower(*args).compile()
                ca = compiled.cost_analysis() or {}
                colls = parse_collectives(compiled.as_text())
        finally:
            model_mod.set_scan_unroll(1)
        meas[u] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": colls.row(),
        }

    def extrap(a1, a2):
        return a1 + (n_units - 1) * max(0.0, a2 - a1)

    coll = {
        k: extrap(meas[1]["coll"][k], meas[2]["coll"][k])
        for k in meas[1]["coll"]
    }
    return {
        "method": "scan-body linear extrapolation (u=1,2 unrolled)",
        "n_units": n_units,
        "flops_per_device": extrap(meas[1]["flops"], meas[2]["flops"]),
        "bytes_per_device": extrap(meas[1]["bytes"], meas[2]["bytes"]),
        "collective_bytes_per_device": coll["collective_bytes"],
        "collectives": coll,
        "raw_u1": meas[1],
        "raw_u2": meas[2],
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, rules=None,
             out_dir: Path = Path("experiments/dryrun"), remat: bool = True,
             tag: str = "", hbm_bytes: int = 0) -> dict:
    rules = rules or ShardingRules()
    hbm = int(hbm_bytes) or HBM_PER_CHIP
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    cell_name = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    t0 = time.time()
    # auto-pick the microbatch count: the smallest power of two whose
    # TPU-dtype-analytic residency fits HBM (the 110B train cell needs 2)
    microbatches = 1
    while SHAPES[shape_name].kind == "train" and microbatches < 8:
        fn, args, in_sh, out_sh = build_cell(
            arch, shape_name, mesh, rules, remat=remat, microbatches=microbatches
        )
        if analytic_memory(arch, shape_name, mesh, args, in_sh,
                           microbatches, rules, hbm_bytes=hbm)["fits_hbm"]:
            break
        microbatches *= 2
    fn, args, in_sh, out_sh = build_cell(
        arch, shape_name, mesh, rules, remat=remat, microbatches=microbatches
    )
    # donate the large carried aggregate (train state / decode cache) so the
    # output aliases the input instead of doubling residency
    donate = {"train": (0,), "decode": (1,), "prefill": ()}[SHAPES[shape_name].kind]
    with mesh:
        lowered = jax.jit(
            fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
        ).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    corrected = corrected_costs(arch, shape_name, mesh, rules, remat)
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    per_dev_bytes = (
        int(getattr(mem, "argument_size_in_bytes", 0))
        + int(getattr(mem, "output_size_in_bytes", 0))
        - int(getattr(mem, "alias_size_in_bytes", 0))
        + int(getattr(mem, "temp_size_in_bytes", 0))
    )
    art = {
        "cell": cell_name,
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "multi_pod": multi_pod,
        "tag": tag,
        # raw cost_analysis numbers (scan body counted once -- see
        # corrected_costs docstring); `corrected` holds the roofline inputs
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": colls.total_link_bytes,
        "collectives": colls.row(),
        "corrected": corrected,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_per_device": per_dev_bytes,
            "hbm_per_chip": hbm,
            "fits_hbm": bool(per_dev_bytes <= hbm),
        },
        "memory_tpu_analytic": analytic_memory(
            arch, shape_name, mesh, args, in_sh, microbatches, rules,
            hbm_bytes=hbm,
        ),
        "microbatches": microbatches,
        "model_flops": model_flops(arch, shape_name),
        "hlo_lines": hlo.count("\n"),
        "lower_s": t_lower,
        "compile_s": t_compile,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell_name}.json").write_text(json.dumps(art, indent=2))
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--hbm-gib", type=float, default=0.0,
                    help="per-chip HBM override in GiB (default: the modeled "
                    "arch's hbm_bytes, repro.core.architecture.TPU_V5E)")
    ap.add_argument("--rules", default="", help="comma list of ShardingRules "
                    "overrides, e.g. 'fsdp_only=true,dp_over_pod=false'")
    args = ap.parse_args()

    rules = ShardingRules()
    if args.rules:
        import dataclasses as _dc

        kv = {}
        for item in args.rules.split(","):
            k, v = item.split("=")
            kv[k] = {"true": True, "false": False}.get(v.lower(), v)
        rules = _dc.replace(rules, **kv)

    cells = runnable_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    out_dir = Path(args.out)
    n_ok = n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            cell = f"{arch}__{shape}__{mesh_name}" + (f"__{args.tag}" if args.tag else "")
            if args.skip_existing and (out_dir / f"{cell}.json").exists():
                print(f"SKIP {cell} (exists)", flush=True)
                continue
            try:
                t0 = time.time()
                art = run_cell(arch, shape, mp, rules=rules, out_dir=out_dir,
                               remat=not args.no_remat, tag=args.tag,
                               hbm_bytes=int(args.hbm_gib * (1 << 30)))
                n_ok += 1
                print(
                    f"OK   {cell}: flops/dev={art['flops_per_device']:.3e} "
                    f"bytes/dev={art['bytes_per_device']:.3e} "
                    f"coll/dev={art['collective_bytes_per_device']:.3e} "
                    f"peak={art['memory']['peak_per_device']/2**30:.2f}GiB "
                    f"tpu_est={art['memory_tpu_analytic']['total_bytes']/2**30:.2f}GiB "
                    f"fits={art['memory_tpu_analytic']['fits_hbm']} "
                    f"({time.time()-t0:.0f}s)",
                    flush=True,
                )
            except Exception as e:
                n_fail += 1
                print(f"FAIL {cell}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
