"""Batched serving driver: wave-batched prefill + lock-step decode.

Scheduling model: requests are packed into *waves* of up to ``--batch``
sequences. Prompts in a wave are LEFT-padded to the wave's max prompt
length so every slot shares one scalar cache position (the padding lives
at positions every real token can already attend to, and contributes only
through the softmax over the pad prefix -- it is masked by feeding a
shared pad token and offsetting positions; see ``_prefill``). The wave
then decodes in lock-step; a wave retires when all its members finish.

This is the fixed-shape JAX analogue of batch-of-requests serving; the
decode step is EXACTLY the step the multi-pod dry-run compiles
(launch/steps.make_serve_step). A continuous-batching scheduler with
per-slot position vectors is a server-side extension that changes only
this file, not the model/step layer.
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch import steps as steps_mod
from repro.models import init_cache, init_params


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class WaveServer:
    """Fixed-shape wave batching on top of make_serve_step."""

    def __init__(self, cfg, params, *, batch_slots: int, max_len: int,
                 pad_token: int = 0) -> None:
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.pad = pad_token
        self.queue: List[Request] = []
        self._decode = jax.jit(steps_mod.make_serve_step(cfg))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------ #
    def _prefill(self, wave: List[Request]):
        """Feed left-padded prompts token-by-token through the decode step.

        Left-padding means pad tokens occupy the OLDEST cache positions;
        every sequence's real tokens are contiguous at the end, so the
        shared scalar position is exact. Pad-prefix keys do enter the
        softmax -- acceptable for a pad/BOS token by construction (the
        model treats it as a BOS prefix), and identical across the batch.
        """
        L = max(len(r.prompt) for r in wave)
        toks = np.full((self.slots, L), self.pad, np.int32)
        for i, r in enumerate(wave):
            toks[i, L - len(r.prompt):] = r.prompt
        cache = init_cache(self.cfg, self.slots, self.max_len)
        logits = None
        for t in range(L):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(toks[:, t : t + 1]), jnp.int32(t)
            )
        return logits, cache, L

    def run_wave(self, wave: List[Request]) -> int:
        """Prefill + decode one wave to completion. Returns decode steps."""
        nxt, cache, pos = self._prefill(wave)
        last = np.asarray(nxt)[:, 0].astype(np.int32)  # (slots,)
        steps = 0
        live = {i: r for i, r in enumerate(wave)}
        for i, r in live.items():
            r.out.append(int(last[i]))
        while any(not r.done for r in wave) and pos < self.max_len - 1:
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(last)[:, None], jnp.int32(pos)
            )
            last = np.asarray(logits)[:, 0].astype(np.int32)
            pos += 1
            steps += 1
            for i, r in list(live.items()):
                if r.done:
                    continue
                r.out.append(int(last[i]))
                if len(r.out) >= r.max_new:
                    r.done = True
                    del live[i]
        for r in wave:
            r.done = True
        return steps

    def run(self) -> List[Request]:
        finished: List[Request] = []
        while self.queue:
            wave = self.queue[: self.slots]
            self.queue = self.queue[self.slots:]
            # pad the wave to full slot count with dummy requests
            while len(wave) < self.slots:
                wave.append(Request(-1, [self.pad], 1))
            self.run_wave(wave)
            finished += [r for r in wave if r.rid >= 0]
        return finished


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b_smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    assert cfg.supports_decode, f"{cfg.name} is encoder-only; nothing to serve"
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    server = WaveServer(cfg, params, batch_slots=args.batch, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(4, 12))).tolist()
        server.submit(Request(rid, prompt, args.max_new))

    t0 = time.time()
    done = server.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    return {
        "requests": len(done),
        "tokens": toks,
        "tok_per_s": toks / max(dt, 1e-9),
    }


if __name__ == "__main__":
    out = main()
    print(f"served {out['requests']} requests, {out['tokens']} tokens "
          f"({out['tok_per_s']:.1f} tok/s)")
