"""Step functions: train_step / serve_step, shared by the real drivers
(train.py / serve.py) and the multi-pod dry-run."""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, init_params, loss_fn, forward
from repro.optim.optimizers import Optimizer


def make_init_state(cfg: ModelConfig, optimizer: Optimizer):
    def init_state(key):
        params = init_params(cfg, key)
        return {"params": params, "opt": optimizer.init(params)}

    return init_state


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    *,
    remat: bool = True,
    microbatches: int = 1,
    remat_policy: str = "full",
):
    """One optimizer step. ``microbatches > 1`` = gradient accumulation:
    the global batch is split along axis 0 and scanned, with f32 grad
    accumulators -- the standard memory/throughput knob for big cells."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat, remat_policy=remat_policy)
        )(params)

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            l, grads = grads_of(params, batch)
        else:
            def split(x):
                assert x.shape[0] % microbatches == 0, (
                    f"batch {x.shape[0]} % microbatches {microbatches} != 0"
                )
                return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, b):
                loss_acc, g_acc = carry
                l, g = grads_of(params, b)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / microbatches, g_acc, g
                )
                return (loss_acc + l / microbatches, g_acc), None

            (l, grads), _ = jax.lax.scan(body, (jnp.zeros(()), acc0), mb)
        new_params, new_opt = optimizer.update(grads, state["opt"], params)
        metrics = {"loss": l, "step": new_opt["step"]}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        return loss_fn(cfg, params, batch, remat=False)

    return eval_step


def make_prefill_step(cfg: ModelConfig):
    """Forward over the full prompt (logits of the last position)."""

    def prefill_step(params, batch):
        logits, _ = forward(cfg, params, batch, remat=False)
        return logits[:, -1]

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, greedy: bool = True):
    """One decode step: new token given a KV/SSM cache of seq_len tokens."""

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = decode_step(cfg, params, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_cache

    return serve_step
