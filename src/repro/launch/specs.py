"""input_specs(): ShapeDtypeStruct stand-ins for every model input of every
(arch x shape) cell -- weak-type-correct, shardable, no device allocation.
Also assembles the full dry-run cell: (fn, args, in/out shardings)."""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_config
from repro.launch import steps as steps_mod
from repro.models import init_cache, init_params
from repro.optim.optimizers import adamw
from repro.sharding.hints import hints_from_mesh
from repro.sharding.specs import (
    ShardingRules,
    batch_specs,
    cache_specs,
    dp_axes,
    named,
    param_specs,
    state_specs,
)

SDS = jax.ShapeDtypeStruct


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStructs for one global batch of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": SDS((B, 1), jnp.int32)}
    if cfg.frontend == "audio_stub":
        return {
            "frames": SDS((B, S, cfg.d_frontend), jnp.bfloat16),
            "labels": SDS((B, S), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        n_img = cfg.n_frontend_tokens
        return {
            "tokens": SDS((B, S - n_img), jnp.int32),
            "patch_embeds": SDS((B, n_img, cfg.d_frontend), jnp.bfloat16),
        }
    return {"tokens": SDS((B, S), jnp.int32)}


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_params, cfg), jax.random.PRNGKey(0))


def state_struct(cfg: ModelConfig, optimizer=None):
    optimizer = optimizer or adamw(1e-4)
    init = steps_mod.make_init_state(cfg, optimizer)
    return jax.eval_shape(init, jax.random.PRNGKey(0))


def cache_struct(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch, shape.seq_len)
    )


def input_specs(arch: str, shape_name: str, optimizer=None) -> Dict:
    """All inputs of the cell's step function, as ShapeDtypeStructs."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return {
            "params": params_struct(cfg),
            "cache": cache_struct(cfg, shape),
            "tokens": SDS((shape.global_batch, 1), jnp.int32),
            "pos": SDS((), jnp.int32),
        }
    if shape.kind == "prefill":
        return {"params": params_struct(cfg), "batch": batch_struct(cfg, shape)}
    return {"state": state_struct(cfg, optimizer), "batch": batch_struct(cfg, shape)}


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    rules: ShardingRules = ShardingRules(),
    *,
    remat: bool = True,
    cfg: ModelConfig | None = None,
    microbatches: int = 1,
):
    """Returns (fn, args_tuple, in_shardings, out_shardings) ready for
    jax.jit(...).lower(*args). ``cfg`` overrides the registry config (used
    by the dry-run's reduced-depth cost-correction compiles);
    ``microbatches`` enables gradient accumulation for train cells."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    hints_from_mesh(mesh, rules)  # activation sharding constraints at trace time
    optimizer = adamw(1e-4)
    if shape.kind == "train":
        fn = steps_mod.make_train_step(
            cfg, optimizer, remat=remat, microbatches=microbatches,
            remat_policy=getattr(rules, "remat_policy", "full"),
        )
        state = state_struct(cfg, optimizer)
        batch = batch_struct(cfg, shape)
        st_specs = state_specs(state, cfg, mesh, rules)
        b_specs = batch_specs(cfg, shape, mesh, rules)
        in_sh = (named(st_specs, mesh), named(b_specs, mesh))
        out_sh = (named(st_specs, mesh), named({"loss": P(), "step": P()}, mesh))
        return fn, (state, batch), in_sh, out_sh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = dp_axes(mesh, rules)
    dp_total = 1
    for a in dp:
        dp_total *= sizes.get(a, 1)
    # divisibility guards: batch=1 long-context cells replicate the batch
    # axis; hubert's vocab=504 cannot shard over a 16-way model axis
    bdp = dp if (dp and shape.global_batch % dp_total == 0) else None
    v_ax = rules.tp_axis if cfg.vocab % sizes.get(rules.tp_axis, 1) == 0 else None
    if shape.kind == "prefill":
        fn = steps_mod.make_prefill_step(cfg)
        params = params_struct(cfg)
        batch = batch_struct(cfg, shape)
        p_specs = param_specs(params, cfg, mesh, rules, for_training=False)
        b_specs = batch_specs(cfg, shape, mesh, rules)
        in_sh = (named(p_specs, mesh), named(b_specs, mesh))
        out_sh = named(P(bdp, v_ax), mesh)
        return fn, (params, batch), in_sh, out_sh
    # decode
    fn = steps_mod.make_serve_step(cfg)
    params = params_struct(cfg)
    cache = cache_struct(cfg, shape)
    p_specs = param_specs(params, cfg, mesh, rules, for_training=False)
    c_specs = cache_specs(cache, cfg, mesh, rules)
    tok_spec = P(bdp, None)
    in_sh = (
        named(p_specs, mesh),
        named(c_specs, mesh),
        named(tok_spec, mesh),
        named(P(), mesh),
    )
    out_sh = (named(tok_spec, mesh), named(c_specs, mesh))
    tokens = SDS((shape.global_batch, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return fn, (params, cache, tokens, pos), in_sh, out_sh
