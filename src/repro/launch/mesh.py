"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The target mesh: one v5e pod = 16x16 = 256 chips (data, model);
    multi-pod = 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(jax.devices())} "
            "(the dry-run must set --xla_force_host_platform_device_count "
            "BEFORE importing jax)"
        )
    dev_array = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh for elastic re-configuration / debug runs."""
    need = int(np.prod(shape))
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(f"mesh {tuple(shape)} needs {need} devices")
    return jax.sharding.Mesh(np.asarray(devices).reshape(tuple(shape)), tuple(axes))


def make_host_mesh():
    """Single-host debug mesh over all visible devices: (data=N, model=1)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
