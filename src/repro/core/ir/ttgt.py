"""TTGT rewriting: Tensor Contraction -> Transpose-Transpose-GEMM-Transpose.

Paper Sec. II-A / V-A (COMET reformulation): a TC is flattened into a GEMM
by grouping indices into M (A-and-C), N (B-and-C), K (A-and-B) groups, with
explicit transposes when the groups are not contiguous in the given
layouts. The Union frontend enumerates candidate groupings, costs the GEMM
with any cost model (optionally + transpose DRAM traffic), and picks the
best algorithm per accelerator (native vs TTGT) -- the Fig. 8 case study.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.problem import Problem


@dataclass
class TTGTPlan:
    tc_name: str
    m_group: Tuple[str, ...]
    n_group: Tuple[str, ...]
    k_group: Tuple[str, ...]
    M: int
    N: int
    K: int
    needs_transpose_a: bool
    needs_transpose_b: bool
    needs_transpose_c: bool
    transpose_elems: int  # elements moved by the explicit transposes

    def gemm_problem(self, word_bytes: int = 1) -> Problem:
        return Problem.gemm(self.M, self.N, self.K,
                            name=f"{self.tc_name}_ttgt", word_bytes=word_bytes)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"TTGT(M={self.M}[{','.join(self.m_group)}] "
                f"N={self.N}[{','.join(self.n_group)}] "
                f"K={self.K}[{','.join(self.k_group)}])")


def _parse_tc(problem: Problem) -> Tuple[Tuple[str, ...], Tuple[str, ...], Tuple[str, ...]]:
    spec = problem.attrs.get("einsum")
    if not spec:
        raise ValueError("TTGT requires an einsum-annotated TC problem")
    lhs, rhs = spec.replace(" ", "").split("->")
    a, b = lhs.split(",")
    return tuple(a), tuple(b), tuple(rhs)


def _is_contiguous_suffix_prefix(order: Tuple[str, ...], group: Tuple[str, ...],
                                 where: str) -> bool:
    """True if `group` (as a set) appears contiguously at the given end of
    `order` in exactly the group's order (no transpose needed)."""
    k = len(group)
    if k == 0:
        return True
    seg = order[-k:] if where == "suffix" else order[:k]
    return tuple(seg) == tuple(group)


def enumerate_ttgt_plans(problem: Problem) -> List[TTGTPlan]:
    """Enumerate (M,N,K) groupings. Group membership is fixed by the einsum
    (an index is M, N, K, or batch); the enumeration is over the ORDER of
    indices inside each group (which changes transpose requirements).
    Batch indices (in A, B, and C) are folded into M.
    """
    a_idx, b_idx, c_idx = _parse_tc(problem)
    a_set, b_set, c_set = set(a_idx), set(b_idx), set(c_idx)
    k_set = (a_set & b_set) - c_set
    batch = a_set & b_set & c_set
    m_set = ((a_set & c_set) - b_set) | batch
    n_set = (b_set & c_set) - a_set
    dangling = (a_set | b_set | c_set) - (k_set | m_set | n_set)
    if dangling:
        raise ValueError(f"non-contractable indices {dangling} in {problem.name}")

    sizes = problem.dims
    M = math.prod(sizes[d] for d in m_set) if m_set else 1
    N = math.prod(sizes[d] for d in n_set) if n_set else 1
    K = math.prod(sizes[d] for d in k_set) if k_set else 1

    import itertools

    plans: List[TTGTPlan] = []
    m_orders = list(itertools.permutations(sorted(m_set)))[:24]
    n_orders = list(itertools.permutations(sorted(n_set)))[:24]
    k_orders = list(itertools.permutations(sorted(k_set)))[:24]
    a_elems = math.prod(sizes[d] for d in a_idx)
    b_elems = math.prod(sizes[d] for d in b_idx)
    c_elems = math.prod(sizes[d] for d in c_idx)
    for mo in m_orders:
        for no in n_orders:
            for ko in k_orders:
                # A must be laid out as [M-group..., K-group...] (row-major GEMM A)
                ta = not (
                    _is_contiguous_suffix_prefix(a_idx, tuple(ko), "suffix")
                    and _is_contiguous_suffix_prefix(a_idx, tuple(mo), "prefix")
                )
                tb = not (
                    _is_contiguous_suffix_prefix(b_idx, tuple(no), "suffix")
                    and _is_contiguous_suffix_prefix(b_idx, tuple(ko), "prefix")
                )
                tc_ = not (
                    _is_contiguous_suffix_prefix(c_idx, tuple(no), "suffix")
                    and _is_contiguous_suffix_prefix(c_idx, tuple(mo), "prefix")
                )
                elems = (a_elems * 2 if ta else 0) + (b_elems * 2 if tb else 0) + (
                    c_elems * 2 if tc_ else 0
                )
                plans.append(
                    TTGTPlan(
                        problem.name, tuple(mo), tuple(no), tuple(ko),
                        M, N, K, ta, tb, tc_, elems,
                    )
                )
    # dedupe by (ta,tb,tc) keeping min transpose volume; all share (M,N,K)
    best: Dict[Tuple[bool, bool, bool], TTGTPlan] = {}
    for p in plans:
        key = (p.needs_transpose_a, p.needs_transpose_b, p.needs_transpose_c)
        if key not in best or p.transpose_elems < best[key].transpose_elems:
            best[key] = p
    return sorted(best.values(), key=lambda p: p.transpose_elems)


def best_ttgt_plan(problem: Problem) -> TTGTPlan:
    return enumerate_ttgt_plans(problem)[0]


def transpose_cost(plan: TTGTPlan, arch, word_bytes: int = 1) -> Tuple[float, float]:
    """``(cycles, energy_pj)`` of the plan's explicit transposes at the
    outermost memory.

    ``plan.transpose_elems`` already counts one read plus one write per
    relaid-out element (the ``2x`` factor in :func:`enumerate_ttgt_plans`),
    so the element count IS the number of outermost-level accesses:

      * energy -- each access moves ``word_bytes`` at the outermost
        (non-virtual) level; half are reads, half writes;
      * cycles -- the relaid bytes stream through the boundary INTO the
        first real level below the outermost memory, limited by that
        level's fill bandwidth (0 when unbounded).

    The Fig. 8 benchmark adds these to the TTGT GEMM's cost before
    comparing EDP against the native contraction, as this module's header
    documents (`--no-transpose-cost` reproduces the uncosted numbers).
    """
    if plan.transpose_elems <= 0:
        return 0.0, 0.0
    real = [i for i, cl in enumerate(arch.clusters) if not cl.virtual]
    if not real:
        return 0.0, 0.0
    bytes_moved = plan.transpose_elems * word_bytes
    top = arch.clusters[real[0]]
    energy_pj = bytes_moved * (top.read_energy + top.write_energy) / 2.0
    cycles = 0.0
    for i in real[1:]:
        bw = arch.clusters[i].fill_bandwidth
        if not math.isinf(bw):
            cycles = bytes_moved * arch.frequency_hz / bw
            break
    return cycles, energy_pj
