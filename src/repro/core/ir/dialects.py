"""IR dialects: LayerOp -> EinsumGeneric -> AffineLoopNest.

These are deliberately small dataclasses, not a full SSA IR -- the point
(as in the paper) is the *abstraction boundaries*: the domain dialect knows
operator semantics, the generic dialect knows only contraction structure,
the affine dialect knows only loops + affine accesses. Each lowering step
discards exactly the information the next consumer does not need, while the
``operation`` annotation is carried through so operation-level cost models
(MAESTRO) still work after lowering (paper Sec. IV-B).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.problem import AffineExpr


@dataclass(frozen=True)
class TensorType:
    shape: Tuple[int, ...]
    dtype: str = "bf16"

    @property
    def elems(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def word_bytes(self) -> int:
        return {"bf16": 2, "f32": 4, "f16": 2, "i8": 1, "u8": 1, "i32": 4}[self.dtype]


@dataclass
class LayerOp:
    """Domain-level op (TOSA/COMET-TA analog)."""

    name: str
    kind: str  # linear | conv2d | dwconv | attention_qk | attention_pv |
    #            moe_gemm | embedding | ssd_chunk | lstm_cell | norm | ...
    inputs: Dict[str, TensorType]
    outputs: Dict[str, TensorType]
    params: Dict[str, object] = field(default_factory=dict)

    def __repr__(self) -> str:  # pragma: no cover
        ins = ", ".join(f"{k}:{list(v.shape)}" for k, v in self.inputs.items())
        return f"LayerOp({self.kind} {self.name} [{ins}])"


@dataclass
class EinsumGeneric:
    """Linalg-generic analog: iteration dims + per-operand affine maps."""

    name: str
    dims: Dict[str, int]  # iteration space
    operands: List[Tuple[str, Tuple[AffineExpr, ...], int]]  # (name, proj, word_bytes)
    result: Tuple[str, Tuple[AffineExpr, ...], int]
    operation: Optional[str] = None  # carried annotation for op-level models
    unit_op: str = "mac2"
    attrs: Dict[str, object] = field(default_factory=dict)


@dataclass
class AffineLoopNest:
    """Affine-dialect analog: perfectly nested loops + one MAC statement."""

    name: str
    loops: List[Tuple[str, int]]  # (iv, extent), outermost first
    reads: List[Tuple[str, Tuple[AffineExpr, ...], int]]
    write: Tuple[str, Tuple[AffineExpr, ...], int]
    operation: Optional[str] = None
    unit_op: str = "mac2"
    attrs: Dict[str, object] = field(default_factory=dict)

    def render(self) -> str:
        lines = []
        for i, (iv, ext) in enumerate(self.loops):
            lines.append("  " * i + f"affine.for %{iv} = 0 to {ext} {{")
        ind = "  " * len(self.loops)
        rhs = " * ".join(
            f"{n}[{', '.join(map(repr, proj))}]" for n, proj, _ in self.reads
        )
        wname, wproj, _ = self.write
        lines.append(ind + f"{wname}[{', '.join(map(repr, wproj))}] += {rhs}")
        for i in range(len(self.loops) - 1, -1, -1):
            lines.append("  " * i + "}")
        return "\n".join(lines)
