"""Progressive lowering: LayerOp -> EinsumGeneric -> AffineLoopNest -> Problem.

Mirrors the paper's pipeline (Fig. 2): domain dialect -> Linalg -> Affine ->
Union problem, with the operation annotation preserved end-to-end so both
operation-level and loop-level cost models can consume the result.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.ir.dialects import AffineLoopNest, EinsumGeneric, LayerOp, TensorType
from repro.core.problem import AffineExpr, DataSpace, Problem


# --------------------------------------------------------------------- #
# LayerOp -> EinsumGeneric
# --------------------------------------------------------------------- #
def _einsum_generic(
    name: str, spec: str, sizes: Dict[str, int], operation: str, wb: int = 2
) -> EinsumGeneric:
    lhs, rhs = spec.replace(" ", "").split("->")
    tokens = lhs.split(",")
    dims = {}
    for tok in tokens + [rhs]:
        for ch in tok:
            dims.setdefault(ch, int(sizes[ch]))
    operands = [
        (f"In{i}", tuple(AffineExpr.of(ch) for ch in tok), wb)
        for i, tok in enumerate(tokens)
    ]
    result = ("Out", tuple(AffineExpr.of(ch) for ch in rhs), wb)
    return EinsumGeneric(name, dims, operands, result, operation, attrs={"einsum": spec})


def layer_to_generic(op: LayerOp) -> EinsumGeneric:
    k = op.kind
    if k == "linear":
        x = op.inputs["x"].shape  # (B, In)  [B may be batch*seq, flattened]
        w = op.inputs["w"].shape  # (In, Out)
        wb = op.inputs["x"].word_bytes
        return _einsum_generic(op.name, "bi,io->bo", {"b": x[0], "i": x[1], "o": w[1]}, "GEMM", wb)
    if k == "embedding_gather":
        # gather is not a contraction; model as onehot-matmul for costing
        tok = op.inputs["ids"].shape
        emb = op.inputs["table"].shape
        g = _einsum_generic(
            op.name, "bv,vd->bd", {"b": tok[0], "v": emb[0], "d": emb[1]}, "GEMM",
            op.inputs["table"].word_bytes,
        )
        g.attrs["gather"] = True
        return g
    if k == "conv2d":
        p = op.params
        wb = p.get("word_bytes") or 2
        g = EinsumGeneric(
            op.name,
            {"n": p["N"], "k": p["K"], "x": p["X"], "y": p["Y"], "c": p["C"],
             "r": p["R"], "s": p["S"]},
            [
                ("Inputs", (
                    AffineExpr.of("n"), AffineExpr.of("c"),
                    AffineExpr.of((p.get("stride", 1), "x"), (1, "r")),
                    AffineExpr.of((p.get("stride", 1), "y"), (1, "s")),
                ), wb),
                ("Weights", (
                    AffineExpr.of("k"), AffineExpr.of("c"),
                    AffineExpr.of("r"), AffineExpr.of("s"),
                ), wb),
            ],
            ("Outputs", (
                AffineExpr.of("n"), AffineExpr.of("k"),
                AffineExpr.of("x"), AffineExpr.of("y"),
            ), wb),
            "CONV2D",
            attrs={"stride": p.get("stride", 1)},
        )
        return g
    if k == "attention_qk":
        p = op.params  # b=batch, h=heads, q/kv seq, d=head_dim
        return _einsum_generic(
            op.name, "bhqd,bhkd->bhqk",
            {"b": p["B"], "h": p["H"], "q": p["Q"], "k": p["KV"], "d": p["D"]},
            "ATTN_QK",
        )
    if k == "attention_pv":
        p = op.params
        return _einsum_generic(
            op.name, "bhqk,bhkd->bhqd",
            {"b": p["B"], "h": p["H"], "q": p["Q"], "k": p["KV"], "d": p["D"]},
            "ATTN_PV",
        )
    if k == "moe_gemm":
        p = op.params  # e experts, t tokens-per-expert, i/o dims
        return _einsum_generic(
            op.name, "eti,eio->eto",
            {"e": p["E"], "t": p["T"], "i": p["I"], "o": p["O"]},
            "GEMM",
        )
    if k == "ssd_chunk":
        p = op.params  # Mamba-2 chunked state update: (b,c,l,h,p)x(b,c,l,n)
        return _einsum_generic(
            op.name, "clhp,cln->chpn",
            {"c": p["C"], "l": p["L"], "h": p["H"], "p": p["P"], "n": p["N"]},
            "SSD",
        )
    if k == "tc":
        # generic einsum contraction; `operation`/`word_bytes` overrides let
        # shared builders (core.opstream) emit GEMM/SSD/... problems
        # bit-identical to the historical Problem.* constructors
        return _einsum_generic(
            op.name, op.params["einsum"], op.params["sizes"],
            op.params.get("operation") or "TC",
            op.params.get("word_bytes") or 2,
        )
    raise NotImplementedError(f"no lowering for LayerOp kind {k!r}")


# --------------------------------------------------------------------- #
# EinsumGeneric -> AffineLoopNest
# --------------------------------------------------------------------- #
def generic_to_affine(g: EinsumGeneric) -> AffineLoopNest:
    loops = [(d, s) for d, s in g.dims.items()]
    return AffineLoopNest(
        name=g.name,
        loops=loops,
        reads=[(n, proj, wb) for n, proj, wb in g.operands],
        write=g.result,
        operation=g.operation,
        unit_op=g.unit_op,
        attrs=dict(g.attrs),
    )


# --------------------------------------------------------------------- #
# AffineLoopNest -> Problem
# --------------------------------------------------------------------- #
def affine_to_problem(nest: AffineLoopNest) -> Problem:
    dims = {iv: ext for iv, ext in nest.loops}
    spaces: List[DataSpace] = []
    for n, proj, wb in nest.reads:
        spaces.append(DataSpace(n, tuple(proj), False, wb))
    wn, wproj, wwb = nest.write
    spaces.append(DataSpace(wn, tuple(wproj), True, wwb))
    p = Problem(nest.name, dims, tuple(spaces), operation=nest.operation,
                unit_op=nest.unit_op)
    p.attrs.update(nest.attrs)
    p.validate()
    return p


def lower_layer_to_problem(op: LayerOp) -> Problem:
    """Full pipeline for one op."""
    return affine_to_problem(generic_to_affine(layer_to_generic(op)))
