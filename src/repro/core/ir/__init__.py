"""Mini-MLIR dialect stack (paper Sec. III-A).

Three levels, mirroring the paper's TOSA/Linalg/Affine pipeline:

  LayerOp       -- domain op ("TOSA/TA-level"): linear, conv2d, attention...
  EinsumGeneric -- language-independent contraction ("Linalg-generic-level")
  AffineLoopNest-- perfectly-nested affine loops ("Affine-level")

plus the final lowering into a Union ``Problem`` and:

  ttgt          -- TC -> transpose-transpose-GEMM-transpose rewriting
                   (algorithm exploration, paper Sec. V-A)
  conformability-- cost-model-dependent conformability passes
  graph         -- model-config -> operator graph extraction
"""

from repro.core.ir.dialects import AffineLoopNest, EinsumGeneric, LayerOp, TensorType  # noqa: F401
from repro.core.ir.lowering import (  # noqa: F401
    affine_to_problem,
    layer_to_generic,
    generic_to_affine,
    lower_layer_to_problem,
)
from repro.core.ir.ttgt import TTGTPlan, enumerate_ttgt_plans, best_ttgt_plan  # noqa: F401
from repro.core.ir.conformability import conformable_models, ConformabilityReport  # noqa: F401
