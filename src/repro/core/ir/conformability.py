"""Cost-model-dependent conformability passes (paper Sec. III-A3).

Each pass embodies one cost model's input constraints; the router returns
the set of models that can evaluate a problem, so Union-opt never feeds a
model something it cannot understand (the paper's example: MTTKRP needs a
three-operand unit op and must be rejected by a mac2-configured Timeloop).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from repro.core.cost.base import CostModel
from repro.core.problem import Problem

MAESTRO_NATIVE_OPS = {"CONV2D", "GEMM", "DWCONV", "TC", "ATTN_QK", "ATTN_PV", "SSD"}


@dataclass
class ConformabilityReport:
    problem: str
    results: Dict[str, Tuple[bool, str]] = field(default_factory=dict)

    def ok(self, model_name: str) -> bool:
        return self.results.get(model_name, (False, "not checked"))[0]

    def render(self) -> str:
        lines = [f"conformability[{self.problem}]:"]
        for k, (ok, why) in self.results.items():
            lines.append(f"  {k}: {'OK' if ok else 'REJECT'} ({why})")
        return "\n".join(lines)


def check_operation_level(problem: Problem) -> Tuple[bool, str]:
    """MAESTRO-style: the op tag must be natively understood."""
    if problem.operation in MAESTRO_NATIVE_OPS and problem.unit_op == "mac2":
        return True, f"operation {problem.operation} natively supported"
    if problem.unit_op != "mac2":
        return False, f"unit op {problem.unit_op} != mac2 energy model"
    return False, f"operation {problem.operation!r} not in native set"


def check_loop_level(problem: Problem, unit_op: str = "mac2") -> Tuple[bool, str]:
    """Timeloop-style: perfectly-nested affine loops, no conditionals,
    loop reordering must not change the result, unit op must match the
    energy model configuration."""
    if problem.attrs.get("data_dependent"):
        return False, "data-dependent control flow (not perfectly nested)"
    if problem.attrs.get("gather"):
        return False, "gather access is not an affine projection"
    for ds in problem.data_spaces:
        for expr in ds.projection:
            if not expr.terms:
                return False, f"empty projection axis in {ds.name}"
    if problem.unit_op != unit_op:
        return False, f"unit op {problem.unit_op} != configured {unit_op}"
    return True, "perfectly-nested affine loop nest"


def conformable_models(
    problem: Problem, models: Sequence[CostModel]
) -> ConformabilityReport:
    rep = ConformabilityReport(problem.name)
    for m in models:
        if m.name == "maestro_like":
            rep.results[m.name] = check_operation_level(problem)
        elif m.name == "timeloop_like":
            rep.results[m.name] = check_loop_level(problem, getattr(m, "unit_op", "mac2"))
        else:
            rep.results[m.name] = (m.conformable(problem), "model-specific check")
    return rep
