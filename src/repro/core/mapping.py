"""Union mapping abstraction (paper Sec. IV-D).

*Cluster-target loop-centric* mapping: for EVERY cluster level C_i the
mapping specifies

  * ``temporal_order``       -- ordering of the temporal loops at this level,
  * ``temporal_tile_sizes``  -- TT_d^i  per problem dimension d,
  * ``spatial_tile_sizes``   -- ST_d^i  per problem dimension d.

Semantics (paper Sec. IV-D "Semantics and characteristics"):

  * The enclosing level hands this level a spatial tile ST^{i+1}
    (for the outermost level, ST^{n+1} := the full problem bounds).
  * That tile is processed in ``steps_i = prod_d ST_d^{i+1} / TT_d^i``
    temporal steps, iterated in ``temporal_order``.
  * Each temporal tile TT^i is split into ``par_i = prod_d TT_d^i / ST_d^i``
    spatial sub-tiles, distributed over the sub-cluster instances.
    Spatial loops at one level iterate CONCURRENTLY -- there is no
    spatial order, and several dims may be parallelized at once
    (this is what memory-target loop-centric abstractions cannot say).

Legality rules (paper Sec. IV-D, verbatim order):

  R1. ST_d^i >= TT_d^{i-1}                (spatial tile can hold the inner
                                           temporal tile)
  R2. TT_d^i / ST_d^i  (product over d) <= fanout of the (i-1) sub-clusters
  R3. non-virtual cluster memory >= sum of data-space footprints of TT^i
  R4. the mapping covers all iteration vectors of the problem
      (we additionally require divisor chains so coverage is exact).
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping as TMapping, Optional, Sequence, Tuple

from repro.core.architecture import Architecture
from repro.core.problem import Problem


def _prod(xs) -> int:
    return math.prod(xs) if xs else 1


def mapping_signature(mapping: "Mapping", dims: Sequence[str]):
    """Canonical hashable identity of a mapping's cost-relevant content.

    Per level: (effective temporal order, TT per dim, ST per dim), with the
    order normalized the way the reuse analysis normalizes it (declared
    order first, then missing dims in problem order). Mappings that differ
    only in how they *store* an equivalent order hash identically; equal
    signatures imply byte-identical analytical costs.
    """
    sig = []
    for lm in mapping.levels:
        declared = tuple(lm.temporal_order)
        order = declared + tuple(d for d in dims if d not in declared)
        tts = lm.temporal_tile_sizes
        sts = lm.spatial_tile_sizes
        sig.append(
            (
                order,
                tuple(int(tts.get(d, 1)) for d in dims),
                tuple(int(sts.get(d, 1)) for d in dims),
            )
        )
    return tuple(sig)


@dataclass
class LevelMapping:
    """Tiling directives targeting one cluster level (paper Fig. 5(d))."""

    cluster: str
    temporal_order: Tuple[str, ...]
    temporal_tile_sizes: Dict[str, int]
    spatial_tile_sizes: Dict[str, int]

    def tt(self, d: str) -> int:
        return int(self.temporal_tile_sizes.get(d, 1))

    def st(self, d: str) -> int:
        return int(self.spatial_tile_sizes.get(d, 1))

    def to_dict(self) -> dict:
        return {
            "target_cluster": self.cluster,
            "temporal_order": list(self.temporal_order),
            "temporal_tile_sizes": dict(self.temporal_tile_sizes),
            "spatial_tile_sizes": dict(self.spatial_tile_sizes),
        }


@dataclass
class Mapping:
    """A full mapping: one LevelMapping per cluster level, outermost first."""

    levels: List[LevelMapping]
    problem_name: str = ""

    # ------------------------------------------------------------------ #
    # Tile-chain accessors.  Index i: 0 == outermost level.
    # ------------------------------------------------------------------ #
    def outer_spatial_tile(self, i: int, problem: Problem) -> Dict[str, int]:
        """ST^{i+1} in paper terms: the tile handed to level i from outside."""
        if i == 0:
            return dict(problem.dims)
        return {d: self.levels[i - 1].st(d) for d in problem.dims}

    def temporal_trips(self, i: int, problem: Problem) -> Dict[str, int]:
        """Temporal loop trip count per dim at level i."""
        outer = self.outer_spatial_tile(i, problem)
        lm = self.levels[i]
        return {d: max(1, outer[d] // max(1, lm.tt(d))) for d in problem.dims}

    def spatial_fanout(self, i: int, problem: Problem) -> Dict[str, int]:
        """Spatial parallelism per dim at level i."""
        lm = self.levels[i]
        return {d: max(1, lm.tt(d) // max(1, lm.st(d))) for d in problem.dims}

    def parallelism(self, i: int, problem: Problem) -> int:
        return _prod(self.spatial_fanout(i, problem).values())

    def steps(self, i: int, problem: Problem) -> int:
        return _prod(self.temporal_trips(i, problem).values())

    def total_parallelism(self, problem: Problem) -> int:
        return _prod(self.parallelism(i, problem) for i in range(len(self.levels)))

    def utilization(self, problem: Problem, arch: Architecture) -> float:
        """Fraction of physical PEs (leaf clusters) used by this mapping."""
        return self.total_parallelism(problem) / max(1, arch.num_pes)

    # ------------------------------------------------------------------ #
    # Legality (paper's four rules + divisibility + constraint hooks)
    # ------------------------------------------------------------------ #
    def violations(self, problem: Problem, arch: Architecture) -> List[str]:
        errs: List[str] = []
        n = len(self.levels)
        if n != arch.n_levels:
            errs.append(f"mapping has {n} levels but architecture has {arch.n_levels}")
            return errs
        dims = problem.dims
        for i, lm in enumerate(self.levels):
            outer = self.outer_spatial_tile(i, problem)
            for d in dims:
                tt, st = lm.tt(d), lm.st(d)
                if tt < 1 or st < 1:
                    errs.append(f"L{i}:{d}: non-positive tile")
                    continue
                if outer[d] % tt != 0:
                    errs.append(f"R4 L{i}:{d}: TT={tt} does not divide outer tile {outer[d]}")
                if tt % st != 0:
                    errs.append(f"R4 L{i}:{d}: ST={st} does not divide TT={tt}")
                # R1: ST_d^i >= TT_d^{i-1} (inner level is i+1 in list order)
                if i + 1 < n:
                    inner_tt = self.levels[i + 1].tt(d)
                    if st < inner_tt:
                        errs.append(
                            f"R1 L{i}:{d}: spatial tile {st} < inner temporal tile {inner_tt}"
                        )
                    if st % max(1, inner_tt) != 0:
                        errs.append(
                            f"R4 L{i}:{d}: inner TT={inner_tt} does not divide ST={st}"
                        )
            # R2: parallelism bounded by sub-cluster fanout
            child_fanout = arch.clusters[i + 1].fanout if i + 1 < n else 1
            par = self.parallelism(i, problem)
            if par > child_fanout:
                errs.append(f"R2 L{i}: parallelism {par} > child fanout {child_fanout}")
            # R3: memory capacity at non-virtual levels
            cl = arch.clusters[i]
            if not cl.virtual and cl.memory_bytes is not None and i > 0:
                tile = {d: lm.tt(d) for d in dims}
                need = sum(ds.footprint_bytes(tile) for ds in problem.data_spaces)
                if need > cl.memory_bytes:
                    errs.append(
                        f"R3 L{i}({cl.name}): tile footprint {need}B > capacity {cl.memory_bytes}B"
                    )
            bad = set(lm.temporal_order) - set(dims)
            if bad:
                errs.append(f"L{i}: unknown dims in temporal_order: {sorted(bad)}")
        # innermost level: no sub-clusters -> TT == ST
        last = self.levels[-1]
        for d in dims:
            if last.tt(d) != last.st(d):
                errs.append(f"R2 L{n-1}:{d}: innermost level cannot parallelize (TT!=ST)")
        return errs

    def is_legal(self, problem: Problem, arch: Architecture) -> bool:
        """Early-exit legality predicate.

        Checks exactly the conditions ``violations`` reports, but returns on
        the first failure without building diagnostic strings -- this is on
        the hot path of every map-space sampler and neighborhood operator.
        Use ``violations`` when you need to know WHY a mapping is illegal.
        """
        n = len(self.levels)
        if n != arch.n_levels:
            return False
        dims = tuple(problem.dims.keys())
        dimset = set(dims)
        outer: TMapping[str, int] = problem.dims
        for i, lm in enumerate(self.levels):
            tts = lm.temporal_tile_sizes
            sts = lm.spatial_tile_sizes
            inner = self.levels[i + 1].temporal_tile_sizes if i + 1 < n else None
            par = 1
            for d in dims:
                tt = int(tts.get(d, 1))
                st = int(sts.get(d, 1))
                if tt < 1 or st < 1:
                    return False
                if outer[d] % tt or tt % st:
                    return False
                par *= tt // st
                if inner is not None:
                    itt = int(inner.get(d, 1))
                    if st < itt or st % max(1, itt):
                        return False
            child_fanout = arch.clusters[i + 1].fanout if i + 1 < n else 1
            if par > child_fanout:
                return False
            cl = arch.clusters[i]
            if not cl.virtual and cl.memory_bytes is not None and i > 0:
                tile = {d: int(tts.get(d, 1)) for d in dims}
                need = sum(ds.footprint_bytes(tile) for ds in problem.data_spaces)
                if need > cl.memory_bytes:
                    return False
            if not set(lm.temporal_order) <= dimset:
                return False
            outer = {d: int(sts.get(d, 1)) for d in dims}
        last = self.levels[-1]
        for d in dims:
            if last.tt(d) != last.st(d):
                return False
        return True

    def clone(self) -> "Mapping":
        """Fast deep copy (cheaper than a to_dict/from_dict round trip)."""
        return Mapping(
            [
                LevelMapping(
                    lm.cluster,
                    lm.temporal_order,
                    dict(lm.temporal_tile_sizes),
                    dict(lm.spatial_tile_sizes),
                )
                for lm in self.levels
            ],
            self.problem_name,
        )

    # ------------------------------------------------------------------ #
    # Rendering (paper Fig. 5(e)/Fig. 7 loop-nest form) + serialization
    # ------------------------------------------------------------------ #
    def loop_nest_str(self, problem: Problem) -> str:
        lines: List[str] = []
        indent = 0
        for i, lm in enumerate(self.levels):
            trips = self.temporal_trips(i, problem)
            spat = self.spatial_fanout(i, problem)
            lines.append("  " * indent + f"// {lm.cluster}")
            order = list(lm.temporal_order) + [d for d in problem.dims if d not in lm.temporal_order]
            for d in order:
                if trips[d] > 1:
                    lines.append("  " * indent + f"for {d}1 in [0:{trips[d]})")
                    indent += 1
            concurrent = [d for d in problem.dims if spat[d] > 1]
            if concurrent:
                decl = ", ".join(f"{d}0 in [0:{spat[d]})" for d in concurrent)
                lines.append("  " * indent + f"spatial_for ({decl})  // concurrent")
                indent += 1
        lines.append("  " * indent + f"compute({problem.name})")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {"problem": self.problem_name, "levels": [lm.to_dict() for lm in self.levels]}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @staticmethod
    def from_dict(d: dict) -> "Mapping":
        levels = [
            LevelMapping(
                cluster=l["target_cluster"],
                temporal_order=tuple(l["temporal_order"]),
                temporal_tile_sizes={k: int(v) for k, v in l["temporal_tile_sizes"].items()},
                spatial_tile_sizes={k: int(v) for k, v in l["spatial_tile_sizes"].items()},
            )
            for l in d["levels"]
        ]
        return Mapping(levels, d.get("problem", ""))

    @staticmethod
    def from_json(s: str) -> "Mapping":
        return Mapping.from_dict(json.loads(s))

    # ------------------------------------------------------------------ #
    # Builders
    # ------------------------------------------------------------------ #
    @staticmethod
    def trivial(problem: Problem, arch: Architecture) -> "Mapping":
        """All-temporal-at-top mapping: always legal iff tiles fit memory.

        Everything executes sequentially on one PE -- the worst legal
        mapping; useful as a search seed and in tests.
        """
        dims = problem.dim_names
        levels: List[LevelMapping] = []
        for i, cl in enumerate(arch.clusters):
            if i == 0:
                tt = {d: 1 for d in dims}
                st = {d: 1 for d in dims}
            else:
                tt = {d: 1 for d in dims}
                st = {d: 1 for d in dims}
            levels.append(LevelMapping(cl.name, tuple(dims), tt, st))
        # outermost level: temporal tile 1 per dim => trips = full dims
        return Mapping(levels, problem.name)

    @staticmethod
    def from_tiles(
        problem: Problem,
        arch: Architecture,
        tile_chain: Sequence[TMapping[str, int]],
        orders: Optional[Sequence[Sequence[str]]] = None,
    ) -> "Mapping":
        """Build from an explicit chain [(TT^n, ST^n), (TT^{n-1}, ST^{n-1}), ...]
        given as a flat list [TT0, ST0, TT1, ST1, ...] of dicts, outermost first.
        Missing dims default to 1.
        """
        assert len(tile_chain) == 2 * arch.n_levels
        dims = problem.dim_names
        levels = []
        for i, cl in enumerate(arch.clusters):
            tt = {d: int(tile_chain[2 * i].get(d, 1)) for d in dims}
            st = {d: int(tile_chain[2 * i + 1].get(d, 1)) for d in dims}
            order = tuple(orders[i]) if orders else tuple(dims)
            levels.append(LevelMapping(cl.name, order, tt, st))
        return Mapping(levels, problem.name)
