"""Device-resident search loops over the shape-generic fused program.

The evaluation engine's per-batch jax path round-trips host<->device every
miss-batch: stack, upload, dispatch, materialize, commit. For the search
loops whose candidate streams do not depend on the engine (random and
exhaustive sampling) or need only a scalar fitness per candidate (the
GA's selection), that cadence is pure overhead -- the candidates of many
batches can be scored by ONE mega-batch dispatch (or left on device and
materialized every K generations) with the host touched only at the sync
points for memo/ResultStore commits and incumbent export.

Two primitives, both strictly RESULT-PRESERVING:

``device_precompute(engine, batches)``
    Scores a window of pre-generated :class:`GenomeBatch` chunks as one
    fused dispatch of the shape-generic runner and hands each chunk its
    row-slice of the results as a :class:`PrecomputedScores`. The engine
    then replays each chunk through ``evaluate_batch(precomputed=...)``:
    dedup, memo/store probes, admission against the CURRENT incumbent and
    every counter run exactly as in the per-batch flow -- only the array
    dispatch is skipped (per-row values are batch-composition independent,
    so the mega-batch rows equal the per-batch rows bit for bit).

``DeviceGAScorer``
    Generation-resident GA scoring: each generation is dispatched with
    results left ON DEVICE; only the scalarized fitness vector (and the
    exactness guards) is fetched per generation -- population dynamics
    need nothing else. Every ``sync_cadence()`` generations the buffered
    device results are materialized and replayed through the engine in
    generation order, so incumbent tracking, trajectory, memo and store
    contents are identical to the host loop's (the GA never reads the
    tracker mid-generation and never prunes, so deferring the offers by K
    generations is observationally equivalent).

Every primitive degrades to ``None``/host-loop behavior when the runner
is unavailable (numpy backend, no generic terms, jax broken mid-flight)
or an exactness guard trips -- callers fall through to the unchanged
per-batch path, and results are identical either way.

Env knobs: ``UNION_DEVICE_LOOP=0`` disables the device loops wholesale;
``UNION_DEVICE_K`` sets the sync cadence (default 8 batches/generations
per host sync).
"""

from __future__ import annotations

import math
import os
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.cost.analysis import (
    BATCH_EXACT_LIMIT,
    StackedBatch,
    global_trace_count,
)
from repro.core.cost.engine import EvaluationEngine, PrecomputedScores
from repro.core.genome_batch import GenomeBatch

__all__ = [
    "sync_cadence",
    "device_loop_enabled",
    "device_precompute",
    "DeviceGAScorer",
]


def sync_cadence() -> int:
    """Batches/generations per host synchronization point (>=1).

    ``UNION_DEVICE_K`` overrides the default of 8; malformed values fall
    back to the default rather than crashing a sweep."""
    try:
        k = int(os.environ.get("UNION_DEVICE_K", "8"))
    except ValueError:
        return 8
    return max(1, k)


def device_loop_enabled(engine: EvaluationEngine) -> bool:
    """Whether the device-resident loops should even be attempted for
    this engine: jax backend and not globally disabled. The runner
    capability check happens lazily in the primitives (they return None
    and the caller keeps the host loop)."""
    return (
        os.environ.get("UNION_DEVICE_LOOP", "1") != "0"
        and engine.backend == "jax"
    )


def _precompute_runner(engine: EvaluationEngine):
    """The engine's fused runner iff it supports precompute (the
    shape-generic runner does; per-context closures do not)."""
    if not device_loop_enabled(engine):
        return None
    runner = engine._get_fused_runner()
    if runner is None or not getattr(runner, "supports_precompute", False):
        return None
    return runner


def _materialize(out, B: int) -> Optional[PrecomputedScores]:
    """Host :class:`PrecomputedScores` from one raw (possibly padded)
    device output tuple, or None when exactness cannot be honoured."""
    _admit, lb_mx, latency, energy, util, score_mx, extras = out
    if not (
        float(np.asarray(lb_mx)) < BATCH_EXACT_LIMIT
        and float(np.asarray(score_mx)) < BATCH_EXACT_LIMIT
    ):
        return None
    latency = np.asarray(latency)
    if latency.dtype != np.float64:
        return None  # x64 unavailable: bit-identity impossible
    extras_h = {k: np.asarray(v)[:B] for k, v in extras.items()}
    return PrecomputedScores(
        extras_h["lb_cycles"],
        extras_h["lb_energy"],
        latency[:B],
        np.asarray(energy)[:B],
        np.asarray(util)[:B],
        extras_h,
    )


def device_precompute(
    engine: EvaluationEngine, batches: Sequence[GenomeBatch]
) -> Optional[List[PrecomputedScores]]:
    """Score a window of batches as ONE fused dispatch; returns each
    batch's :class:`PrecomputedScores` row-slice, or None (caller keeps
    the per-batch host flow -- results identical either way).

    The dispatch runs with ``incumbent=inf`` (every row scored); the
    engine replays admission per batch against the then-current incumbent
    from the returned bound arrays, which equals the per-batch decision
    bit for bit. One host sync per window (``stats.device_syncs``)."""
    runner = _precompute_runner(engine)
    if runner is None or not batches:
        return None
    try:
        sbs = [gb.stacked() for gb in batches]
        mega = StackedBatch(
            np.ascontiguousarray(np.concatenate([s.tt for s in sbs])),
            np.ascontiguousarray(np.concatenate([s.st for s in sbs])),
            np.ascontiguousarray(np.concatenate([s.perm for s in sbs])),
        )
    except Exception:
        return None
    total = int(mega.tt.shape[0])
    before = global_trace_count()
    try:
        out = runner(mega, math.inf)
    finally:
        engine.stats.n_traces += global_trace_count() - before
    if out is None:
        return None
    _admit, lb_mx, latency, energy, util, score_mx, extras = out
    if not (lb_mx < BATCH_EXACT_LIMIT and score_mx < BATCH_EXACT_LIMIT):
        return None
    engine.stats.device_syncs += 1
    whole = PrecomputedScores(
        extras["lb_cycles"][:total],
        extras["lb_energy"][:total],
        latency[:total],
        energy[:total],
        util[:total],
        {k: v[:total] for k, v in extras.items()},
    )
    views: List[PrecomputedScores] = []
    off = 0
    for gb in batches:
        views.append(whole.select(slice(off, off + len(gb))))
        off += len(gb)
    return views


class DeviceGAScorer:
    """Generation-resident GA fitness with K-deferred host replay.

    ``score(gb)`` dispatches one generation and returns its float64
    fitness vector (the engine metric, scalarized on device) -- the only
    host transfer is that vector plus two guard scalars. The full device
    results are buffered; every :func:`sync_cadence` generations (and at
    :meth:`flush`) they are materialized and replayed IN ORDER through
    ``engine.evaluate_batch(gb, precomputed=...)``, with ``on_costs(gb,
    costs)`` invoked per generation so the caller's incumbent tracking
    sees the exact host-loop offer sequence.

    ``score`` returns None once the device path is unavailable (no
    generic runner, guard trip, jax failure); buffered generations are
    replayed first -- falling back to plain engine evaluation if their
    device buffers can no longer be read -- so no offer is ever lost and
    the caller can continue with the host loop mid-search."""

    def __init__(
        self,
        engine: EvaluationEngine,
        on_costs: Callable[[GenomeBatch, List], None],
    ) -> None:
        self._engine = engine
        self._on_costs = on_costs
        self._runner = _precompute_runner(engine)
        self._buf: List[tuple] = []  # (gb, raw device out)
        self._k = sync_cadence()

    @property
    def active(self) -> bool:
        return self._runner is not None

    def _disable(self) -> None:
        self.flush()
        self._runner = None

    def score(self, gb: GenomeBatch) -> Optional[np.ndarray]:
        if self._runner is None:
            return None
        runner = self._runner
        if getattr(runner, "dispatch_device", None) is None:
            self._disable()
            return None
        before = global_trace_count()
        try:
            out = runner.dispatch_device(gb.stacked())
        finally:
            self._engine.stats.n_traces += global_trace_count() - before
        if out is None:
            self._disable()
            return None
        try:
            _admit, lb_mx, _lat, _en, _ut, score_mx, extras = out
            # guards + fitness are the ONLY per-generation host transfers
            if not (
                float(np.asarray(lb_mx)) < BATCH_EXACT_LIMIT
                and float(np.asarray(score_mx)) < BATCH_EXACT_LIMIT
            ):
                self._disable()
                return None
            fitness = np.asarray(extras["metric_score"])[: len(gb)]
            if fitness.dtype != np.float64:
                self._disable()
                return None
        except Exception:
            self._disable()
            return None
        self._buf.append((gb, out))
        if len(self._buf) >= self._k:
            self.flush()
        return fitness

    def flush(self) -> None:
        """Materialize and replay every buffered generation, in order.
        One host sync for the whole buffer."""
        if not self._buf:
            return
        buf, self._buf = self._buf, []
        self._engine.stats.device_syncs += 1
        for gb, out in buf:
            try:
                pre = _materialize(out, len(gb))
            except Exception:
                pre = None  # device buffers gone (jax died): re-evaluate
            costs = self._engine.evaluate_batch(gb, precomputed=pre)
            self._on_costs(gb, costs)
