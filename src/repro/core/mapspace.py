"""Map-space definition, enumeration, sampling and mutation.

The map-space of (problem, architecture, constraints) is the set of legal
Union mappings. It is exponential/multiplicative (paper Sec. III-B3), so we
provide:

  * ``enumerate_tilings``  -- systematic divisor-chain enumeration with
    early pruning (fanout, memory, constraints), capped;
  * ``random_mapping``     -- uniform-ish rejection sampling with repair;
  * ``mutate`` / ``crossover`` -- neighborhood operators shared by the
    genetic and heuristic mappers.

All mappers consume THIS interface, which is what makes them interchangeable
across cost models (the paper's core interoperability claim).
"""

from __future__ import annotations

import itertools
import math
import random
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.architecture import Architecture
from repro.core.constraints import Constraints
from repro.core.mapping import LevelMapping, Mapping
from repro.core.problem import Problem


def divisors(n: int) -> List[int]:
    out = []
    i = 1
    while i * i <= n:
        if n % i == 0:
            out.append(i)
            if i != n // i:
                out.append(n // i)
        i += 1
    return sorted(out)


@dataclass
class MapSpace:
    problem: Problem
    arch: Architecture
    constraints: Optional[Constraints] = None

    def __post_init__(self) -> None:
        self.dims = list(self.problem.dims.keys())
        self.n_levels = self.arch.n_levels
        # spatial capability per mapping level: fanout of the child cluster
        self.child_fanout = [
            self.arch.clusters[i + 1].fanout if i + 1 < self.n_levels else 1
            for i in range(self.n_levels)
        ]
        self._div_cache: Dict[int, List[int]] = {}

    # ------------------------------------------------------------------ #
    def _divs(self, n: int) -> List[int]:
        if n not in self._div_cache:
            self._div_cache[n] = divisors(n)
        return self._div_cache[n]

    def size_log10(self) -> float:
        """Rough log10 of the number of tilings (ignoring orders)."""
        total = 0.0
        for d, s in self.problem.dims.items():
            nd = len(self._divs(s))
            total += 2 * self.n_levels * math.log10(max(nd, 1)) * 0.5
        # loop orders per level
        total += self.n_levels * math.log10(math.factorial(len(self.dims))) * 0.5
        return total

    # ------------------------------------------------------------------ #
    # Chain representation: per dim, a tuple of 2n divisors
    # (TT_0, ST_0, TT_1, ST_1, ..., TT_{n-1}, ST_{n-1}), nested:
    # full >= TT_0 >= ST_0 >= TT_1 >= ... and each divides the previous.
    # ------------------------------------------------------------------ #
    def _chain_to_mapping(
        self,
        chains: Dict[str, Tuple[int, ...]],
        orders: Optional[Sequence[Sequence[str]]] = None,
    ) -> Mapping:
        levels = []
        for i, cl in enumerate(self.arch.clusters):
            tt = {d: chains[d][2 * i] for d in self.dims}
            st = {d: chains[d][2 * i + 1] for d in self.dims}
            order = tuple(orders[i]) if orders else tuple(self.dims)
            levels.append(LevelMapping(cl.name, order, tt, st))
        return Mapping(levels, self.problem.name)

    def _sample_chain(self, rng: random.Random, size: int, spatial_slots: List[bool]) -> Tuple[int, ...]:
        """Sample one nested divisor chain for a dim of the given size."""
        chain: List[int] = []
        cur = size
        for i in range(self.n_levels):
            tt = rng.choice(self._divs(cur))
            if spatial_slots[i]:
                st = rng.choice(self._divs(tt))
            else:
                st = tt
            if i == self.n_levels - 1:
                st = tt  # innermost cannot parallelize
            chain.extend((tt, st))
            cur = st
        return tuple(chain)

    def random_mapping(self, rng: random.Random, max_tries: int = 200) -> Mapping:
        """Rejection-sample a legal mapping (with spatial repair)."""
        spatial_slots = [f > 1 for f in self.child_fanout]
        for _ in range(max_tries):
            chains = {}
            for d in self.dims:
                allowed_spatial = [
                    spatial_slots[i]
                    and (self.constraints is None
                         or self.constraints._spatial_ok(self.arch.clusters[i].name, d))
                    for i in range(self.n_levels)
                ]
                chains[d] = self._sample_chain(rng, self.problem.dims[d], allowed_spatial)
            # repair: clamp per-level parallelism to child fanout
            for i in range(self.n_levels):
                par = math.prod(chains[d][2 * i] // chains[d][2 * i + 1] for d in self.dims)
                while par > self.child_fanout[i]:
                    cand = [d for d in self.dims if chains[d][2 * i] // chains[d][2 * i + 1] > 1]
                    d = rng.choice(cand)
                    c = list(chains[d])
                    # grow ST toward TT by the smallest prime factor
                    ratio = c[2 * i] // c[2 * i + 1]
                    p = min(f for f in self._divs(ratio) if f > 1)
                    newst = c[2 * i + 1] * p
                    # rescale the rest of the chain below to keep nesting
                    c[2 * i + 1] = newst
                    for j in range(2 * i + 2, 2 * self.n_levels):
                        c[j] = math.gcd(c[j], newst) if c[j] > newst else c[j]
                        newst = c[j]
                    chains[d] = tuple(c)
                    par = math.prod(chains[d][2 * i] // chains[d][2 * i + 1] for d in self.dims)
            orders = [list(self.dims) for _ in range(self.n_levels)]
            for o in orders:
                rng.shuffle(o)
            if self.constraints is not None:
                for i, cl in enumerate(self.arch.clusters):
                    want = self.constraints.loop_orders.get(cl.name)
                    if want:
                        orders[i] = list(want) + [d for d in self.dims if d not in want]
            m = self._chain_to_mapping(chains, orders)
            if m.is_legal(self.problem, self.arch) and (
                self.constraints is None or self.constraints.ok(m, self.problem, self.arch)
            ):
                return m
        # guaranteed-legal fallback
        return Mapping.trivial(self.problem, self.arch)

    # ------------------------------------------------------------------ #
    def enumerate_tilings(
        self,
        max_mappings: Optional[int] = None,
        orders: str = "canonical",
        rng: Optional[random.Random] = None,
    ) -> Iterator[Mapping]:
        """Systematic enumeration of legal tilings with early pruning.

        ``orders``: 'canonical' uses the problem dim order at every level;
        'sampled' draws one random order per tiling (cheap diversification).
        """
        rng = rng or random.Random(0)
        spatial_slots = [f > 1 for f in self.child_fanout]

        def chains_for_dim(d: str) -> List[Tuple[int, ...]]:
            size = self.problem.dims[d]
            results: List[Tuple[int, ...]] = []

            def rec(cur: int, i: int, acc: List[int]) -> None:
                if i == self.n_levels:
                    results.append(tuple(acc))
                    return
                for tt in self._divs(cur):
                    st_opts = self._divs(tt) if (spatial_slots[i] and i < self.n_levels - 1) else [tt]
                    if self.constraints is not None and not self.constraints._spatial_ok(
                        self.arch.clusters[i].name, d
                    ):
                        st_opts = [tt]
                    for st in st_opts:
                        if tt // st > self.child_fanout[i]:
                            continue
                        rec(st, i + 1, acc + [tt, st])

            rec(size, 0, [])
            return results

        per_dim = {d: chains_for_dim(d) for d in self.dims}
        count = 0
        for combo in itertools.product(*(per_dim[d] for d in self.dims)):
            chains = dict(zip(self.dims, combo))
            # per-level fanout product prune
            ok = True
            for i in range(self.n_levels):
                par = math.prod(chains[d][2 * i] // chains[d][2 * i + 1] for d in self.dims)
                if par > self.child_fanout[i]:
                    ok = False
                    break
            if not ok:
                continue
            if orders == "sampled":
                ordset = []
                for _ in range(self.n_levels):
                    o = list(self.dims)
                    rng.shuffle(o)
                    ordset.append(o)
            else:
                ordset = None
            m = self._chain_to_mapping(chains, ordset)
            if not m.is_legal(self.problem, self.arch):
                continue
            if self.constraints is not None and not self.constraints.ok(m, self.problem, self.arch):
                continue
            yield m
            count += 1
            if max_mappings is not None and count >= max_mappings:
                return

    # ------------------------------------------------------------------ #
    # Neighborhood operators (used by genetic / heuristic mappers)
    # ------------------------------------------------------------------ #
    def mutate(self, mapping: Mapping, rng: random.Random, tries: int = 50) -> Mapping:
        """Random small move: re-sample one dim's chain, or permute one order."""
        for _ in range(tries):
            m = Mapping.from_dict(mapping.to_dict())
            move = rng.random()
            if move < 0.3:
                # permute a level's temporal order
                i = rng.randrange(self.n_levels)
                order = list(m.levels[i].temporal_order)
                if len(order) >= 2:
                    a, b = rng.sample(range(len(order)), 2)
                    order[a], order[b] = order[b], order[a]
                    m.levels[i].temporal_order = tuple(order)
            else:
                # re-sample one dim's chain
                d = rng.choice(self.dims)
                spatial_slots = [
                    f > 1 and (self.constraints is None
                               or self.constraints._spatial_ok(self.arch.clusters[i].name, d))
                    for i, f in enumerate(self.child_fanout)
                ]
                chain = self._sample_chain(rng, self.problem.dims[d], spatial_slots)
                for i in range(self.n_levels):
                    m.levels[i].temporal_tile_sizes[d] = chain[2 * i]
                    m.levels[i].spatial_tile_sizes[d] = chain[2 * i + 1]
            if m.is_legal(self.problem, self.arch) and (
                self.constraints is None or self.constraints.ok(m, self.problem, self.arch)
            ):
                return m
        return mapping

    def crossover(self, a: Mapping, b: Mapping, rng: random.Random, tries: int = 20) -> Mapping:
        """Per-dim uniform crossover of tile chains; orders from either parent."""
        for _ in range(tries):
            m = Mapping.from_dict(a.to_dict())
            for d in self.dims:
                src = a if rng.random() < 0.5 else b
                for i in range(self.n_levels):
                    m.levels[i].temporal_tile_sizes[d] = src.levels[i].temporal_tile_sizes[d]
                    m.levels[i].spatial_tile_sizes[d] = src.levels[i].spatial_tile_sizes[d]
            for i in range(self.n_levels):
                src = a if rng.random() < 0.5 else b
                m.levels[i].temporal_order = src.levels[i].temporal_order
            if m.is_legal(self.problem, self.arch) and (
                self.constraints is None or self.constraints.ok(m, self.problem, self.arch)
            ):
                return m
        return a
