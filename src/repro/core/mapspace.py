"""Map-space definition, enumeration, sampling and mutation.

The map-space of (problem, architecture, constraints) is the set of legal
Union mappings. It is exponential/multiplicative (paper Sec. III-B3), so we
provide:

  * ``enumerate_tilings``  -- systematic divisor-chain enumeration with
    early pruning (fanout, memory, constraints), capped;
  * ``random_mapping``     -- uniform-ish rejection sampling with repair;
  * ``mutate`` / ``crossover`` -- neighborhood operators shared by the
    genetic and heuristic mappers.

All mappers consume THIS interface, which is what makes them interchangeable
across cost models (the paper's core interoperability claim).

Hot-path note: samplers and neighborhood operators work on :class:`Genome`
-- the raw (divisor chains, loop orders) representation -- and only
materialize a :class:`Mapping` object when something actually needs it
(an evaluation cache miss, a constraint check, the final best). Legality
of chain-structured candidates is decided directly on the int tuples
(``_chains_legal``), which is equivalent to ``Mapping.is_legal`` for every
candidate these generators produce but an order of magnitude cheaper. The
RNG call sequence of every operator is part of its contract: genome ops
consume randomness exactly like the historical Mapping-based ops, so fixed
seeds reproduce identical searches.
"""

from __future__ import annotations

import functools
import math
import random
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.architecture import Architecture
from repro.core.constraints import Constraints
from repro.core.mapping import LevelMapping, Mapping
from repro.core.problem import Problem


@functools.lru_cache(maxsize=65536)
def _divisors_cached(n: int) -> Tuple[int, ...]:
    """Sorted divisors of ``n``, memoized process-wide.

    Shared across every MapSpace instance -- benchmark sweeps construct many
    spaces over the same dim sizes, so a per-instance cache wastes work.
    """
    out = []
    i = 1
    while i * i <= n:
        if n % i == 0:
            out.append(i)
            if i != n // i:
                out.append(n // i)
        i += 1
    return tuple(sorted(out))


def divisors(n: int) -> List[int]:
    return list(_divisors_cached(n))


# ---------------------------------------------------------------------- #
# Stream-identical RNG fast path. ``random.Random.choice`` / ``shuffle``
# spend most of their time in the pure-Python ``_randbelow`` wrapper; the
# samplers below inline the exact same getrandbits-rejection loop, so they
# consume the identical bit stream (fixed seeds reproduce the exact same
# candidates) at a fraction of the call overhead. Verified against the
# stdlib at import time; any mismatch (exotic interpreter) disables the
# fast path and the samplers fall back to the stdlib methods.
# ---------------------------------------------------------------------- #
def _verify_fast_rng() -> bool:
    try:
        ref = random.Random(987654321)
        tst = random.Random(987654321)
        for n in range(1, 40):
            seq = list(range(n))
            want = ref.choice(seq)
            k = n.bit_length()
            r = tst.getrandbits(k)
            while r >= n:
                r = tst.getrandbits(k)
            if seq[r] != want:
                return False
        xs = list(range(17))
        ys = list(xs)
        ref.shuffle(xs)
        gb = tst.getrandbits
        for i in range(len(ys) - 1, 0, -1):
            n = i + 1
            k = n.bit_length()
            r = gb(k)
            while r >= n:
                r = gb(k)
            ys[i], ys[r] = ys[r], ys[i]
        if xs != ys:
            return False
        # sample (both the pool branch and the selection-set branch)
        for n, k in ((10, 3), (40, 3), (60, 8)):
            seq = list(range(n))
            if ref.sample(seq, k) != _fast_sample(tst, seq, k):
                return False
        return True
    except Exception:
        return False


def _fast_choice(gb, seq):
    """``seq[rng._randbelow(len(seq))]`` via a pre-bound ``getrandbits``."""
    n = len(seq)
    k = n.bit_length()
    r = gb(k)
    while r >= n:
        r = gb(k)
    return seq[r]


def fast_sample(rng: random.Random, population, k: int) -> list:
    """Stream-identical ``rng.sample`` (falls back to the stdlib when the
    fast path is unavailable). Shared by the tournament selection in the
    genetic mapper."""
    if _FAST_RNG and type(rng) is random.Random:
        return _fast_sample(rng, population, k)
    return rng.sample(population, k)


def _fast_shuffle(gb, xs) -> None:
    for i in range(len(xs) - 1, 0, -1):
        n = i + 1
        k = n.bit_length()
        r = gb(k)
        while r >= n:
            r = gb(k)
        xs[i], xs[r] = xs[r], xs[i]


def _fast_sample(rng, population, k: int) -> list:
    """``rng.sample(population, k)`` consuming the identical bit stream
    (replicates CPython's pool/selection-set branch choice)."""
    n = len(population)
    if not 0 <= k <= n:
        return rng.sample(population, k)  # let stdlib raise identically
    gb = rng.getrandbits
    result = [None] * k
    setsize = 21
    if k > 5:
        setsize += 4 ** math.ceil(math.log(k * 3, 4))
    if n <= setsize:
        pool = list(population)
        for i in range(k):
            m = n - i
            kb = m.bit_length()
            j = gb(kb)
            while j >= m:
                j = gb(kb)
            result[i] = pool[j]
            pool[j] = pool[m - 1]
    else:
        selected = set()
        selected_add = selected.add
        kb = n.bit_length()
        for i in range(k):
            j = gb(kb)
            while j >= n:
                j = gb(kb)
            while j in selected:
                j = gb(kb)
                while j >= n:
                    j = gb(kb)
            selected_add(j)
            result[i] = population[j]
    return result



_FAST_RNG = _verify_fast_rng()

class Genome:
    """Chain-level candidate: per-dim divisor chains + per-level loop orders.

    ``chains[d]`` is the ``(TT_0, ST_0, ..., TT_{n-1}, ST_{n-1})`` tuple for
    dim ``d``; ``orders[i]`` is the full temporal order of level i. The
    evaluation engine consumes the genome directly (``signature`` for the
    memo cache, ``to_mapping`` only on a miss).
    """

    __slots__ = (
        "space",
        "chains",
        "orders",
        "_mapping",
        "_signature",
        "_sig_dims",
        "_chain_list",
    )

    def __init__(
        self,
        space: "MapSpace",
        chains: Dict[str, Tuple[int, ...]],
        orders: Tuple[Tuple[str, ...], ...],
    ) -> None:
        self.space = space
        self.chains = chains
        self.orders = orders
        self._mapping: Optional[Mapping] = None
        self._signature = None
        self._sig_dims = None
        self._chain_list: Optional[List[Tuple[int, ...]]] = None

    @property
    def chain_list(self) -> List[Tuple[int, ...]]:
        """Per-dim chains in problem-dim order (the form the chain-level
        lower bound consumes)."""
        if self._chain_list is None:
            chains = self.chains
            self._chain_list = [chains[d] for d in self.space.dims]
        return self._chain_list

    def cache_key(self, dims: Sequence[str]):
        """Cheap engine-cache key: (orders, chains) uniquely determine the
        canonical signature, so equal keys imply identical costs."""
        return (self.orders, tuple(self.chain_list))

    def signature(self, dims: Sequence[str]):
        """Same canonical signature ``engine.mapping_signature`` computes
        for the materialized mapping (orders here are always full)."""
        if self._signature is None:
            chains = self.chains
            chain_list = [chains[d] for d in dims]
            self._sig_dims = tuple(dims)
            sig = []
            for i in range(self.space.n_levels):
                k = 2 * i
                k1 = k + 1
                sig.append(
                    (
                        self.orders[i],
                        tuple(ch[k] for ch in chain_list),
                        tuple(ch[k1] for ch in chain_list),
                    )
                )
            self._signature = tuple(sig)
        return self._signature

    def to_mapping(self) -> Mapping:
        if self._mapping is None:
            self._mapping = self.space._chain_to_mapping(self.chains, self.orders)
            if self._signature is not None:
                # let the analysis pick the signature up without re-deriving
                self._mapping._sig_cache = (self._sig_dims, self._signature)
        return self._mapping


@dataclass
class MapSpace:
    problem: Problem
    arch: Architecture
    constraints: Optional[Constraints] = None

    def __post_init__(self) -> None:
        self.dims = list(self.problem.dims.keys())
        self.n_levels = self.arch.n_levels
        # spatial capability per mapping level: fanout of the child cluster
        self.child_fanout = [
            self.arch.clusters[i + 1].fanout if i + 1 < self.n_levels else 1
            for i in range(self.n_levels)
        ]
        self._chain_cache: Dict[str, List[Tuple[int, ...]]] = {}
        # spatial capability per (dim, level) incl. constraints -- fixed for
        # the lifetime of the space, so hoisted out of the samplers
        self._allowed_spatial: Dict[str, List[bool]] = {
            d: [
                self.child_fanout[i] > 1
                and (
                    self.constraints is None
                    or self.constraints._spatial_ok(self.arch.clusters[i].name, d)
                )
                for i in range(self.n_levels)
            ]
            for d in self.dims
        }
        # R3 data for chain-level legality: memory-capped levels + per-data-
        # space projections as (|coeff|, dim) terms
        self._mem_levels: List[Tuple[int, int]] = [
            (i, cl.memory_bytes)
            for i, cl in enumerate(self.arch.clusters)
            if not cl.virtual and cl.memory_bytes is not None and i > 0
        ]
        self._ds_axes: List[Tuple[int, List[List[Tuple[int, str]]]]] = [
            (
                ds.word_bytes,
                [[(abs(t.coeff), t.dim) for t in expr.terms] for expr in ds.projection],
            )
            for ds in self.problem.data_spaces
        ]

    # ------------------------------------------------------------------ #
    def _divs(self, n: int) -> Tuple[int, ...]:
        return _divisors_cached(n)

    def size_log10(self) -> float:
        """Rough log10 of the number of tilings (ignoring orders)."""
        total = 0.0
        for d, s in self.problem.dims.items():
            nd = len(self._divs(s))
            total += 2 * self.n_levels * math.log10(max(nd, 1)) * 0.5
        # loop orders per level
        total += self.n_levels * math.log10(math.factorial(len(self.dims))) * 0.5
        return total

    # ------------------------------------------------------------------ #
    # Chain representation: per dim, a tuple of 2n divisors
    # (TT_0, ST_0, TT_1, ST_1, ..., TT_{n-1}, ST_{n-1}), nested:
    # full >= TT_0 >= ST_0 >= TT_1 >= ... and each divides the previous.
    # ------------------------------------------------------------------ #
    def _chain_to_mapping(
        self,
        chains: Dict[str, Tuple[int, ...]],
        orders: Optional[Sequence[Sequence[str]]] = None,
    ) -> Mapping:
        levels = []
        for i, cl in enumerate(self.arch.clusters):
            tt = {d: chains[d][2 * i] for d in self.dims}
            st = {d: chains[d][2 * i + 1] for d in self.dims}
            order = tuple(orders[i]) if orders else tuple(self.dims)
            levels.append(LevelMapping(cl.name, order, tt, st))
        return Mapping(levels, self.problem.name)

    def _chains_legal(self, chains: Dict[str, Tuple[int, ...]]) -> bool:
        """``Mapping.is_legal`` specialized to chain-structured candidates.

        Valid for any candidate whose per-dim chain is a nested divisor
        chain with full per-level orders -- which is everything the
        samplers, neighborhood operators and the enumerator produce. The
        chain nesting itself is re-verified (cheap int ops), so this is
        equivalent to materializing + ``is_legal``.
        """
        n = self.n_levels
        pars = [1] * n
        for d, size in self.problem.dims.items():
            ch = chains[d]
            prev = size
            i = 0
            for k in range(0, 2 * n, 2):
                tt = ch[k]
                st = ch[k + 1]
                if tt < 1 or st < 1 or prev % tt or tt % st:
                    return False
                pars[i] *= tt // st
                prev = st
                i += 1
            if ch[2 * n - 2] != ch[2 * n - 1]:  # innermost cannot parallelize
                return False
        for i in range(n):
            if pars[i] > self.child_fanout[i]:
                return False
        for i, cap in self._mem_levels:
            need = 0
            for wb, axes in self._ds_axes:
                foot = 1
                for ax in axes:
                    span = 1
                    for coeff, d in ax:
                        span += coeff * (chains[d][2 * i] - 1)
                    foot *= span
                need += foot * wb
            if need > cap:
                return False
        return True

    def _constraints_ok(self, genome: Genome) -> bool:
        if self.constraints is None:
            return True
        return self.constraints.ok(genome.to_mapping(), self.problem, self.arch)

    def _sample_chain(self, rng: random.Random, size: int, spatial_slots: List[bool]) -> Tuple[int, ...]:
        """Sample one nested divisor chain for a dim of the given size."""
        chain: List[int] = []
        cur = size
        last = self.n_levels - 1
        if _FAST_RNG and type(rng) is random.Random:
            gb = rng.getrandbits
            for i in range(self.n_levels):
                divs = _divisors_cached(cur)
                n = len(divs)
                k = n.bit_length()
                r = gb(k)
                while r >= n:
                    r = gb(k)
                tt = divs[r]
                st = tt
                if spatial_slots[i]:
                    divs = _divisors_cached(tt)
                    n = len(divs)
                    k = n.bit_length()
                    r = gb(k)
                    while r >= n:
                        r = gb(k)
                    if i != last:
                        st = divs[r]
                chain.append(tt)
                chain.append(st)
                cur = st
            return tuple(chain)
        for i in range(self.n_levels):
            tt = rng.choice(self._divs(cur))
            if spatial_slots[i]:
                st = rng.choice(self._divs(tt))
            else:
                st = tt
            if i == self.n_levels - 1:
                st = tt  # innermost cannot parallelize
            chain.extend((tt, st))
            cur = st
        return tuple(chain)

    def random_genome(self, rng: random.Random, max_tries: int = 200) -> Genome:
        """Rejection-sample a legal candidate (with spatial repair)."""
        fast = _FAST_RNG and type(rng) is random.Random
        gb = rng.getrandbits if fast else None
        for _ in range(max_tries):
            chains: Dict[str, Tuple[int, ...]] = {}
            for d in self.dims:
                chains[d] = self._sample_chain(
                    rng, self.problem.dims[d], self._allowed_spatial[d]
                )
            # repair: clamp per-level parallelism to child fanout
            for i in range(self.n_levels):
                par = 1
                for d in self.dims:
                    ch = chains[d]
                    par *= ch[2 * i] // ch[2 * i + 1]
                while par > self.child_fanout[i]:
                    cand = [d for d in self.dims if chains[d][2 * i] // chains[d][2 * i + 1] > 1]
                    d = _fast_choice(gb, cand) if fast else rng.choice(cand)
                    c = list(chains[d])
                    # grow ST toward TT by the smallest prime factor
                    ratio = c[2 * i] // c[2 * i + 1]
                    p = min(f for f in self._divs(ratio) if f > 1)
                    newst = c[2 * i + 1] * p
                    # rescale the rest of the chain below to keep nesting
                    c[2 * i + 1] = newst
                    for j in range(2 * i + 2, 2 * self.n_levels):
                        c[j] = math.gcd(c[j], newst) if c[j] > newst else c[j]
                        newst = c[j]
                    chains[d] = tuple(c)
                    par = math.prod(chains[d][2 * i] // chains[d][2 * i + 1] for d in self.dims)
            orders = [list(self.dims) for _ in range(self.n_levels)]
            for o in orders:
                if fast:
                    _fast_shuffle(gb, o)
                else:
                    rng.shuffle(o)
            orders_ok = True
            if self.constraints is not None:
                dimset = set(self.dims)
                for i, cl in enumerate(self.arch.clusters):
                    want = self.constraints.loop_orders.get(cl.name)
                    if want:
                        orders[i] = list(want) + [d for d in self.dims if d not in want]
                        # constraint orders naming unknown dims are illegal
                        # (matches Mapping.is_legal's temporal_order check)
                        orders_ok &= set(want) <= dimset
            g = Genome(self, chains, tuple(tuple(o) for o in orders))
            if orders_ok and self._chains_legal(chains) and self._constraints_ok(g):
                return g
        # guaranteed-legal fallback: the all-serial trivial mapping
        ones = (1,) * (2 * self.n_levels)
        return Genome(
            self,
            {d: ones for d in self.dims},
            tuple(tuple(self.dims) for _ in range(self.n_levels)),
        )

    def random_mapping(self, rng: random.Random, max_tries: int = 200) -> Mapping:
        return self.random_genome(rng, max_tries).to_mapping()

    # ------------------------------------------------------------------ #
    def _chains_for_dim(self, d: str) -> List[Tuple[int, ...]]:
        """All legal nested divisor chains for one dim, cached per instance
        (problem/arch/constraints are fixed for a MapSpace, so repeated
        ``enumerate_tilings`` calls reuse the lists)."""
        cached = self._chain_cache.get(d)
        if cached is not None:
            return cached
        spatial_slots = [f > 1 for f in self.child_fanout]
        size = self.problem.dims[d]
        results: List[Tuple[int, ...]] = []

        def rec(cur: int, i: int, acc: List[int]) -> None:
            if i == self.n_levels:
                results.append(tuple(acc))
                return
            for tt in self._divs(cur):
                st_opts = self._divs(tt) if (spatial_slots[i] and i < self.n_levels - 1) else (tt,)
                if self.constraints is not None and not self.constraints._spatial_ok(
                    self.arch.clusters[i].name, d
                ):
                    st_opts = (tt,)
                for st in st_opts:
                    if tt // st > self.child_fanout[i]:
                        continue
                    rec(st, i + 1, acc + [tt, st])

        rec(size, 0, [])
        self._chain_cache[d] = results
        return results

    def enumerate_genomes(
        self,
        max_mappings: Optional[int] = None,
        orders: str = "canonical",
        rng: Optional[random.Random] = None,
    ) -> Iterator[Genome]:
        """Systematic enumeration of legal tilings with early pruning.

        ``orders``: 'canonical' uses the problem dim order at every level;
        'sampled' draws one random order per tiling (cheap diversification).
        """
        rng = rng or random.Random(0)
        n = self.n_levels
        per_dim = [self._chains_for_dim(d) for d in self.dims]
        # per-chain per-level spatial fanout vectors, precomputed once so the
        # product loop below multiplies ints instead of re-deriving them
        per_dim_fans = [
            [tuple(ch[2 * i] // ch[2 * i + 1] for i in range(n)) for ch in chains]
            for chains in per_dim
        ]
        ones = (1,) * n
        fanout = tuple(self.child_fanout)
        ndims = len(self.dims)
        canonical = tuple(tuple(self.dims) for _ in range(n))

        # depth-first product over per-dim chains with incremental per-level
        # fanout products: a prefix whose parallelism already exceeds the
        # child fanout at any level prunes its whole subtree (the remaining
        # dims can only multiply by >= 1). Yields exactly the combos the
        # naive product + post-filter admits, in the same order.
        def combos(di: int, acc: List[Tuple[int, ...]], fans: Tuple[int, ...]):
            if di == ndims:
                yield tuple(acc)
                return
            chains = per_dim[di]
            cfans = per_dim_fans[di]
            for ci in range(len(chains)):
                nf = tuple(a * b for a, b in zip(fans, cfans[ci]))
                if any(f > cap for f, cap in zip(nf, fanout)):
                    continue
                acc.append(chains[ci])
                yield from combos(di + 1, acc, nf)
                acc.pop()

        count = 0
        for combo in combos(0, [], ones):
            chains = dict(zip(self.dims, combo))
            if orders == "sampled":
                ordset = []
                for _ in range(n):
                    o = list(self.dims)
                    rng.shuffle(o)
                    ordset.append(tuple(o))
                ordset = tuple(ordset)
            else:
                ordset = canonical
            if not self._chains_legal(chains):
                continue
            g = Genome(self, chains, ordset)
            if not self._constraints_ok(g):
                continue
            yield g
            count += 1
            if max_mappings is not None and count >= max_mappings:
                return

    def enumerate_tilings(
        self,
        max_mappings: Optional[int] = None,
        orders: str = "canonical",
        rng: Optional[random.Random] = None,
    ) -> Iterator[Mapping]:
        for g in self.enumerate_genomes(max_mappings, orders, rng):
            yield g.to_mapping()

    # ------------------------------------------------------------------ #
    # Neighborhood operators (used by genetic / heuristic mappers)
    # ------------------------------------------------------------------ #
    def mutate_genome(self, genome: Genome, rng: random.Random, tries: int = 50) -> Genome:
        """Random small move: re-sample one dim's chain, or permute one order."""
        for _ in range(tries):
            chains = dict(genome.chains)
            orders = list(genome.orders)
            move = rng.random()
            if move < 0.3:
                # permute a level's temporal order
                i = rng.randrange(self.n_levels)
                order = list(orders[i])
                if len(order) >= 2:
                    a, b = rng.sample(range(len(order)), 2)
                    order[a], order[b] = order[b], order[a]
                    orders[i] = tuple(order)
            else:
                # re-sample one dim's chain
                if _FAST_RNG and type(rng) is random.Random:
                    d = _fast_choice(rng.getrandbits, self.dims)
                else:
                    d = rng.choice(self.dims)
                chains[d] = self._sample_chain(
                    rng, self.problem.dims[d], self._allowed_spatial[d]
                )
            g = Genome(self, chains, tuple(orders))
            if self._chains_legal(chains) and self._constraints_ok(g):
                return g
        return genome

    def crossover_genome(self, a: Genome, b: Genome, rng: random.Random, tries: int = 20) -> Genome:
        """Per-dim uniform crossover of tile chains; orders from either parent."""
        for _ in range(tries):
            chains: Dict[str, Tuple[int, ...]] = {}
            for d in self.dims:
                src = a if rng.random() < 0.5 else b
                chains[d] = src.chains[d]
            orders = []
            for i in range(self.n_levels):
                src = a if rng.random() < 0.5 else b
                orders.append(src.orders[i])
            g = Genome(self, chains, tuple(orders))
            if self._chains_legal(chains) and self._constraints_ok(g):
                return g
        return a

    # ------------------------------------------------------------------ #
    # Array-native batch generation (seed_version=2 samplers). The heavy
    # lifting lives in ``repro.core.genome_batch`` (imported lazily --
    # that module imports this one); these wrappers are the discoverable
    # entry points mirroring random_genome/enumerate_genomes.
    # ------------------------------------------------------------------ #
    def random_genome_batch(self, rng, k: int):
        """``k`` legal candidates as ONE dense :class:`GenomeBatch`
        (vectorized counter-based sampling; ``rng`` is a numpy Generator,
        see ``genome_batch.philox_rng``). Draws a different stream than
        ``random_genome`` -- the mappers version it as ``seed_version=2``."""
        from repro.core.genome_batch import random_genome_batch

        return random_genome_batch(self, rng, k)

    def enumerate_genome_batches(self, max_mappings=None, batch_size: int = 256):
        """The exhaustive candidate stream as :class:`GenomeBatch` chunks:
        vectorized mixed-radix decoding of the per-dim chain lists,
        bit-identical in content and order to ``enumerate_genomes`` with
        canonical orders and no constraints (callers gate on that)."""
        from repro.core.genome_batch import exhaustive_genome_batches

        return exhaustive_genome_batches(
            self, max_mappings=max_mappings, batch_size=batch_size
        )

    # Mapping-object compatibility wrappers (hill-climbers and external
    # callers hold Mappings; the genome ops above are the hot path).
    def _genome_of(self, mapping: Mapping) -> Genome:
        chains = {
            d: tuple(
                int(v)
                for lm in mapping.levels
                for v in (lm.temporal_tile_sizes.get(d, 1), lm.spatial_tile_sizes.get(d, 1))
            )
            for d in self.dims
        }
        orders = tuple(
            tuple(lm.temporal_order)
            + tuple(d for d in self.dims if d not in lm.temporal_order)
            for lm in mapping.levels
        )
        g = Genome(self, chains, orders)
        g._mapping = mapping
        return g

    def mutate(self, mapping: Mapping, rng: random.Random, tries: int = 50) -> Mapping:
        return self.mutate_genome(self._genome_of(mapping), rng, tries).to_mapping()

    def crossover(self, a: Mapping, b: Mapping, rng: random.Random, tries: int = 20) -> Mapping:
        return self.crossover_genome(
            self._genome_of(a), self._genome_of(b), rng, tries
        ).to_mapping()
