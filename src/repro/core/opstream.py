"""Whole-model operator streams: (ModelConfig, ShapeConfig) -> OpStream.

This is the layer that connects the five previously disconnected
subsystems into one pipeline:

  configs (ModelConfig/ShapeConfig)  ->  IR (LayerOp lowering)  ->
  OpStream [(Problem, multiplicity, role)]  ->  ONE union_opt_sweep
  (shared engines / memo / ResultStore / shape-class warmup)  ->
  multiplicity-weighted end-to-end latency / energy / EDP per model,
  cross-checked against launch/dryrun's ``cost_analysis()`` artifacts.

Design contract (docs/whole_model.md):

* **Every contraction-shaped op goes through the IR path.** The shared
  builders below (`build_gemm`, `build_conv2d`, `build_einsum`, the TCCG
  constructors) construct a ``LayerOp`` and run the full
  ``LayerOp -> EinsumGeneric -> AffineLoopNest -> Problem`` lowering --
  and are asserted BIT-IDENTICAL to the historical ad-hoc
  ``Problem.gemm``/``Problem.conv2d``/``Problem.from_einsum``
  constructors (tests/test_opstream.py), so ``benchmarks/workloads.py``
  and the fig3/fig8/fig10/fig11 problem tables sit on the same builders
  as the model streams.

* **Dedup by content, weight by multiplicity.** Content-equal problems
  (name excluded -- e.g. wk and wv, or the 26 identical MoE layers of
  deepseek-v2-lite) collapse into ONE entry whose ``multiplicity``
  counts how many times the op runs per model step. The sweep then
  searches each unique op once (the engine/store would dedup the cost
  anyway -- the stream dedups the *search*), and the aggregation
  multiplies costs back out.

* **Roles.** Each entry is tagged with the model component it came from
  (``embed / attention / attention_score / mlp / moe / router / ssm /
  ssm_scan / head``) so end-to-end EDP decomposes into a stacked
  per-role breakdown (benchmarks/plot_figures.py). ``PARAM_ROLES``
  mark the entries whose FLOPs correspond to parameter MACs -- the
  subset reconciled against the ``2 * active_params * tokens``
  MODEL_FLOPS convention that ``launch/dryrun.py`` embeds in every
  artifact (``formula_model_flops`` here is that same formula; dryrun
  imports it from this module).

* **Gather is costed, not mapped.** ``embedding_gather`` lowers to the
  onehot-matmul Problem the conformability pass rightly REJECTS for
  loop-level cost models (a gather is not an affine contraction), so
  its entry carries ``mappable=False``: it is excluded from the sweep
  and costed analytically (bandwidth term only) in the aggregation,
  while its onehot MACs still reconcile the embedding's share of
  MODEL_FLOPS.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union as TUnion

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, get_config
from repro.core.architecture import Architecture
from repro.core.ir.dialects import LayerOp, TensorType
from repro.core.ir.lowering import lower_layer_to_problem
from repro.core.problem import Problem

# --------------------------------------------------------------------- #
# Shared IR-routed builders (workloads.py + figure tables + streams)
# --------------------------------------------------------------------- #


def build_einsum(
    name: str,
    spec: str,
    sizes: Dict[str, int],
    operation: Optional[str] = None,
    word_bytes: int = 2,
) -> Problem:
    """Lower an einsum through the FULL IR pipeline (LayerOp -> generic ->
    affine -> Problem). Bit-identical to ``Problem.from_einsum`` -- the
    point is that every constructor routes through one lowering path."""
    op = LayerOp(
        name, "tc", {}, {},
        params={"einsum": spec, "sizes": dict(sizes),
                "operation": operation, "word_bytes": word_bytes},
    )
    return lower_layer_to_problem(op)


def build_gemm(M: int, N: int, K: int, name: str = "gemm", word_bytes: int = 2) -> Problem:
    """IR-routed equivalent of ``Problem.gemm`` (asserted bit-identical)."""
    return build_einsum(name, "mk,kn->mn", {"m": M, "k": K, "n": N}, "GEMM", word_bytes)


def build_conv2d(
    N: int, K: int, C: int, X: int, Y: int, R: int, S: int,
    stride: int = 1, name: str = "conv2d", word_bytes: int = 2,
) -> Problem:
    """IR-routed equivalent of ``Problem.conv2d`` (asserted bit-identical)."""
    op = LayerOp(
        name, "conv2d", {}, {},
        params=dict(N=N, K=K, C=C, X=X, Y=Y, R=R, S=S, stride=stride,
                    word_bytes=word_bytes),
    )
    return lower_layer_to_problem(op)


def build_tc_intensli2(tds: int, word_bytes: int = 2) -> Problem:
    return build_einsum(f"intensli2_tds{tds}", "dbea,ec->abcd",
                        {k: tds for k in "abcde"}, "TC", word_bytes)


def build_tc_ccsd7(tds: int, word_bytes: int = 2) -> Problem:
    return build_einsum(f"ccsd7_tds{tds}", "adec,ebd->abc",
                        {k: tds for k in "abcde"}, "TC", word_bytes)


def build_tc_ccsd_t4(tds: int, word_bytes: int = 2) -> Problem:
    return build_einsum(f"ccsd-t4_tds{tds}", "dfgb,geac->abcdef",
                        {k: tds for k in "abcdefg"}, "TC", word_bytes)


# --------------------------------------------------------------------- #
# OpStream
# --------------------------------------------------------------------- #

#: roles whose FLOPs are parameter MACs (reconciled against MODEL_FLOPS);
#: the complement (attention_score / ssm_scan) is activation-activation
#: compute the 2*N*T convention deliberately excludes.
PARAM_ROLES = ("embed", "attention", "mlp", "moe", "router", "ssm", "head")
SCORE_ROLES = ("attention_score", "ssm_scan")

#: documented tolerance band for stream-vs-formula FLOPs reconciliation
#: (see docs/whole_model.md): the stream may exceed the formula by the
#: MoE capacity factor (cf=1.25 on the routed-expert share) and the tied
#: lm-head term (added to the expectation explicitly), and may fall short
#: by the norm/bias/conv parameters the stream does not model (<~7%).
RECONCILE_BAND = (0.90, 1.40)


@dataclass
class OpEntry:
    """One deduplicated operator of a model step."""

    problem: Problem
    multiplicity: float  # executions per model step (fwd only; see backward_factor)
    role: str
    mappable: bool = True  # False => excluded from the sweep, costed analytically

    @property
    def flops(self) -> float:
        return self.multiplicity * self.problem.flops

    @property
    def bytes(self) -> float:
        return self.multiplicity * self.problem.total_tensor_bytes()


@dataclass
class OpStream:
    """Deduplicated operator stream of one (model, shape) cell."""

    model: str
    shape: str
    kind: str  # train | prefill | decode
    entries: List[OpEntry]
    backward_factor: float  # 3.0 for train (fwd+bwd), 1.0 otherwise
    meta: Dict[str, object] = field(default_factory=dict)

    def __iter__(self):
        return iter(self.entries)

    def __len__(self) -> int:
        return len(self.entries)

    def mappable_entries(self) -> List[OpEntry]:
        return [e for e in self.entries if e.mappable]

    def total_flops(self) -> float:
        """Multiplicity-weighted FLOPs per model step (incl. backward)."""
        return self.backward_factor * sum(e.flops for e in self.entries)

    def total_bytes(self) -> float:
        return self.backward_factor * sum(e.bytes for e in self.entries)

    def flops_by_role(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.entries:
            out[e.role] = out.get(e.role, 0.0) + self.backward_factor * e.flops
        return out

    def param_flops(self) -> float:
        """FLOPs of the parameter-MAC roles only (MODEL_FLOPS subset)."""
        return self.backward_factor * sum(
            e.flops for e in self.entries if e.role in PARAM_ROLES
        )


class _StreamBuilder:
    """Accumulates lowered ops with content-keyed dedup."""

    def __init__(self) -> None:
        self._order: List[OpEntry] = []
        self._index: Dict[tuple, OpEntry] = {}
        self.n_ops = 0.0  # pre-dedup op executions (multiplicity-weighted)

    @staticmethod
    def _content_key(p: Problem, role: str) -> tuple:
        return (
            role,
            tuple(p.dims.items()),
            tuple((ds.name, ds.projection, ds.is_output, ds.word_bytes)
                  for ds in p.data_spaces),
            p.operation,
            p.unit_op,
            tuple(sorted((k, repr(v)) for k, v in p.attrs.items())),
        )

    def add(self, problem: Problem, mult: float, role: str, mappable: bool = True) -> None:
        if mult <= 0:
            return
        self.n_ops += mult
        key = self._content_key(problem, role)
        e = self._index.get(key)
        if e is None:
            e = OpEntry(problem, float(mult), role, mappable)
            self._index[key] = e
            self._order.append(e)
        else:
            e.multiplicity += float(mult)

    def entries(self) -> List[OpEntry]:
        return list(self._order)


def _linear(name: str, tokens: int, d_in: int, d_out: int) -> Problem:
    return build_einsum(name, "bi,io->bo",
                        {"b": tokens, "i": d_in, "o": d_out}, "GEMM")


def _attention_ops(add, cfg: ModelConfig, prefix: str, B: int, T: int,
                   Q: int, KV: int) -> None:
    """Attention block: projection GEMMs + score/context einsums.

    GQA shapes come straight from the config (n_kv_heads < n_heads share
    KV); decode cells carry Q=1 at the serving batch size B."""
    d, h = cfg.d_model, cfg.n_heads
    if cfg.use_mla:
        r, dr = cfg.kv_lora_rank, cfg.rope_head_dim
        dn, dv = cfg.nope_head_dim, cfg.v_head_dim
        if cfg.q_lora_rank:
            add(_linear(f"{prefix}.q_down", T, d, cfg.q_lora_rank), 1, "attention")
            add(_linear(f"{prefix}.q_up", T, cfg.q_lora_rank, h * (dn + dr)), 1, "attention")
        else:
            add(_linear(f"{prefix}.wq", T, d, h * (dn + dr)), 1, "attention")
        add(_linear(f"{prefix}.kv_down", T, d, r), 1, "attention")
        add(_linear(f"{prefix}.k_rope", T, d, dr), 1, "attention")
        add(_linear(f"{prefix}.kv_up", T, r, h * (dn + dv)), 1, "attention")
        add(lower_layer_to_problem(LayerOp(
            f"{prefix}.qk", "attention_qk", {}, {},
            params=dict(B=B, H=h, Q=Q, KV=KV, D=dn + dr))), 1, "attention_score")
        add(lower_layer_to_problem(LayerOp(
            f"{prefix}.pv", "attention_pv", {}, {},
            params=dict(B=B, H=h, Q=Q, KV=KV, D=dv))), 1, "attention_score")
        add(_linear(f"{prefix}.wo", T, h * dv, d), 1, "attention")
    else:
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        add(_linear(f"{prefix}.wq", T, d, h * hd), 1, "attention")
        add(_linear(f"{prefix}.wk", T, d, kv * hd), 1, "attention")
        add(_linear(f"{prefix}.wv", T, d, kv * hd), 1, "attention")
        add(lower_layer_to_problem(LayerOp(
            f"{prefix}.qk", "attention_qk", {}, {},
            params=dict(B=B, H=h, Q=Q, KV=KV, D=hd))), 1, "attention_score")
        add(lower_layer_to_problem(LayerOp(
            f"{prefix}.pv", "attention_pv", {}, {},
            params=dict(B=B, H=h, Q=Q, KV=KV, D=hd))), 1, "attention_score")
        add(_linear(f"{prefix}.wo", T, h * hd, d), 1, "attention")


def _dense_ffn_ops(add, cfg: ModelConfig, prefix: str, T: int, d_ff: int) -> None:
    d = cfg.d_model
    if cfg.act in ("silu", "swiglu"):
        add(_linear(f"{prefix}.gate", T, d, d_ff), 1, "mlp")
        add(_linear(f"{prefix}.up", T, d, d_ff), 1, "mlp")
    else:
        add(_linear(f"{prefix}.up", T, d, d_ff), 1, "mlp")
    add(_linear(f"{prefix}.down", T, d_ff, d), 1, "mlp")


def moe_expert_capacity(cfg: ModelConfig, tokens: int) -> int:
    """Per-expert token capacity -- the SAME rule ``models/moe.py`` uses
    for dispatch: C = max(1, ceil(T * k * cf / e))."""
    e, k = cfg.n_routed_experts, cfg.top_k
    return max(1, int(math.ceil(tokens * k * cfg.capacity_factor / e)))


def _moe_ops(add, cfg: ModelConfig, prefix: str, T: int) -> None:
    """MoE layer: router GEMM + capacity-dispatched expert GEMMs (the
    ``moe_gemm`` LayerOp kind: E experts x C token slots) + shared-expert
    dense GEMMs. Active-expert multiplicity follows models/moe.py's
    capacity rule, so the stream FLOPs carry the same cf=1.25 padding
    the runtime dispatch pays."""
    d, de, e = cfg.d_model, cfg.d_expert, cfg.n_routed_experts
    add(_linear(f"{prefix}.router", T, d, e), 1, "router")
    C = moe_expert_capacity(cfg, T)
    up = lower_layer_to_problem(LayerOp(
        f"{prefix}.experts_up", "moe_gemm", {}, {},
        params=dict(E=e, T=C, I=d, O=de)))
    down = lower_layer_to_problem(LayerOp(
        f"{prefix}.experts_down", "moe_gemm", {}, {},
        params=dict(E=e, T=C, I=de, O=d)))
    add(up, 2, "moe")  # gate + up projections
    add(down, 1, "moe")
    for _ in range(cfg.n_shared_experts):
        add(_linear(f"{prefix}.shared_gate", T, d, de), 1, "moe")
        add(_linear(f"{prefix}.shared_up", T, d, de), 1, "moe")
        add(_linear(f"{prefix}.shared_down", T, de, d), 1, "moe")


def _ffn_ops(add, cfg: ModelConfig, prefix: str, T: int, layer_idx: int) -> None:
    """FFN for an attn layer, mirroring ModelConfig.num_params exactly:
    MoE past first_k_dense, dense (d_ff) before it / without experts."""
    if cfg.n_routed_experts and layer_idx >= cfg.first_k_dense:
        _moe_ops(add, cfg, prefix, T)
    elif cfg.n_routed_experts:
        if cfg.d_ff:
            _dense_ffn_ops(add, cfg, prefix, T, cfg.d_ff)
    elif cfg.d_ff:
        _dense_ffn_ops(add, cfg, prefix, T, cfg.d_ff)


_SSD_CHUNK = 256  # models/ssm.py mamba2_apply default


def _mamba2_ops(add, cfg: ModelConfig, prefix: str, B: int, T: int,
                S: int, decode: bool) -> None:
    """Mamba-2 block: projection GEMMs + the chunked-SSD scan contractions
    (models/ssm.py ``_ssd_chunked``) for train/prefill, or the O(1)
    recurrent state update for decode."""
    d, di = cfg.d_model, cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    nh, p = cfg.n_ssm_heads, cfg.ssm_head_dim
    add(_linear(f"{prefix}.in_z", T, d, di), 1, "ssm")
    add(_linear(f"{prefix}.in_x", T, d, di), 1, "ssm")
    add(_linear(f"{prefix}.in_B", T, d, g * n), 1, "ssm")
    add(_linear(f"{prefix}.in_C", T, d, g * n), 1, "ssm")
    add(_linear(f"{prefix}.in_dt", T, d, nh), 1, "ssm")
    # depthwise causal conv over x/B/C (macs == conv params * tokens)
    add(build_einsum(f"{prefix}.conv1d", "twc,wc->tc",
                     {"t": T, "w": cfg.conv_width, "c": di + 2 * g * n},
                     "DWCONV"), 1, "ssm")
    add(_linear(f"{prefix}.out", T, di, d), 1, "ssm")
    if decode:
        # recurrent step: state outer-product update + state read per token
        add(build_einsum(f"{prefix}.ssd_update", "bhp,bhn->bhpn",
                         {"b": B, "h": nh, "p": p, "n": n}, "SSD"), 1, "ssm_scan")
        add(build_einsum(f"{prefix}.ssd_read", "bhpn,bhn->bhp",
                         {"b": B, "h": nh, "p": p, "n": n}, "SSD"), 1, "ssm_scan")
        return
    chunk = min(_SSD_CHUNK, S)
    nc = B * max(1, S // chunk)  # batch folded into the chunk axis
    # intra-chunk scores C_i . B_j  (bclhn,bcshn->bchls)
    add(build_einsum(f"{prefix}.ssd_scores", "clhn,cshn->chls",
                     {"c": nc, "l": chunk, "s": chunk, "h": nh, "n": n},
                     "SSD"), 1, "ssm_scan")
    # diagonal-block output (bchls,bcshp->bclhp)
    add(build_einsum(f"{prefix}.ssd_diag", "chls,cshp->clhp",
                     {"c": nc, "l": chunk, "s": chunk, "h": nh, "p": p},
                     "SSD"), 1, "ssm_scan")
    # chunk-final states via the ssd_chunk LayerOp kind (clhp,cln->chpn)
    add(lower_layer_to_problem(LayerOp(
        f"{prefix}.ssd_state", "ssd_chunk", {}, {},
        params=dict(C=nc, L=chunk, H=nh, P=p, N=n))), 1, "ssm_scan")
    # inter-chunk contribution C_i . S_in  (bclhn,bchpn->bclhp)
    add(build_einsum(f"{prefix}.ssd_off", "clhn,chpn->clhp",
                     {"c": nc, "l": chunk, "h": nh, "p": p, "n": n},
                     "SSD"), 1, "ssm_scan")


def _mlstm_ops(add, cfg: ModelConfig, prefix: str, B: int, T: int,
               S: int, decode: bool) -> None:
    """mLSTM block: 5 d->d projections (q,k,v,gates,out -- matching the
    4d^2+d^2 parameter count) + matrix-memory recurrence, chunkwise for
    train/prefill (attention-like within a chunk + per-chunk d_head^2
    state update), O(1) recurrent for decode."""
    d, h = cfg.d_model, cfg.n_heads
    hd = d // max(1, h)
    add(_linear(f"{prefix}.qkv_gates", T, d, d), 5, "ssm")
    if decode:
        add(build_einsum(f"{prefix}.mem_update", "bhp,bhn->bhpn",
                         {"b": B, "h": h, "p": hd, "n": hd}, "SSD"), 1, "ssm_scan")
        add(build_einsum(f"{prefix}.mem_read", "bhpn,bhn->bhp",
                         {"b": B, "h": h, "p": hd, "n": hd}, "SSD"), 1, "ssm_scan")
        return
    chunk = min(_SSD_CHUNK, S)
    nc = B * max(1, S // chunk)
    add(build_einsum(f"{prefix}.scores", "chqd,chkd->chqk",
                     {"c": nc, "h": h, "q": chunk, "k": chunk, "d": hd},
                     "SSD"), 1, "ssm_scan")
    add(build_einsum(f"{prefix}.diag", "chqk,chkd->chqd",
                     {"c": nc, "h": h, "q": chunk, "k": chunk, "d": hd},
                     "SSD"), 1, "ssm_scan")
    add(build_einsum(f"{prefix}.mem_state", "chkd,chke->chde",
                     {"c": nc, "h": h, "k": chunk, "d": hd, "e": hd},
                     "SSD"), 1, "ssm_scan")
    add(build_einsum(f"{prefix}.mem_off", "chqd,chde->chqe",
                     {"c": nc, "h": h, "q": chunk, "d": hd, "e": hd},
                     "SSD"), 1, "ssm_scan")


def _slstm_ops(add, cfg: ModelConfig, prefix: str, T: int) -> None:
    """sLSTM block: 4 gate input projections (d->d) + 4 per-head recurrent
    GEMMs (hd x hd each, applied per token)."""
    d, h = cfg.d_model, cfg.n_heads
    hd = d // max(1, h)
    add(_linear(f"{prefix}.gates_in", T, d, d), 4, "ssm")
    add(build_einsum(f"{prefix}.gates_rec", "tghp,ghpn->tghn",
                     {"t": T, "g": 4, "h": h, "p": hd, "n": hd}, "GEMM"),
        1, "ssm")


def build_opstream(
    model: TUnion[str, ModelConfig],
    shape: TUnion[str, ShapeConfig],
    serving_batch: Optional[int] = None,
) -> OpStream:
    """Lower a (ModelConfig, ShapeConfig) cell into its deduplicated
    operator stream. ``serving_batch`` overrides the shape's global batch
    (decode cells at serving batch sizes)."""
    cfg = get_config(model) if isinstance(model, str) else model
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    B = int(serving_batch or sh.global_batch)
    S = sh.seq_len
    decode = sh.kind == "decode"
    Q = 1 if decode else S
    T = B * Q  # tokens processed per step
    if decode and not cfg.supports_decode:
        raise ValueError(f"{cfg.name} is encoder-only: no decode stream")

    b = _StreamBuilder()
    add = b.add

    # frontend projector (vlm/audio stubs): matches num_params' projector MLP
    if cfg.frontend != "none" and cfg.d_frontend:
        add(_linear("frontend.proj_in", T, cfg.d_frontend, cfg.d_model), 1, "embed")
        add(_linear("frontend.proj_mid", T, cfg.d_model, cfg.d_model), 1, "embed")

    # token embedding: gather, lowered to the onehot matmul the
    # conformability pass rejects for loop-level models -> mappable=False
    emb = lower_layer_to_problem(LayerOp(
        "embed", "embedding_gather",
        {"ids": TensorType((T,), "i32"),
         "table": TensorType((cfg.vocab, cfg.d_model))},
        {"y": TensorType((T, cfg.d_model))},
    ))
    add(emb, 1, "embed", mappable=False)

    for i, blk in enumerate(cfg.block_pattern * cfg.n_units):
        prefix = {"attn": "attn", "mamba2": "mamba2",
                  "mlstm": "mlstm", "slstm": "slstm"}[blk]
        if blk == "attn":
            _attention_ops(add, cfg, prefix, B, T, Q, S)
            if cfg.family not in ("hybrid",):
                _ffn_ops(add, cfg, prefix, T, i)
        elif blk == "mamba2":
            _mamba2_ops(add, cfg, prefix, B, T, S, decode)
        elif blk == "mlstm":
            _mlstm_ops(add, cfg, prefix, B, T, S, decode)
        elif blk == "slstm":
            _slstm_ops(add, cfg, prefix, T)

    # lm head (runs whether or not embeddings are tied)
    add(_linear("head", T, cfg.d_model, cfg.vocab), 1, "head")

    return OpStream(
        model=cfg.name,
        shape=sh.name,
        kind=sh.kind,
        entries=b.entries(),
        backward_factor=3.0 if sh.kind == "train" else 1.0,
        meta={
            "tokens_per_step": T,
            "global_batch": B,
            "seq_len": S,
            "n_ops_pre_dedup": b.n_ops,
            "n_unique": len(b.entries()),
        },
    )


# --------------------------------------------------------------------- #
# FLOPs reconciliation (MODEL_FLOPS convention + dryrun artifacts)
# --------------------------------------------------------------------- #


def formula_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The MODEL_FLOPS convention: 6*N_active*tokens (train) /
    2*N_active*tokens (prefill) / 2*N_active*batch (decode).
    ``launch/dryrun.py`` embeds this number in every artifact; it imports
    this function so the two sides cannot drift."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def reconcile_model_flops(stream: OpStream,
                          cfg: Optional[ModelConfig] = None) -> Dict[str, float]:
    """Reconcile the stream's parameter-role FLOPs against the
    MODEL_FLOPS formula. Returns the ratio + the documented correction
    terms; callers assert ``RECONCILE_BAND[0] <= ratio <= RECONCILE_BAND[1]``.

    Corrections applied to the expectation (docs/whole_model.md):
      * tied embeddings: the lm head still runs a full T x d x vocab GEMM
        but num_params counts vocab*d once -- add it back;
      * everything else (MoE capacity padding above, norm/bias/conv
        deficit below) is what the band absorbs.
    """
    cfg = cfg or get_config(stream.model)
    bf = stream.backward_factor
    T = float(stream.meta["tokens_per_step"])
    expected = 2.0 * T * cfg.active_params() * bf
    corrections = {}
    if cfg.tie_embeddings:
        tied = 2.0 * T * cfg.vocab * cfg.d_model * bf
        expected += tied
        corrections["tied_head_flops"] = tied
    got = stream.param_flops()
    return {
        "stream_param_flops": got,
        "expected_flops": expected,
        "formula_model_flops": formula_model_flops(
            cfg, ShapeConfig(stream.shape, int(stream.meta["seq_len"]),
                             int(stream.meta["global_batch"]), stream.kind)),
        "ratio": got / expected if expected else float("inf"),
        "corrections": corrections,
        "band": RECONCILE_BAND,
    }


def artifact_path(model: str, shape: str, mesh: str = "16x16",
                  art_dir: TUnion[str, Path] = "experiments/dryrun") -> Path:
    return Path(art_dir) / f"{model}__{shape}__{mesh}.json"


def reconcile_with_artifact(stream: OpStream, art: TUnion[dict, str, Path]) -> Dict[str, float]:
    """Cross-check the stream against a dryrun ``cost_analysis()``
    artifact: stream FLOPs vs the structure-corrected per-device FLOPs
    summed over chips, and the artifact's embedded MODEL_FLOPS (which
    must match ``formula_model_flops`` exactly -- same formula).

    The stream/HLO ratio shares dryrun's own useful-FLOPs band
    ((0.05, 1.1]): compiled HLO includes remat recompute, masking and
    vector work the stream does not model, so the stream is a lower
    bound up to small einsum-accounting slack."""
    if not isinstance(art, dict):
        art = json.loads(Path(art).read_text())
    corrected = art.get("corrected", art)
    hlo_total = float(corrected["flops_per_device"]) * float(art["chips"])
    bytes_total = float(corrected["bytes_per_device"]) * float(art["chips"])
    return {
        "stream_flops": stream.total_flops(),
        "hlo_flops": hlo_total,
        "flops_ratio": stream.total_flops() / hlo_total if hlo_total else float("inf"),
        "stream_bytes": stream.total_bytes(),
        "hlo_bytes": bytes_total,
        "bytes_ratio": stream.total_bytes() / bytes_total if bytes_total else float("inf"),
        "model_flops_artifact": float(art["model_flops"]),
        "collective_bytes_per_device": float(
            corrected.get("collective_bytes_per_device", 0.0)),
    }


def measured_collective_s(art: TUnion[dict, str, Path]) -> float:
    """The roofline collective term fed from MEASURED hloparse bytes: the
    artifact's per-device collective link bytes over the ICI link
    bandwidth (``RooflineReport.from_artifact`` semantics)."""
    from repro.core.cost.roofline import RooflineReport

    if not isinstance(art, dict):
        art = json.loads(Path(art).read_text())
    return RooflineReport.from_artifact(art.get("cell", "cell"), art).collective_s


# --------------------------------------------------------------------- #
# One-sweep driver + end-to-end aggregation
# --------------------------------------------------------------------- #


def stream_sweep_tasks(
    streams: Sequence[OpStream],
    arch: Architecture,
    mapper: str = "heuristic",
    cost_model: str = "timeloop",
    metric: str = "edp",
    constraints=None,
    mapper_kw: Optional[dict] = None,
):
    """Flatten model streams into ONE task list for ``union_opt_sweep``.
    Returns (tasks, index) where index[i] = (stream_idx, entry_idx) maps
    solutions back to entries (solutions come back in task order)."""
    from repro.core.optimizer import SweepTask

    tasks, index = [], []
    for si, stream in enumerate(streams):
        for ei, e in enumerate(stream.entries):
            if not e.mappable:
                continue
            tasks.append(SweepTask(
                e.problem, arch, mapper=mapper, cost_model=cost_model,
                metric=metric, constraints=constraints,
                mapper_kw=dict(mapper_kw or {}),
                tag=(stream.model, stream.shape, e.role, e.problem.name),
            ))
            index.append((si, ei))
    return tasks, index


def _gather_cost(problem: Problem, arch: Architecture) -> Tuple[float, float]:
    """Analytic (latency_s, energy_j) for a non-mappable gather entry:
    a pure bandwidth term (read one embedding row + write it per token)
    at DRAM energy -- NOT the onehot-matmul FLOPs, which exist only to
    reconcile MODEL_FLOPS."""
    out = problem.outputs()[0]
    move_bytes = 2.0 * out.footprint_bytes(problem.dims)  # row read + out write
    bw = next((c.fill_bandwidth for c in arch.clusters
               if math.isfinite(c.fill_bandwidth)), 1e9)
    dram = arch.clusters[0]
    energy_pj = move_bytes * (dram.read_energy + dram.write_energy) / 2.0
    return move_bytes / bw, energy_pj * 1e-12


@dataclass
class ModelCost:
    """Multiplicity-weighted end-to-end cost of one model stream."""

    model: str
    shape: str
    latency_s: float
    energy_j: float
    collective_s: float
    roles: Dict[str, Dict[str, float]]
    n_unique_ops: int
    n_ops: float

    @property
    def edp(self) -> float:
        return self.energy_j * (self.latency_s + self.collective_s)

    def row(self) -> Dict[str, object]:
        return {
            "model": self.model, "shape": self.shape,
            "latency_s": self.latency_s, "energy_j": self.energy_j,
            "collective_s": self.collective_s, "edp": self.edp,
            "roles": self.roles, "n_unique_ops": self.n_unique_ops,
            "n_ops": self.n_ops,
        }


def aggregate_stream_costs(
    streams: Sequence[OpStream],
    index: Sequence[Tuple[int, int]],
    solutions: Sequence,
    arch: Architecture,
    collective_s: Optional[Dict[str, float]] = None,
) -> List[ModelCost]:
    """Fold per-op sweep solutions back into per-model end-to-end costs.

    Latency is the serialized multiplicity-weighted sum of per-op
    latencies (ops of one step run back-to-back on the modeled
    accelerator), energy the weighted sum; EDP = total energy x total
    latency. Non-mappable entries (gathers) contribute their analytic
    bandwidth term. ``collective_s`` (per model name) adds the measured
    hloparse collective term as a serial component."""
    per_entry: Dict[Tuple[int, int], object] = {}
    for (si, ei), sol in zip(index, solutions):
        per_entry[(si, ei)] = sol
    out: List[ModelCost] = []
    for si, stream in enumerate(streams):
        bf = stream.backward_factor
        lat = en = 0.0
        roles: Dict[str, Dict[str, float]] = {}
        for ei, e in enumerate(stream.entries):
            sol = per_entry.get((si, ei))
            if sol is not None:
                l = bf * e.multiplicity * sol.cost.latency_s
                j = bf * e.multiplicity * sol.cost.energy_j
            elif not e.mappable:
                l0, j0 = _gather_cost(e.problem, arch)
                l = bf * e.multiplicity * l0
                j = bf * e.multiplicity * j0
            else:  # mappable entry whose task was skipped upstream
                continue
            lat += l
            en += j
            r = roles.setdefault(e.role, {"latency_s": 0.0, "energy_j": 0.0, "flops": 0.0})
            r["latency_s"] += l
            r["energy_j"] += j
            r["flops"] += bf * e.flops
        out.append(ModelCost(
            model=stream.model, shape=stream.shape,
            latency_s=lat, energy_j=en,
            collective_s=float((collective_s or {}).get(stream.model, 0.0)),
            roles=roles,
            n_unique_ops=len(stream.entries),
            n_ops=float(stream.meta.get("n_ops_pre_dedup", len(stream.entries))),
        ))
    return out
