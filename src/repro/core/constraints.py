"""Constraint files (paper Sec. IV-E).

Constraints encode accelerator-specific mapping restrictions so the
map-space can be pruned: allowed/required parallel dims per level
(e.g. NVDLA forces C and K parallel), fixed loop orders (dataflow styles:
weight/output/input/row stationary), feasible tile sizes, aspect ratios,
and min/max PE utilization.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.architecture import Architecture
from repro.core.mapping import Mapping
from repro.core.problem import Problem


@dataclass
class Constraints:
    """A constraint set. All fields optional; empty == fully flexible
    accelerator (paper: 'to describe a fully flexible accelerator like
    MAERI, the user will not provide constraint file')."""

    name: str = "flexible"
    # level name (or "*") -> set of dims allowed to be spatially distributed
    allowed_spatial_dims: Dict[str, Set[str]] = field(default_factory=dict)
    # level name -> dims that MUST be spatially distributed (NVDLA: {c, k})
    required_spatial_dims: Dict[str, Set[str]] = field(default_factory=dict)
    # level name -> required temporal order (outer->inner); prefix match
    loop_orders: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    # (level name, dim) -> allowed temporal tile sizes
    allowed_tile_sizes: Dict[Tuple[str, str], Set[int]] = field(default_factory=dict)
    # dim -> required multiple for the innermost (compute) tile, e.g. MXU=128
    tile_multiples: Dict[str, int] = field(default_factory=dict)
    # cap on CONCURRENTLY parallelized dims per cluster level. 1 emulates
    # memory-target loop-centric abstractions (Timeloop/Interstellar),
    # where one spatial_for binds one dim to one physical axis -- used by
    # the fig8 benchmark to reproduce the paper's native-TC results
    # faithfully before showing Union's richer space beats them.
    max_concurrent_spatial: Optional[int] = None
    min_utilization: float = 0.0
    max_utilization: float = 1.0

    def _spatial_ok(self, level: str, dim: str) -> bool:
        for key in (level, "*"):
            if key in self.allowed_spatial_dims:
                return dim in self.allowed_spatial_dims[key]
        return True

    def check(self, mapping: Mapping, problem: Problem, arch: Architecture) -> List[str]:
        errs: List[str] = []
        for i, lm in enumerate(mapping.levels):
            fan = mapping.spatial_fanout(i, problem)
            for d, f in fan.items():
                if f > 1 and not self._spatial_ok(lm.cluster, d):
                    errs.append(f"C:{lm.cluster}: dim {d} may not be spatial")
            if self.max_concurrent_spatial is not None:
                n_sp = sum(1 for f in fan.values() if f > 1)
                if n_sp > self.max_concurrent_spatial:
                    errs.append(
                        f"C:{lm.cluster}: {n_sp} concurrent spatial dims > "
                        f"cap {self.max_concurrent_spatial}"
                    )
            req = self.required_spatial_dims.get(lm.cluster, set())
            for d in req:
                if fan.get(d, 1) <= 1:
                    errs.append(f"C:{lm.cluster}: dim {d} must be spatial")
            order = self.loop_orders.get(lm.cluster)
            if order:
                trips = mapping.temporal_trips(i, problem)
                active = [d for d in lm.temporal_order if trips.get(d, 1) > 1]
                want = [d for d in order if d in active]
                got = [d for d in active if d in order]
                if want != got:
                    errs.append(f"C:{lm.cluster}: temporal order {got} violates required {want}")
            for d in problem.dims:
                allowed = self.allowed_tile_sizes.get((lm.cluster, d))
                if allowed is not None and lm.tt(d) not in allowed:
                    errs.append(f"C:{lm.cluster}:{d}: tile {lm.tt(d)} not in allowed set")
        innermost = mapping.levels[-1]
        for d, m in self.tile_multiples.items():
            if d in problem.dims:
                tt = innermost.tt(d)
                if tt % m != 0 and tt != problem.dims[d]:
                    errs.append(f"C:innermost:{d}: tile {tt} not a multiple of {m}")
        util = mapping.utilization(problem, arch)
        if util < self.min_utilization - 1e-9:
            errs.append(f"C:util {util:.3f} < min {self.min_utilization}")
        if util > self.max_utilization + 1e-9:
            errs.append(f"C:util {util:.3f} > max {self.max_utilization}")
        return errs

    def ok(self, mapping: Mapping, problem: Problem, arch: Architecture) -> bool:
        return not self.check(mapping, problem, arch)


def nvdla_style(conv_dims: Tuple[str, str] = ("c", "k")) -> Constraints:
    """Paper Sec. IV-E: NVDLA-style accelerator forces parallel C and K."""
    return Constraints(
        name="nvdla_style",
        allowed_spatial_dims={"*": set(conv_dims)},
        required_spatial_dims={},
        min_utilization=0.0,
    )


def weight_stationary(reduction_dims: Sequence[str], level: str) -> Constraints:
    """Keep weights resident: reduction loops innermost at the given level."""
    return Constraints(name="weight_stationary", loop_orders={level: tuple(reduction_dims)})


def mxu_aligned(dims: Sequence[str], multiple: int = 128) -> Constraints:
    """TPU MXU alignment: innermost compute tiles multiples of 128."""
    return Constraints(name="mxu_aligned", tile_multiples={d: multiple for d in dims})
