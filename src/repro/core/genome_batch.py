"""Array-native candidate generation for the map-space search pipeline.

PRs 1-4 made mapping EVALUATION a batched array program; this module makes
CANDIDATE GENERATION array-shaped too. A :class:`GenomeBatch` holds a whole
population of chain-level candidates as dense ``[B, n_levels, D]`` int64
matrices -- the exact layout :class:`repro.core.cost.analysis.StackedBatch`
consumes -- so a batch flows from the samplers through signature dedup,
admission and scoring without materializing per-candidate Python objects
(:class:`~repro.core.mapspace.Genome` / ``Mapping`` are built lazily, only
for scalar-path fallbacks and search winners).

Dedup is an array program as well: :meth:`GenomeBatch.key_rows` builds a
CANONICAL key matrix in one pass (each level's order reduced to its active
subsequence -- rows differing only in inactive-dim placement provably cost
the same and collapse), :meth:`GenomeBatch.dedup` row-hashes it with
``np.unique``, and :meth:`GenomeBatch.row_key` yields a key row's bytes --
the engine's memo key, strictly finer dedup than the old per-genome
``(orders, chains)`` tuple key and far cheaper to build.

Vectorized generation draws from a COUNTER-BASED RNG (numpy's Philox): one
array draw replaces thousands of per-candidate ``random.Random`` calls.
These draws consume a different stream than the historical samplers, so
the sampling mappers gate them behind ``seed_version=2`` (their default);
``seed_version=1`` preserves the bit-exact historical candidate stream.
For a fixed seed, version-2 candidates depend only on (seed, batch-call
sequence) -- generation is all-numpy and never touches the engine backend,
so searches are bit-identical across scalar/numpy/jax engines (asserted in
``tests/test_genome_batch.py``). The exhaustive enumerator needs no seed
version at all: its vectorized mixed-radix decoding reproduces the DFS
candidate stream exactly.

Legality of batch-generated candidates is decided by two array programs:
:func:`chains_legal_batch` (the vectorization of
``MapSpace._chains_legal``: nesting, innermost-serial, per-level fanout,
memory capacity) and :func:`constraints_ok_batch` (the vectorization of
``Constraints.check`` for chain-structured candidates whose constrained
loop orders were forced at generation -- never looser than the scalar
check; equality is asserted in tests).
"""

from __future__ import annotations

import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.mapspace import _divisors_cached

if False:  # typing only -- imported lazily to keep this module cycle-free
    from repro.core.cost.analysis import StackedBatch  # noqa: F401


def philox_rng(seed: int, salt: int = 0) -> np.random.Generator:
    """Counter-based generator for the version-2 samplers. ``salt``
    separates independent phases of one search (population init vs
    per-generation operators) without correlating their streams."""
    return np.random.Generator(np.random.Philox(key=(int(seed) << 32) + int(salt)))


# --------------------------------------------------------------------- #
# Per-(space, dim) divisor tables: the data the vectorized chain sampler
# gathers from. Built once per MapSpace and cached on the instance.
# --------------------------------------------------------------------- #
class _DimTables:
    __slots__ = ("vals", "idx_of", "div_val", "div_cnt", "spf")

    def __init__(self, size: int) -> None:
        vals = np.asarray(_divisors_cached(size), dtype=np.int64)
        nd = len(vals)
        idx_of = np.full(int(size) + 1, -1, dtype=np.int64)
        idx_of[vals] = np.arange(nd)
        rows = [_divisors_cached(int(v)) for v in vals]
        cnt = np.asarray([len(r) for r in rows], dtype=np.int64)
        div_val = np.empty((nd, int(cnt.max())), dtype=np.int64)
        for i, r in enumerate(rows):
            div_val[i, : len(r)] = r
            div_val[i, len(r) :] = r[-1]  # pad with the max: rows stay sorted
        spf = np.ones(nd, dtype=np.int64)
        for i, v in enumerate(vals.tolist()):
            if v > 1:
                f = 2
                while v % f:
                    f += 1
                spf[i] = f
        self.vals = vals
        self.idx_of = idx_of
        self.div_val = div_val  # div_val[i, k] = k-th divisor of vals[i]
        self.div_cnt = cnt
        self.spf = spf  # smallest prime factor of vals[i] (1 for 1)


@functools.lru_cache(maxsize=4096)
def _dim_tables_for_size(size: int) -> _DimTables:
    """Tables depend only on the dim SIZE -- shared process-wide, so the
    thousands of MapSpace instances a benchmark sweep builds pay the
    construction once per distinct size."""
    return _DimTables(size)


def _tables(space) -> Dict[str, _DimTables]:
    tabs = getattr(space, "_gb_tables", None)
    if tabs is None:
        tabs = {d: _dim_tables_for_size(space.problem.dims[d]) for d in space.dims}
        space._gb_tables = tabs
    return tabs


def _axes_idx(space) -> List[Tuple[int, List[List[Tuple[int, int]]]]]:
    """``(word_bytes, [[(|coeff|, dim_index), ...] per axis])`` per data
    space -- the index form of ``MapSpace._ds_axes`` the batched footprint
    program consumes."""
    axes = getattr(space, "_gb_axes", None)
    if axes is None:
        dim_index = {d: j for j, d in enumerate(space.dims)}
        axes = [
            (wb, [[(c, dim_index[d]) for c, d in ax] for ax in ds_axes])
            for wb, ds_axes in space._ds_axes
        ]
        space._gb_axes = axes
    return axes


class _LegalityConsts:
    """Per-space constants of the legality array program, built once.

    Footprints use DENSE coefficient matrices (``spans = 1 +
    (tt - 1) @ coeff.T``, one matmul per data space) -- a reassociation of
    the scalar span sum that is exact here because every quantity is an
    integer-valued float64 below 2**53; the LEGALITY verdicts are
    therefore still bit-equal to ``_chains_legal``. (Cost models never use
    this form: their float-op order is contractual.)"""

    __slots__ = ("sizes", "caps", "mem", "num_pes")

    def __init__(self, space) -> None:
        self.sizes = np.asarray(
            [space.problem.dims[d] for d in space.dims], dtype=np.int64
        )
        self.caps = np.asarray(space.child_fanout, dtype=np.float64)
        D = len(space.dims)
        dense = []
        for wb, ax in _axes_idx(space):
            A = max(1, len(ax))
            coeff = np.zeros((A, D), dtype=np.float64)
            for a, terms in enumerate(ax):
                for c, j in terms:
                    coeff[a, j] += c
            dense.append((float(wb), coeff))
        self.mem = [
            (lvl, float(cap), dense) for lvl, cap in space._mem_levels
        ]
        self.num_pes = max(1, space.arch.num_pes)


def _legality_consts(space) -> _LegalityConsts:
    lc = getattr(space, "_gb_legality", None)
    if lc is None:
        lc = _LegalityConsts(space)
        space._gb_legality = lc
    return lc


# --------------------------------------------------------------------- #
# GenomeBatch: the dense population representation
# --------------------------------------------------------------------- #
class GenomeBatch:
    """A batch of chain-level candidates as dense int64 matrices.

    ``tt[b, i, j]`` / ``st[b, i, j]`` are the temporal/spatial tile sizes
    of dim ``j`` (problem-dim order) at level ``i``; ``perm[b, i, p]`` is
    the dim index at position ``p`` of level ``i``'s (full) temporal
    order -- exactly the layout ``StackedBatch`` holds, so the evaluation
    engine stacks a miss-batch by slicing rows, with zero per-candidate
    work.
    """

    __slots__ = ("space", "tt", "st", "perm", "_rows2d", "_keys")

    def __init__(self, space, tt: np.ndarray, st: np.ndarray, perm: np.ndarray) -> None:
        self.space = space
        self.tt = np.ascontiguousarray(tt, dtype=np.int64)
        self.st = np.ascontiguousarray(st, dtype=np.int64)
        self.perm = np.ascontiguousarray(perm, dtype=np.int64)
        self._rows2d: Optional[np.ndarray] = None
        self._keys: Optional[np.ndarray] = None

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return int(self.tt.shape[0])

    @property
    def size(self) -> int:
        return len(self)

    @classmethod
    def from_genomes(cls, space, genomes: Sequence) -> "GenomeBatch":
        """Stack chain-level :class:`Genome` objects (or anything with
        ``chains``/``orders`` in their layout) into one batch."""
        n = space.n_levels
        dims = space.dims
        D = len(dims)
        B = len(genomes)
        count = B * n * D
        tt = np.fromiter(
            (g.chains[d][2 * i] for g in genomes for i in range(n) for d in dims),
            dtype=np.int64,
            count=count,
        ).reshape(B, n, D)
        st = np.fromiter(
            (g.chains[d][2 * i + 1] for g in genomes for i in range(n) for d in dims),
            dtype=np.int64,
            count=count,
        ).reshape(B, n, D)
        dim_index = {d: j for j, d in enumerate(dims)}
        perm = np.fromiter(
            (dim_index[d] for g in genomes for o in g.orders for d in o),
            dtype=np.int64,
            count=count,
        ).reshape(B, n, D)
        return cls(space, tt, st, perm)

    def select(self, idx) -> "GenomeBatch":
        """Row subset (slice or index array) as a new batch."""
        return GenomeBatch(self.space, self.tt[idx], self.st[idx], self.perm[idx])

    # ------------------------------------------------------------------ #
    def rows2d(self) -> np.ndarray:
        """``[B, 3*n*D]`` contiguous row matrix: the hashable identity of
        each candidate (tt, st, perm concatenated)."""
        if self._rows2d is None:
            B = len(self)
            self._rows2d = np.ascontiguousarray(
                np.concatenate(
                    [
                        self.tt.reshape(B, -1),
                        self.st.reshape(B, -1),
                        self.perm.reshape(B, -1),
                    ],
                    axis=1,
                )
            )
        return self._rows2d

    def key_rows(self) -> np.ndarray:
        """``[B, 3*n*D]`` canonical KEY matrix: like :meth:`rows2d` but
        with each level's order reduced to its ACTIVE subsequence (dims
        whose temporal trips exceed 1, in declared order; inactive slots
        pad with -1). The reuse analysis consumes only the active loops,
        so rows with equal key rows have bit-identical costs -- a strictly
        finer dedup than the per-genome ``(orders, chains)`` tuple key,
        computed as one array program over the batch."""
        if self._keys is None:
            B, n, D = self.tt.shape
            lc = _legality_consts(self.space)
            ttc = np.maximum(self.tt, 1)
            stc = np.maximum(self.st, 1)
            outer = np.concatenate(
                [np.broadcast_to(lc.sizes, (B, 1, D)), stc[:, :-1, :]], axis=1
            )
            active = (outer // ttc) > 1  # per dim, [B, n, D]
            act_pos = np.take_along_axis(active, self.perm, axis=2)
            pos = np.arange(D, dtype=np.int64)
            rank = np.where(act_pos, pos, pos + D)
            idx = np.argsort(rank, axis=2, kind="stable")
            cperm = np.take_along_axis(self.perm, idx, axis=2)
            cperm = np.where(np.take_along_axis(act_pos, idx, axis=2), cperm, -1)
            self._keys = np.ascontiguousarray(
                np.concatenate(
                    [
                        self.tt.reshape(B, -1),
                        self.st.reshape(B, -1),
                        cperm.reshape(B, -1),
                    ],
                    axis=1,
                )
            )
        return self._keys

    def row_key(self, b: int) -> bytes:
        """Engine memo key for row ``b``: the canonical key-row bytes
        (see :meth:`key_rows`). Equal keys imply bit-identical costs."""
        return self.key_rows()[b].tobytes()

    def dedup(self) -> Tuple[np.ndarray, np.ndarray]:
        """In-batch dedup as ONE array program (``np.unique`` over the row
        matrix) instead of a per-candidate dict probe. Returns
        ``(rep, inverse)``: ``rep`` lists the first-occurrence row index
        of every distinct candidate IN SUBMISSION ORDER, and
        ``inverse[b]`` is the position in ``rep`` representing row ``b``.
        Distinctness is by the canonical :meth:`key_rows` identity (rows
        that provably cost the same are one candidate)."""
        r = self.key_rows()
        _, first, inv = np.unique(r, axis=0, return_index=True, return_inverse=True)
        inv = inv.reshape(-1)
        order = np.argsort(first, kind="stable")
        rep = first[order]
        rank = np.empty(len(order), dtype=np.int64)
        rank[order] = np.arange(len(order))
        return rep, rank[inv]

    def stacked(self, rows=None) -> "StackedBatch":
        """A :class:`StackedBatch` over all rows (or the given subset) --
        shared by the engine's admission and scoring array programs."""
        from repro.core.cost.analysis import StackedBatch

        if rows is None:
            return StackedBatch(self.tt, self.st, self.perm)
        idx = np.asarray(rows, dtype=np.int64)
        return StackedBatch(
            np.ascontiguousarray(self.tt[idx]),
            np.ascontiguousarray(self.st[idx]),
            np.ascontiguousarray(self.perm[idx]),
        )

    # ------------------------------------------------------------------ #
    def orders_of(self, b: int) -> Tuple[Tuple[str, ...], ...]:
        dims = self.space.dims
        return tuple(
            tuple(dims[p] for p in row) for row in self.perm[b].tolist()
        )

    def signature(self, b: int):
        """Canonical signature of row ``b`` -- identical to
        ``Genome.signature`` for the equivalent genome (orders are full)."""
        tt = self.tt[b].tolist()
        st = self.st[b].tolist()
        return tuple(
            (order, tuple(trow), tuple(srow))
            for order, trow, srow in zip(self.orders_of(b), tt, st)
        )

    def genome(self, b: int):
        """Materialize row ``b`` as a chain-level Genome (lazy import: the
        mapspace module does not import this one)."""
        from repro.core.mapspace import Genome

        space = self.space
        n = space.n_levels
        tt = self.tt[b].tolist()
        st = self.st[b].tolist()
        chains = {
            d: tuple(v for i in range(n) for v in (tt[i][j], st[i][j]))
            for j, d in enumerate(space.dims)
        }
        return Genome(space, chains, self.orders_of(b))


class RowCandidate:
    """Lazy per-row view of a :class:`GenomeBatch`: the candidate object
    the engine hands to its scalar fallbacks (bound, per-candidate
    evaluation, store puts) and to the mapper's incumbent tracker. The
    underlying Genome/Mapping is built only when actually consumed."""

    __slots__ = ("gb", "row", "_g", "_sig")

    def __init__(self, gb: GenomeBatch, row: int) -> None:
        self.gb = gb
        self.row = int(row)
        self._g = None
        self._sig = None

    def _genome(self):
        if self._g is None:
            self._g = self.gb.genome(self.row)
        return self._g

    def signature(self, dims):
        if self._sig is None:
            self._sig = self.gb.signature(self.row)
        return self._sig

    def to_mapping(self):
        return self._genome().to_mapping()

    @property
    def chain_list(self):
        return self._genome().chain_list

    @property
    def orders(self):
        return self.gb.orders_of(self.row)


# --------------------------------------------------------------------- #
# Vectorized legality: the array form of MapSpace._chains_legal
# --------------------------------------------------------------------- #
def chains_legal_batch(
    space, tt: np.ndarray, st: np.ndarray, structured: bool = False
) -> np.ndarray:
    """Bool mask over the batch: exactly ``MapSpace._chains_legal`` per
    row (nested divisor chains, innermost-serial, per-level fanout caps,
    memory capacity), as one array program. Quantities are integer-valued
    float64 where products could overflow int64 -- exact below 2**53,
    far above any realistic footprint/fanout here.

    ``structured=True`` skips the nesting/positivity/innermost checks:
    valid ONLY for rows assembled from per-dim chain COLUMNS that are
    nested divisor chains by construction (the samplers, fanout repair,
    column crossover, column re-sampling -- everything in this module).
    The verdicts are identical for such rows; arbitrary foreign rows must
    use the full check."""
    B, n, D = tt.shape
    lc = _legality_consts(space)
    ttc = np.maximum(tt, 1)
    stc = np.maximum(st, 1)
    if structured:
        ok = np.ones(B, dtype=bool)
    else:
        outer = np.concatenate(
            [np.broadcast_to(lc.sizes, (B, 1, D)), stc[:, :-1, :]], axis=1
        )
        # nesting + positivity + innermost-serial in one violation matrix
        bad = (tt < 1) | (st < 1) | ((outer % ttc) != 0) | ((ttc % stc) != 0)
        bad[:, -1, :] |= tt[:, -1, :] != st[:, -1, :]
        ok = ~bad.reshape(B, -1).any(axis=1)
    fans = (ttc // stc).astype(np.float64)
    par = fans.prod(axis=2)  # [B, n]
    ok &= (par <= lc.caps).all(axis=1)
    for lvl, cap, dense in lc.mem:
        need = np.zeros(B, dtype=np.float64)
        tm1 = ttc[:, lvl, :].astype(np.float64) - 1.0
        for wb, coeff in dense:
            spans = 1.0 + tm1 @ coeff.T  # [B, A], exact (integer-valued)
            need += spans.prod(axis=1) * wb
        ok &= need <= cap
    return ok


def constraints_ok_batch(
    space, tt: np.ndarray, st: np.ndarray, perm: np.ndarray
) -> np.ndarray:
    """Bool mask: ``Constraints.check`` vectorized for chain-structured
    candidates. For levels with a forced loop order the check requires the
    EXACT forced permutation (the batch samplers force it at generation),
    which is never looser than the scalar active-dims check; every other
    field (allowed/required spatial dims, concurrent-spatial cap, allowed
    tile sizes, tile multiples, utilization bounds) replays the scalar
    comparisons, tolerances included."""
    cons = space.constraints
    B, n, D = tt.shape
    ok = np.ones(B, dtype=bool)
    if cons is None:
        return ok
    dims = space.dims
    dim_index = {d: j for j, d in enumerate(dims)}
    ttc = np.maximum(tt, 1)
    stc = np.maximum(st, 1)
    fan = np.maximum(ttc // stc, 1)
    for i, cl in enumerate(space.arch.clusters):
        name = cl.name
        f = fan[:, i, :]
        for j, d in enumerate(dims):
            if not cons._spatial_ok(name, d):
                ok &= f[:, j] <= 1
        if cons.max_concurrent_spatial is not None:
            ok &= (f > 1).sum(axis=1) <= cons.max_concurrent_spatial
        req = cons.required_spatial_dims.get(name)
        if req:
            for d in req:
                if d in dim_index:
                    ok &= f[:, dim_index[d]] > 1
                else:
                    ok &= False
        want = cons.loop_orders.get(name)
        if want:
            if not set(want) <= set(dims):
                ok &= False
            else:
                forced = np.asarray(
                    [dim_index[d] for d in want]
                    + [j for j, d in enumerate(dims) if d not in want],
                    dtype=np.int64,
                )
                ok &= (perm[:, i, :] == forced).all(axis=1)
        for j, d in enumerate(dims):
            allowed = cons.allowed_tile_sizes.get((name, d))
            if allowed is not None:
                ok &= np.isin(
                    tt[:, i, j], np.asarray(sorted(allowed), dtype=np.int64)
                )
    for d, m in cons.tile_multiples.items():
        if d in dim_index:
            j = dim_index[d]
            tin = tt[:, -1, j]
            ok &= ((tin % m) == 0) | (tin == space.problem.dims[d])
    par = fan.astype(np.float64).reshape(B, -1).prod(axis=1)
    util = par / max(1, space.arch.num_pes)
    ok &= util >= cons.min_utilization - 1e-9
    ok &= util <= cons.max_utilization + 1e-9
    return ok


def legal_batch(space, tt, st, perm, structured: bool = False) -> np.ndarray:
    return chains_legal_batch(space, tt, st, structured=structured) & (
        constraints_ok_batch(space, tt, st, perm)
    )


# --------------------------------------------------------------------- #
# Vectorized samplers (seed_version=2)
# --------------------------------------------------------------------- #
def sample_chain_cols(
    space,
    rng: np.random.Generator,
    j: int,
    B: int,
    start: Optional[np.ndarray] = None,
    from_level: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """``B`` nested divisor chains for dim index ``j`` as array draws:
    per level, gather the divisor table of the current value and draw one
    index for TT and -- where the level may parallelize -- one for ST.
    Mirrors ``MapSpace._sample_chain``'s distribution. ``start`` (values,
    per row) and ``from_level`` support conditional resampling below a
    fixed prefix (the decoupled mapper's phase 2); levels before
    ``from_level`` come back as the start value."""
    n = space.n_levels
    d = space.dims[j]
    tb = _tables(space)[d]
    allowed = space._allowed_spatial[d]
    last = n - 1
    tt = np.empty((B, n), dtype=np.int64)
    st = np.empty((B, n), dtype=np.int64)
    if start is None:
        cur = np.full(B, tb.idx_of[space.problem.dims[d]], dtype=np.int64)
    else:
        cur = tb.idx_of[np.asarray(start, dtype=np.int64)]
    # ONE uniform draw covers the whole chain; per level the bounded index
    # is floor(u * count) -- negligible bias, and 2 generator calls per
    # level collapse into one per chain batch
    u = rng.random((B, n, 2))
    for i in range(from_level, n):
        r = (u[:, i, 0] * tb.div_cnt[cur]).astype(np.int64)
        ttv = tb.div_val[cur, r]
        if allowed[i] and i != last:
            ti = tb.idx_of[ttv]
            stv = tb.div_val[ti, (u[:, i, 1] * tb.div_cnt[ti]).astype(np.int64)]
        else:
            stv = ttv
        tt[:, i] = ttv
        st[:, i] = stv
        cur = tb.idx_of[stv]
    return tt, st


def sample_chains_batch(
    space, rng: np.random.Generator, B: int
) -> Tuple[np.ndarray, np.ndarray]:
    """``B`` nested divisor chains for every dim (one
    :func:`sample_chain_cols` pass per dim)."""
    n = space.n_levels
    D = len(space.dims)
    tt = np.empty((B, n, D), dtype=np.int64)
    st = np.empty((B, n, D), dtype=np.int64)
    for j in range(D):
        tcol, scol = sample_chain_cols(space, rng, j, B)
        tt[:, :, j] = tcol
        st[:, :, j] = scol
    return tt, st


def repair_fanout_batch(space, rng: np.random.Generator, tt, st) -> None:
    """In-place vectorized counterpart of ``random_genome``'s repair:
    while any level's parallelism exceeds the child fanout, grow the
    largest-ratio dim's ST toward TT by the smallest sufficient divisor
    (deterministic greedy -- the scalar repair picks a random dim and one
    prime factor per step; the v2 stream is seed-versioned precisely so
    the repair can take the one-shot form), rescaling the chain below to
    keep nesting. ``rng`` is accepted for signature stability; the greedy
    repair consumes no draws."""
    n = space.n_levels
    D = tt.shape[2]
    lc = _legality_consts(space)
    # one pass decides whether ANY row needs repair; the fix loops below
    # then run on the violating subset only (typically a small minority)
    fans = (tt // np.maximum(st, 1)).astype(np.float64)
    sel = np.flatnonzero((fans.prod(axis=2) > lc.caps).any(axis=1))
    if sel.size == 0:
        return
    tabs = [_tables(space)[d] for d in space.dims]
    sub_t = tt[sel]
    sub_s = st[sel]
    for i in range(n):
        while True:
            ratio = sub_t[:, i, :] // np.maximum(sub_s[:, i, :], 1)
            par = ratio.astype(np.float64).prod(axis=1)
            viol = np.flatnonzero(par > space.child_fanout[i])
            if viol.size == 0:
                break
            # greedily serialize the LARGEST-ratio dim by the SMALLEST
            # divisor of its fan ratio that brings the level under the
            # cap (the whole ratio when none suffices): one deterministic
            # pass fixes almost every row, instead of one random dim and
            # one prime factor per iteration
            dimsel = np.argmax(ratio[viol], axis=1)
            needed = np.ceil(par[viol] / space.child_fanout[i])
            for j in range(D):
                rows = viol[dimsel == j]
                if rows.size == 0:
                    continue
                tb = tabs[j]
                rat = sub_t[rows, i, j] // sub_s[rows, i, j]
                want = np.minimum(needed[dimsel == j], rat)
                drows = tb.div_val[tb.idx_of[rat]]  # sorted, max-padded
                pos = (drows < want[:, None]).sum(axis=1)
                g = drows[np.arange(rows.size), pos]
                cur = sub_s[rows, i, j] * g
                sub_s[rows, i, j] = cur
                for lvl in range(i + 1, n):
                    for arr in (sub_t, sub_s):
                        v = arr[rows, lvl, j]
                        v = np.where(v > cur, np.gcd(v, cur), v)
                        arr[rows, lvl, j] = v
                        cur = v
    tt[sel] = sub_t
    st[sel] = sub_s


def sample_orders_batch(
    space, rng: np.random.Generator, B: int
) -> Tuple[np.ndarray, bool]:
    """Per-level random full orders for a batch (one ``permuted`` draw),
    with constrained levels forced to their required prefix order.
    Returns ``(perm, orders_ok)``; ``orders_ok`` is False when a
    constraint order names unknown dims (nothing can be legal, matching
    the scalar sampler's fallback)."""
    n = space.n_levels
    D = len(space.dims)
    perm = rng.permuted(
        np.tile(np.arange(D, dtype=np.int64), (B, n, 1)), axis=2
    )
    ok = True
    cons = space.constraints
    if cons is not None and cons.loop_orders:
        dim_index = {d: j for j, d in enumerate(space.dims)}
        dimset = set(space.dims)
        for i, cl in enumerate(space.arch.clusters):
            want = cons.loop_orders.get(cl.name)
            if want:
                forced = [dim_index[d] for d in want if d in dimset] + [
                    j for j, d in enumerate(space.dims) if d not in want
                ]
                perm[:, i, :] = np.asarray(forced, dtype=np.int64)
                ok &= set(want) <= dimset
    return perm, ok


def trivial_rows(space, B: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The guaranteed-legal all-serial candidate, tiled ``B`` times (the
    batch samplers' fallback, mirroring ``random_genome``'s)."""
    n = space.n_levels
    D = len(space.dims)
    tt = np.ones((B, n, D), dtype=np.int64)
    st = np.ones((B, n, D), dtype=np.int64)
    perm = np.tile(np.arange(D, dtype=np.int64), (B, n, 1))
    return tt, st, perm


def random_rows_batch(
    space, rng: np.random.Generator, B: int, tries: int = 200
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``B`` legal random candidates: sample + repair + legality filter as
    array programs, rejection-resampling only the still-illegal rows.
    Rows that stay illegal after ``tries`` rounds fall back to the
    trivial all-serial candidate (scalar-sampler semantics)."""
    n = space.n_levels
    D = len(space.dims)
    tt = np.empty((B, n, D), dtype=np.int64)
    st = np.empty_like(tt)
    perm = np.empty_like(tt)
    todo = np.arange(B)
    for _ in range(tries):
        t2, s2 = sample_chains_batch(space, rng, todo.size)
        repair_fanout_batch(space, rng, t2, s2)
        p2, orders_ok = sample_orders_batch(space, rng, todo.size)
        tt[todo], st[todo], perm[todo] = t2, s2, p2
        if not orders_ok:
            break
        good = legal_batch(space, t2, s2, p2, structured=True)
        todo = todo[~good]
        if todo.size == 0:
            break
    if todo.size:
        t0, s0, p0 = trivial_rows(space, todo.size)
        tt[todo], st[todo], perm[todo] = t0, s0, p0
    return tt, st, perm


def random_genome_batch(space, rng: np.random.Generator, B: int) -> GenomeBatch:
    return GenomeBatch(space, *random_rows_batch(space, rng, B))


def resample_inner_rows(
    space,
    rng: np.random.Generator,
    tt_base: np.ndarray,
    st_base: np.ndarray,
    perm_base: np.ndarray,
    split: int,
    B: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``B`` candidates keeping levels ``[0, split)`` of one base row and
    re-sampling the on-chip rest (chains conditioned on the prefix's ST,
    fresh below-split orders) -- the decoupled mapper's phase-2 batch."""
    n = space.n_levels
    D = len(space.dims)
    tt = np.tile(tt_base, (B, 1, 1))
    st = np.tile(st_base, (B, 1, 1))
    perm = np.tile(perm_base, (B, 1, 1))
    for j in range(D):
        if split > 0:
            start = np.full(B, st_base[split - 1, j], dtype=np.int64)
        else:
            start = None
        tcol, scol = sample_chain_cols(
            space, rng, j, B, start=start, from_level=split
        )
        tt[:, split:, j] = tcol[:, split:]
        st[:, split:, j] = scol[:, split:]
    sub = rng.permuted(
        np.tile(np.arange(D, dtype=np.int64), (B, n - split, 1)), axis=2
    )
    perm[:, split:, :] = sub
    cons = space.constraints
    if cons is not None and cons.loop_orders:
        dim_index = {d: j for j, d in enumerate(space.dims)}
        dimset = set(space.dims)
        for i in range(split, n):
            want = cons.loop_orders.get(space.arch.clusters[i].name)
            if want:
                forced = [dim_index[d] for d in want if d in dimset] + [
                    j for j, d in enumerate(space.dims) if d not in want
                ]
                perm[:, i, :] = np.asarray(forced, dtype=np.int64)
    return tt, st, perm


# --------------------------------------------------------------------- #
# Vectorized exhaustive enumeration: mixed-radix index decoding over the
# per-dim legal chain lists, in the EXACT order the recursive DFS of
# ``MapSpace.enumerate_genomes`` yields (lexicographic over per-dim chain
# indices, fanout-cap filtered -- prefix pruning removes exactly the
# combos the full per-level check rejects).
# --------------------------------------------------------------------- #
def exhaustive_row_blocks(space, block: int = 2048):
    """Yield ``(tt, st)`` blocks of fanout-feasible chain combos in DFS
    order. The outer dims run as a Python DFS over their (few) prefix
    nodes with incremental fanout products -- pruning whole subtrees like
    the scalar enumerator -- while the innermost dim is decided for ALL
    its chains at once with one masked array comparison per prefix."""
    dims = space.dims
    n = space.n_levels
    D = len(dims)
    per = [
        np.asarray(space._chains_for_dim(d), dtype=np.int64).reshape(-1, n, 2)
        for d in dims
    ]
    fans = [np.maximum(p[:, :, 0] // np.maximum(p[:, :, 1], 1), 1) for p in per]
    caps = np.asarray(space.child_fanout, dtype=np.float64)
    fansf = [f.astype(np.float64) for f in fans]

    buf_idx: List[np.ndarray] = []  # [k, D] index rows awaiting emission
    buffered = 0

    def emit(rows_idx: np.ndarray):
        """Gather chain tuples for a [k, D] block of per-dim indices."""
        k = rows_idx.shape[0]
        tt = np.empty((k, n, D), dtype=np.int64)
        st = np.empty((k, n, D), dtype=np.int64)
        for j in range(D):
            ch = per[j][rows_idx[:, j]]
            tt[:, :, j] = ch[:, :, 0]
            st[:, :, j] = ch[:, :, 1]
        return tt, st

    def dfs(j: int, prefix: List[int], fan_prod: np.ndarray):
        nonlocal buffered
        if j == D - 1:
            okm = (fansf[j] * fan_prod <= caps).all(axis=1)
            last = np.flatnonzero(okm)
            if last.size == 0:
                return
            rows = np.empty((last.size, D), dtype=np.int64)
            rows[:, :-1] = np.asarray(prefix, dtype=np.int64)
            rows[:, -1] = last
            buf_idx.append(rows)
            buffered += last.size
            while buffered >= block:
                yield _drain()
            return
        fj = fansf[j]
        for ci in range(per[j].shape[0]):
            nf = fan_prod * fj[ci]
            if (nf > caps).any():
                continue
            prefix.append(ci)
            yield from dfs(j + 1, prefix, nf)
            prefix.pop()

    def _drain():
        nonlocal buffered
        allrows = np.concatenate(buf_idx, axis=0)
        head, rest = allrows[:block], allrows[block:]
        buf_idx.clear()
        if rest.size:
            buf_idx.append(rest)
        buffered = sum(r.shape[0] for r in buf_idx)
        return emit(head)

    if D == 1:
        okm = (fansf[0] <= caps).all(axis=1)
        idxs = np.flatnonzero(okm)
        for s in range(0, idxs.size, block):
            yield emit(idxs[s : s + block, None])
        return
    yield from dfs(0, [], np.ones(n, dtype=np.float64))
    while buffered:
        yield _drain()


def exhaustive_genome_batches(
    space,
    max_mappings: Optional[int] = None,
    batch_size: int = 256,
    decode_block: int = 2048,
):
    """Stream legal candidates as :class:`GenomeBatch` chunks of EXACTLY
    ``batch_size`` rows (last chunk partial), reproducing the scalar
    enumerator's candidate stream and chunk boundaries bit-for-bit
    (canonical orders, no constraints -- callers gate on that)."""
    n = space.n_levels
    D = len(space.dims)
    canonical = np.arange(D, dtype=np.int64)
    pend_tt: List[np.ndarray] = []
    pend_st: List[np.ndarray] = []
    pending = 0
    emitted = 0
    budget = math.inf if max_mappings is None else int(max_mappings)

    def flush(k: int):
        nonlocal pending
        tt = np.concatenate(pend_tt, axis=0) if len(pend_tt) > 1 else pend_tt[0]
        st = np.concatenate(pend_st, axis=0) if len(pend_st) > 1 else pend_st[0]
        head_t, rest_t = tt[:k], tt[k:]
        head_s, rest_s = st[:k], st[k:]
        pend_tt.clear()
        pend_st.clear()
        if rest_t.shape[0]:
            pend_tt.append(rest_t)
            pend_st.append(rest_s)
        pending = rest_t.shape[0]
        perm = np.tile(canonical, (head_t.shape[0], n, 1))
        return GenomeBatch(space, head_t, head_s, perm)

    for tt, st in exhaustive_row_blocks(space, block=decode_block):
        good = legal_batch(
            space, tt, st, np.tile(canonical, (tt.shape[0], n, 1)), structured=True
        )
        keep = np.flatnonzero(good)
        if keep.size == 0:
            continue
        remaining = budget - emitted - pending
        if keep.size > remaining:
            keep = keep[: int(remaining)]
        pend_tt.append(tt[keep])
        pend_st.append(st[keep])
        pending += keep.size
        while pending >= batch_size:
            gb = flush(batch_size)
            emitted += len(gb)
            yield gb
        if emitted + pending >= budget:
            break
    while pending:
        gb = flush(min(batch_size, pending))
        emitted += len(gb)
        yield gb
