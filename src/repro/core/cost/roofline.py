"""TPU v5e three-term roofline cost model.

Terms (in seconds, per chip):

  compute    = FLOPs_per_chip / 197e12
  memory     = HBM_bytes_per_chip / 819e9
  collective = ici_bytes_per_chip / 50e9   (ring-discounted per collective)

Two modes:

  * analytic  -- ``TPURooflineModel.evaluate`` scores a (Problem, Mapping)
    pair before any compilation: HBM traffic from the shared reuse
    analysis, collective traffic inferred from which mesh-level spatial
    splits are relevant/irrelevant/reduction for each data space. This is
    what the mappers use to search sharding+tiling jointly.
  * artifact  -- ``RooflineReport.from_artifact`` consumes the dry-run's
    compiled HLO statistics (launch/dryrun.py) and is the source of truth
    for EXPERIMENTS.md. `cost_analysis()` on an SPMD module reports
    PER-DEVICE FLOPs/bytes, so no further division by chip count happens.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core.architecture import Architecture, TPU_V5E
from repro.core.cost.analysis import (
    BATCH_EXACT_LIMIT,
    analyze,
    batch_projection_footprint,
    boundary_bytes_per_instance,
    exact_divisor,
    get_context,
)
from repro.core.cost.base import Cost, CostModel
from repro.core.mapping import Mapping
from repro.core.problem import Problem

MESH_AXES = ("pod", "data", "model")


@dataclass
class RooflineReport:
    """The §Roofline record for one (arch x shape x mesh) cell."""

    name: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops_total: float = 0.0
    peak_flops: float = TPU_V5E["peak_bf16_flops"]
    hbm_bw: float = TPU_V5E["hbm_bw"]
    link_bw: float = TPU_V5E["ici_link_bw"]
    extras: Dict[str, float] = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_chip / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / self.link_bw

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic fully-overlapped step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is 'useful'."""
        total_hlo = self.flops_per_chip * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilization at the optimistic step time (MFU bound)."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops_total / (t * self.chips * self.peak_flops)

    def row(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "step_s": self.step_time_s,
            "useful_flops_frac": self.useful_flops_fraction,
            "roofline_frac": self.roofline_fraction,
        }

    @staticmethod
    def from_artifact(name: str, art: Dict) -> "RooflineReport":
        """Build from a dry-run artifact dict (launch/dryrun.py output).

        Prefers the structure-corrected costs (scan bodies x trip count --
        see dryrun.corrected_costs); raw cost_analysis numbers are the
        fallback for artifacts produced without the correction pass.
        """
        src = art.get("corrected", art)
        return RooflineReport(
            name=name,
            chips=int(art["chips"]),
            flops_per_chip=float(src["flops_per_device"]),
            hbm_bytes_per_chip=float(src["bytes_per_device"]),
            collective_bytes_per_chip=float(src["collective_bytes_per_device"]),
            model_flops_total=float(art.get("model_flops", 0.0)),
            extras={k: float(v) for k, v in art.get("extras", {}).items()},
        )


class TPURooflineModel(CostModel):
    """Analytic three-term roofline over (Problem, Mapping) on a TPU arch."""

    name = "tpu_roofline"

    def lower_bound(self, problem: Problem, mapping, arch: Architecture, sig=None):
        """(cycles, energy_pj) floor: perfect chip scaling + compulsory VMEM
        traffic; energy floor is the MAC term alone."""
        from repro.core.mapping import mapping_signature

        ctx = get_context(problem, arch)
        if sig is None:
            sig = mapping_signature(mapping, ctx.dims)
        peak = float(arch.attrs.get("peak_bf16_flops", TPU_V5E["peak_bf16_flops"]))
        hbm_bw = float(arch.attrs.get("hbm_bw", TPU_V5E["hbm_bw"]))
        chips = 1
        for cl in arch.clusters:
            if cl.dimension in MESH_AXES and cl.fanout > 1:
                chips *= cl.fanout
        compute_s = 2.0 * problem.macs / max(1, chips) / peak
        vmem_level = arch.n_levels - 1
        memory_s = 0.0
        if vmem_level in ctx.real_levels:
            memory_s = ctx.signature_min_boundary_bytes(sig, vmem_level) / hbm_bw
        cycles = max(compute_s, memory_s) * arch.frequency_hz
        energy = problem.macs * arch.clusters[-1].mac_energy
        return self._calibrate_bound((cycles, energy))

    def batch_admit_core_builder(self, problem: Problem, arch: Architecture):
        """Traceable form of the roofline admission bound (perfect chip
        scaling + compulsory VMEM traffic): an ``(xp, lax=None) -> core``
        builder whose core reproduces ``lower_bound`` per row bit-for-bit
        with numpy or inside the fused jitted program. A calibration scale
        is applied to the cycles as the same final multiply the scalar
        ``_calibrate_bound`` performs."""
        cal_s = (
            float(self.calibration.scale) if self.calibration is not None else None
        )
        ctx = get_context(problem, arch)
        peak = float(arch.attrs.get("peak_bf16_flops", TPU_V5E["peak_bf16_flops"]))
        hbm_bw = float(arch.attrs.get("hbm_bw", TPU_V5E["hbm_bw"]))
        chips = 1
        for cl in arch.clusters:
            if cl.dimension in MESH_AXES and cl.fanout > 1:
                chips *= cl.fanout
        compute_s = 2.0 * problem.macs / max(1, chips) / peak
        vmem_level = arch.n_levels - 1
        vmem_real = vmem_level in ctx.real_levels
        freq = arch.frequency_hz
        energy_const = problem.macs * arch.clusters[-1].mac_energy
        axes_info = ctx.ds_projection_axes

        def build(xp, lax=None):
            def core(tt, st, perm):
                B = tt.shape[0]
                mx = xp.zeros(())
                memory_s = xp.zeros(B, dtype=xp.float64)
                if vmem_real:
                    ttf = xp.maximum(tt[:, vmem_level, :], 1).astype(xp.float64)
                    total = xp.zeros(B, dtype=xp.float64)
                    for wb, axes, _rel in axes_info:
                        t = batch_projection_footprint(axes, ttf, xp) * wb
                        mx = xp.maximum(mx, xp.max(t))
                        total = total + t
                    memory_s = total / exact_divisor(xp, hbm_bw)
                cycles = xp.maximum(compute_s, memory_s) * freq
                if cal_s is not None:
                    cycles = cycles * cal_s
                return cycles, xp.full(B, energy_const, dtype=xp.float64), mx

            return core

        return build

    def lower_bound_batch_fn(self, problem: Problem, arch: Architecture):
        """Vectorized ``lower_bound``: one array program reproduces the
        scalar bound (perfect chip scaling + compulsory VMEM traffic) for
        a whole stacked batch, bit-identically -- or returns None beyond
        the float64-exact range so the engine falls back per candidate.
        Runs the same core the fused jitted path traces, with numpy (the
        admit core already carries the calibration multiply)."""
        ctx = get_context(problem, arch)
        core = self.batch_admit_core_builder(problem, arch)(np)

        def lb_batch(sigs=None, backend: str = "numpy", stacked=None):
            sb = stacked
            if sb is None:
                if not sigs:
                    return None
                sb = ctx.stacked_batch(sigs)
            if sb.size == 0:
                return None
            cycles, energy, mx = core(sb.tt, sb.st, sb.perm)
            if not (float(mx) < BATCH_EXACT_LIMIT):
                return None
            return cycles, energy

        return lb_batch

    def batch_cost_terms_fn(self, problem: Problem, arch: Architecture):
        """Array-program twin of ``evaluate``'s three-term roofline: VMEM
        boundary traffic from the shared batch analysis, chip utilization
        and collective terms from the stacked fan/tile matrices. Same
        float-operation order per row with numpy or jax.numpy; a
        calibration scale is applied as the final latency multiply, exactly
        as ``apply_calibration`` does on the scalar path. See
        ``CostModel.batch_cost_terms_fn``."""
        cal_s = (
            float(self.calibration.scale) if self.calibration is not None else None
        )
        ctx = get_context(problem, arch)
        peak = float(arch.attrs.get("peak_bf16_flops", TPU_V5E["peak_bf16_flops"]))
        hbm_bw = float(arch.attrs.get("hbm_bw", TPU_V5E["hbm_bw"]))
        link_bw = float(arch.attrs.get("ici_link_bw", TPU_V5E["ici_link_bw"]))
        freq = arch.frequency_hz
        mac_term = problem.macs * arch.clusters[-1].mac_energy
        num_pes = max(1, arch.num_pes)
        chips = 1
        mesh_levels = []
        for i, cl in enumerate(arch.clusters):
            if cl.dimension in MESH_AXES and cl.fanout > 1:
                chips *= cl.fanout
                mesh_levels.append(i)
        vmem_level = arch.n_levels - 1
        vmem_real = vmem_level in ctx.real_levels
        pos_v = ctx.real_levels.index(vmem_level) if vmem_real else -1
        red = set(problem.reduction_dims())
        red_idx = np.asarray(
            [j for j, d in enumerate(ctx.dims) if d in red], dtype=np.int64
        )
        axes_info = ctx.ds_projection_axes
        ds_out = [ds.is_output for ds in problem.data_spaces]
        word_bytes = [ds.word_bytes for ds in problem.data_spaces]

        def terms(bt, xp):
            B = bt.compute_cycles.shape[0]
            # par is guarded too: utilization must match the scalar path's
            # exact-int parallelism bit for bit
            mx = xp.maximum(xp.max(bt.total_trips), xp.max(bt.par))

            fansf = bt.fans.astype(xp.float64)
            lvl_par = xp.prod(fansf, axis=2)  # [B, n_levels]
            used_chips = xp.ones(B)
            for i in mesh_levels:
                if i > 0:
                    used_chips = used_chips * lvl_par[:, i - 1]
            used_chips = xp.maximum(1.0, xp.minimum(float(chips), used_chips))
            flops_per_chip = 2.0 * problem.macs / used_chips
            compute_s = flops_per_chip / exact_divisor(xp, peak)

            hbm_bytes = xp.zeros(B)
            if vmem_real:
                for k in range(len(axes_info)):
                    r = bt.rows[k]
                    t = (r.fills[:, pos_v] + r.drains[:, pos_v]) * word_bytes[k]
                    mx = xp.maximum(mx, xp.max(t))
                    hbm_bytes = hbm_bytes + t
            memory_s = hbm_bytes / exact_divisor(xp, hbm_bw)

            coll_bytes = xp.zeros(B)
            for i in mesh_levels:
                lvl = i - 1  # mapping level distributing over this mesh axis
                if lvl < 0:
                    continue
                f = bt.fans[:, lvl, :]
                n_arr = lvl_par[:, lvl]
                has_split = n_arr > 1
                split_red = (
                    xp.any(f[:, red_idx] > 1, axis=1)
                    if red_idx.size
                    else xp.zeros(B, dtype=bool)
                )
                stf = bt.st[:, lvl, :].astype(xp.float64)
                for k, (wb, axes, rel_idx) in enumerate(axes_info):
                    shard = xp.ones(B)
                    for ax in axes:
                        span = xp.ones(B)
                        for coeff, j in ax:
                            span = span + coeff * (stf[:, j] - 1.0)
                        shard = shard * span
                    mx = xp.maximum(mx, xp.max(shard))
                    if ds_out[k]:
                        cond = has_split & split_red
                        term = 2.0 * (n_arr - 1.0) / n_arr * shard * wb
                    else:
                        split_rel = (
                            xp.any(f[:, np.asarray(rel_idx, dtype=np.int64)] > 1, axis=1)
                            if rel_idx
                            else xp.zeros(B, dtype=bool)
                        )
                        cond = has_split & ~split_rel
                        term = (n_arr - 1.0) / n_arr * shard * wb
                    coll_bytes = coll_bytes + xp.where(cond, term, 0.0)
            collective_s = coll_bytes / exact_divisor(xp, link_bw)

            latency_s = xp.maximum(compute_s, xp.maximum(memory_s, collective_s))
            energy_pj = (
                hbm_bytes * used_chips * 7.0 + coll_bytes * used_chips * 2.0 + mac_term
            )
            util = bt.par / exact_divisor(xp, num_pes)
            bound_idx = xp.argmax(
                xp.stack([compute_s, memory_s, collective_s]), axis=0
            )
            extras = {
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "bound": bound_idx,
            }
            latency = latency_s * freq
            if cal_s is not None:
                latency = latency * cal_s
            return latency, energy_pj, util, mx, extras

        return terms

    def costs_from_batch(
        self, problem, arch, latency, energy, util, extras, indices=None
    ):
        freq = arch.frequency_hz
        cal_s = (
            float(self.calibration.scale) if self.calibration is not None else None
        )
        rows = range(latency.shape[0]) if indices is None else indices
        out = []
        for b in rows:
            breakdown = {
                "compute_s": float(extras["compute_s"][b]),
                "memory_s": float(extras["memory_s"][b]),
                "collective_s": float(extras["collective_s"][b]),
                "bound": float(extras["bound"][b]),
            }
            if cal_s is not None:
                # latency is already scaled inside the terms program; the
                # breakdown records the scale exactly like apply_calibration
                breakdown["calibration_scale"] = cal_s
            out.append(
                Cost(
                    latency_cycles=float(latency[b]),
                    energy_pj=float(energy[b]),
                    utilization=float(util[b]),
                    macs=problem.macs,
                    frequency_hz=freq,
                    breakdown=breakdown,
                )
            )
        return out

    def evaluate_signature_batch(
        self,
        problem: Problem,
        arch: Architecture,
        sigs,
        backend: str = "numpy",
        stacked=None,
        select=None,
    ):
        """Vectorized ``evaluate`` over a miss-batch of signatures: the
        SAME array program the fused jitted single-dispatch path traces
        (``batch_cost_terms_fn``), run here with numpy over the admitted
        subset. Same float-operation order per candidate as ``evaluate``
        (bit-identical; BATCH_EXACT_LIMIT guard falls back to the scalar
        path). ``stacked``/``select`` reuse the engine's admission-stage
        StackedBatch (see ``CostModel.evaluate_signature_batch``)."""
        ctx = get_context(problem, arch)
        bt = ctx.signature_traffic_batch(
            sigs, backend=backend, stacked=stacked, select=select
        )
        if bt is None:
            return None
        terms = self.batch_cost_terms_fn(problem, arch)
        latency, energy, util, mx, extras = terms(bt, np)
        if not (float(mx) < BATCH_EXACT_LIMIT):
            return None  # exactness not guaranteed: use the scalar path
        return self.costs_from_batch(problem, arch, latency, energy, util, extras)

    def evaluate(self, problem: Problem, mapping: Mapping, arch: Architecture) -> Cost:
        prof = analyze(problem, mapping, arch)
        peak = float(arch.attrs.get("peak_bf16_flops", TPU_V5E["peak_bf16_flops"]))
        hbm_bw = float(arch.attrs.get("hbm_bw", TPU_V5E["hbm_bw"]))
        link_bw = float(arch.attrs.get("ici_link_bw", TPU_V5E["ici_link_bw"]))

        # chips = product of fanouts at mesh-axis levels
        chips = 1
        mesh_levels = []
        for i, cl in enumerate(arch.clusters):
            if cl.dimension in MESH_AXES and cl.fanout > 1:
                chips *= cl.fanout
                mesh_levels.append(i)

        # compute term: FLOPs divide evenly over the chips actually used
        used_chips = 1
        for i in mesh_levels:
            # parallelism expressed at the mapping level whose children are
            # the mesh level's instances (= level i-1 in list order)
            used_chips *= mapping.parallelism(i - 1, problem) if i > 0 else 1
        used_chips = max(1, min(chips, used_chips))
        flops_per_chip = 2.0 * problem.macs / used_chips
        compute_s = flops_per_chip / peak

        # memory term: traffic into the innermost real buffer (VMEM) per chip
        vmem_level = arch.n_levels - 1
        hbm_bytes = boundary_bytes_per_instance(prof, problem, vmem_level)
        memory_s = hbm_bytes / hbm_bw

        # collective term from mesh-level spatial splits
        coll_bytes = 0.0
        for i in mesh_levels:
            lvl = i - 1  # mapping level that distributes over this mesh axis
            if lvl < 0:
                continue
            fan = mapping.spatial_fanout(lvl, problem)
            split = {d: f for d, f in fan.items() if f > 1}
            if not split:
                continue
            n = math.prod(split.values())
            red = set(problem.reduction_dims())
            tile = mapping.outer_spatial_tile(lvl + 1, problem)
            for ds in problem.data_spaces:
                rel = set(ds.dims)
                shard = ds.footprint(tile)
                if ds.is_output:
                    if any(d in red for d in split):
                        # partial sums all-reduced: ring = 2*(n-1)/n * bytes
                        coll_bytes += 2.0 * (n - 1) / n * shard * ds.word_bytes
                else:
                    if not any(d in rel for d in split):
                        # replicated input must be broadcast: all-gather
                        coll_bytes += (n - 1) / n * shard * ds.word_bytes
        collective_s = coll_bytes / link_bw

        latency_s = max(compute_s, memory_s, collective_s)
        freq = arch.frequency_hz
        rep = RooflineReport(
            name=problem.name, chips=chips,
            flops_per_chip=flops_per_chip, hbm_bytes_per_chip=hbm_bytes,
            collective_bytes_per_chip=coll_bytes,
            model_flops_total=2.0 * problem.macs,
            peak_flops=peak, hbm_bw=hbm_bw, link_bw=link_bw,
        )
        # energy: rough HBM+ICI+MAC (used only for EDP-style ranking on TPU)
        energy_pj = (
            hbm_bytes * used_chips * 7.0
            + coll_bytes * used_chips * 2.0
            + problem.macs * arch.clusters[-1].mac_energy
        )
        return self.apply_calibration(Cost(
            latency_cycles=latency_s * freq,
            energy_pj=energy_pj,
            utilization=mapping.utilization(problem, arch),
            macs=problem.macs,
            frequency_hz=freq,
            breakdown={
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "bound": {"compute": 0.0, "memory": 1.0, "collective": 2.0}[rep.bound],
            },
        ))
