"""Accelergy-style energy tables (paper Sec. V-C uses Accelergy [41]).

Per-access energies live on the Cluster records themselves; this module
adds technology presets and NoC hop energies used by the MAESTRO-like
model's multicast accounting.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class EnergyTable:
    """pJ per byte / per MAC for one technology point."""

    name: str
    dram_pj_byte: float
    onchip_sram_pj_byte: float
    local_sram_pj_byte: float
    noc_hop_pj_byte: float
    package_link_pj_byte: float
    mac_pj: float


# 45nm-class numbers in the lineage of Eyeriss/Accelergy tables
ACCEL_45NM_UINT8 = EnergyTable(
    name="45nm_uint8",
    dram_pj_byte=64.0,
    onchip_sram_pj_byte=4.0,
    local_sram_pj_byte=0.5,
    noc_hop_pj_byte=0.35,
    package_link_pj_byte=10.0,
    mac_pj=0.2,
)

# 7nm-class bf16 numbers for the TPU-adapted studies
TPU_7NM_BF16 = EnergyTable(
    name="7nm_bf16",
    dram_pj_byte=7.0,  # HBM2e
    onchip_sram_pj_byte=0.6,  # CMEM/VMEM-class
    local_sram_pj_byte=0.15,
    noc_hop_pj_byte=0.08,
    package_link_pj_byte=2.0,  # ICI
    mac_pj=0.4,
)
