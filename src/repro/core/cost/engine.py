"""Batched, cached, bound-pruned mapping-evaluation engine.

Every mapper's inner loop is "score this candidate mapping with that cost
model". The paper's plug-and-play matrix (any mapper x any model) lives or
dies on the throughput of that loop, so this module centralizes it:

  * **Canonical signatures** -- ``mapping_signature`` collapses a Mapping to
    the (effective loop order, TT, ST) tuple per level that the analytical
    models actually consume. Two mappings with the same signature have
    byte-identical costs, so genetic/heuristic searches stop re-analyzing
    the neighborhoods they revisit (an LRU memo keyed on the signature).
  * **Lower-bound admission** -- a chain-only bound (compute cycles +
    compulsory boundary bytes; see ``CostModel.lower_bound``) rejects
    candidates that provably cannot beat the incumbent BEFORE the expensive
    reuse analysis runs. The bound never exceeds the true metric, so
    pruning never discards a candidate better than the incumbent.
  * **Batching** -- ``evaluate_batch`` deduplicates, prunes, and evaluates a
    population at once. Cache misses are scored as ONE vectorized array
    program (``CostModel.evaluate_signature_batch`` over the stacked
    signature matrices; numpy by default, jitted JAX via ``backend="jax"``,
    bit-identical to the scalar path either way), or optionally fanned out
    to a process pool (``workers > 0``).

The engine is the single evaluation path for all mappers (see
``repro.core.mappers``) and reports evaluated / cache-hit / pruned counters
through ``SearchResult`` so speedups stay observable.
"""

from __future__ import annotations

import logging
import math
import pickle
from collections import OrderedDict
from dataclasses import dataclass, replace
from time import perf_counter
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.architecture import Architecture
from repro.core.cost.analysis import (
    BATCH_EXACT_LIMIT,
    StackedBatch,
    get_context,
    global_trace_count,
)
from repro.core.cost.base import Cost, CostModel
from repro.core.cost.store import ResultStore
from repro.core.genome_batch import GenomeBatch, RowCandidate
from repro.core.mapping import Mapping, mapping_signature  # noqa: F401 (re-export)
from repro.core.problem import Problem

log = logging.getLogger("repro.engine")

Signature = Tuple[Tuple[Tuple[str, ...], Tuple[int, ...], Tuple[int, ...]], ...]

# Minimum miss-batch size worth routing through the vectorized array-program
# path; below this the per-candidate fused scalar path is cheaper.
_BATCH_MIN = 4

# Candidates are either Mapping objects or chain-level genomes
# (``repro.core.mapspace.Genome``): anything with .signature(dims) and
# .to_mapping(). Genomes let the samplers defer Mapping materialization to
# actual cache misses.


class _FusedOutcome(NamedTuple):
    """Result of one fused admit+score attempt (see
    ``EvaluationEngine._fused_admit_score``)."""

    decided: bool  # admission decisions were made on device
    misses: Optional[List[Tuple[object, object]]]  # admitted (key, cand)
    select: Optional[List[int]]  # admitted row indices into the batch
    stacked: Optional[object]  # StackedBatch to reuse on any fallback
    arrays: Optional[tuple]  # (latency, energy, util, extras) or None


class PrecomputedScores:
    """Host-materialized results of one mega-batch generic-fused dispatch
    (see ``repro.core.device_loop``): per-row admission-bound and score
    arrays for one :class:`GenomeBatch`, in row order. ``_serve_order``
    consumes them in place of a dispatch -- admission is recomputed
    host-side from the bound arrays against the CURRENT incumbent, so
    decisions (and therefore memo/store/counters) match a per-batch
    dispatch exactly even when the scoring ran generations earlier."""

    __slots__ = ("lb_cyc", "lb_en", "latency", "energy", "util", "extras")

    def __init__(self, lb_cyc, lb_en, latency, energy, util, extras) -> None:
        self.lb_cyc = lb_cyc
        self.lb_en = lb_en
        self.latency = latency
        self.energy = energy
        self.util = util
        self.extras = extras

    def select(self, rows) -> "PrecomputedScores":
        """Row-sliced view (slice object or index list), mirroring
        ``GenomeBatch.select`` for the probe recursion."""
        return PrecomputedScores(
            self.lb_cyc[rows],
            self.lb_en[rows],
            self.latency[rows],
            self.energy[rows],
            self.util[rows],
            {k: v[rows] for k, v in self.extras.items()},
        )


@dataclass
class EngineStats:
    """Counters for one engine lifetime (one search, in practice)."""

    evaluated: int = 0  # full cost-model analyses (cache misses everywhere)
    cache_hits: int = 0  # served by the in-engine signature memo
    store_hits: int = 0  # served by the cross-search ResultStore
    pruned: int = 0  # candidates rejected by the lower-bound filter
    batches: int = 0
    # candidate instances submitted by the mapper (pre-dedup, regardless of
    # how they were served). The mapper's candidate stream is unchanged by
    # cache/store warmth, so -- unlike the evaluated/pruned split -- this
    # total is warm/cold invariant.
    considered: int = 0
    # miss-batches served by the single-dispatch fused admit+score program
    # (jax backend): one jitted dispatch covered bound + mask + traffic +
    # energy for the whole batch.
    fused_dispatches: int = 0
    # jax backend broke mid-flight (trace/compile/dispatch failure or a
    # missing install) and the engine degraded itself to the numpy batch
    # path -- results are bit-identical by the backend contract, so this
    # is a warning-level event, not an error (at most 1 per engine unless
    # a circuit breaker re-arms the jax path and it fails again).
    backend_fallbacks: int = 0
    # batches whose incumbent was warm-started from ``seed_incumbent``
    # (nearest-neighbor warm start): admission pruned from candidate #1
    # instead of bootstrapping via an unpruned probe head.
    seeded_batches: int = 0
    # NEW compiled programs traced on behalf of this engine (sampled as
    # deltas of the process-global trace registry around every dispatch
    # site, so shape-generic cache hits -- a program traced by ANOTHER
    # engine of the same shape class -- correctly count zero here).
    n_traces: int = 0
    # host<->device synchronization points of the device-resident search
    # loops (one per mega-batch precompute / deferred-generation flush);
    # stays 0 on the host-loop paths.
    device_syncs: int = 0
    admit_s: float = 0.0  # wall-clock spent in the admission (bound) stage
    score_s: float = 0.0  # wall-clock spent scoring admitted misses

    def snapshot(self) -> "EngineStats":
        return replace(self)

    @property
    def candidates(self) -> int:
        return self.evaluated + self.cache_hits + self.store_hits + self.pruned

    @property
    def cache_hit_rate(self) -> float:
        seen = self.evaluated + self.cache_hits + self.store_hits
        return self.cache_hits / seen if seen else 0.0


# ------------------------------------------------------------------ #
# Process-pool plumbing. Workers hold the (cost model, problem, arch)
# triple in module state (shipped once via the initializer) and receive
# only mapping dicts per task.
# ------------------------------------------------------------------ #
_POOL_STATE: Optional[Tuple[CostModel, Problem, Architecture]] = None


def _pool_init(payload: bytes) -> None:
    global _POOL_STATE
    _POOL_STATE = pickle.loads(payload)


def _pool_eval(mapping_dicts: List[dict]) -> List[Cost]:
    cm, problem, arch = _POOL_STATE  # type: ignore[misc]
    return [cm.evaluate(problem, Mapping.from_dict(d), arch) for d in mapping_dicts]


class EvaluationEngine:
    """Single evaluation path for (one cost model, one problem, one arch).

    Parameters
    ----------
    metric:      the search objective; used to scalarize lower bounds.
    cache_size:  LRU memo capacity (signatures -> Cost).
    prune:       enable the lower-bound admission filter.
    workers:     >0 fans cache misses of ``evaluate_batch`` out to a
                 process pool (beneficial for expensive models / large
                 batches; 0 keeps everything in-process).
    backend:     array backend for the vectorized miss-batch analysis AND
                 the batched admission bound ("numpy" default, "jax" for
                 the jitted device-resident path); any other value
                 disables batching (per-candidate scalar path).
    store:       optional cross-search :class:`ResultStore`; probed on
                 memo misses (before the admission filter) and fed every
                 fresh evaluation, so repeated sweeps over the same
                 (problem, arch, model) space stop re-scoring identical
                 signatures across searches and processes.
    breaker:     optional circuit breaker (``runtime.fault_tolerance.
                 CircuitBreaker``, duck-typed so core stays free of the
                 runtime package). ``_check_backend_degraded`` reports a
                 jax failure to it, and :meth:`maybe_restore_backend`
                 re-arms the jax path when the breaker's probe schedule
                 admits a half-open retry -- turning the one-way
                 degradation into a recoverable state machine for
                 long-lived processes (the mapping-service daemon).

    ``seed_incumbent`` (attribute, default None) warm-starts a search:
    when a batch arrives with ``probe`` set and no incumbent yet
    (``incumbent == inf``), the seed is used as the incumbent for the
    whole batch INSTEAD of the unpruned probe head -- admission prunes
    from candidate #1. Sound by the lower-bound contract: any candidate
    whose true metric beats the seed has ``lb <= true < seed`` and is
    always admitted, so the best found is unchanged whenever the space
    can beat the seed at all; a too-optimistic seed prunes everything
    (every result None) and the CALLER must fall back to an unseeded
    retry. Population calls that disable pruning (``incumbent=inf``
    without ``probe``, e.g. genetic fitness batches) never consume it.
    """

    def __init__(
        self,
        cost_model: CostModel,
        problem: Problem,
        arch: Architecture,
        metric: str = "edp",
        cache_size: int = 1 << 16,
        prune: bool = True,
        workers: int = 0,
        backend: Optional[str] = "numpy",
        store: Optional[ResultStore] = None,
        breaker: Optional[object] = None,
    ) -> None:
        self.cost_model = cost_model
        self.problem = problem
        self.arch = arch
        self.metric = metric
        self.cache_size = cache_size
        self.prune = prune
        self.workers = max(0, int(workers))
        self.backend = backend if backend in ("numpy", "jax") else None
        self.stats = EngineStats()
        self._dims: Tuple[str, ...] = tuple(problem.dims.keys())
        self._cache: "OrderedDict[Signature, Cost]" = OrderedDict()
        self._ctx = get_context(problem, arch)
        self._freq = arch.frequency_hz
        self._lb_fn = cost_model.lower_bound_fn(problem, arch)
        self._lb_chains_fn = cost_model.lower_bound_chains_fn(problem, arch)
        self._lb_batch_fn = cost_model.lower_bound_batch_fn(problem, arch)
        self._store = store
        self._store_skey = (
            store.space_key(cost_model, problem, arch) if store is not None else None
        )
        self._pool = None
        self._pool_failed = False
        # fused single-dispatch admit+score (jax backend only; lazy)
        self._fused_runner = None
        self._fused_failed = False
        # nearest-neighbor warm start (see class docstring)
        self.seed_incumbent: Optional[float] = None
        # circuit-breaker hook (duck-typed; see class docstring)
        self._breaker = breaker
        self._requested_backend = self.backend
        self._probe_pending = False  # restored jax path awaiting evidence
        self._probe_baseline = 0  # fused_dispatches at restore time

    # -------------------------------------------------------------- #
    def signature(self, cand) -> Signature:
        if isinstance(cand, Mapping):
            cached = cand.__dict__.get("_sig_cache")
            if cached is not None and cached[0] == self._dims:
                return cached[1]
            sig = mapping_signature(cand, self._dims)
            # mappings are treated as immutable once they reach the engine
            cand._sig_cache = (self._dims, sig)
            return sig
        return cand.signature(self._dims)

    @staticmethod
    def _materialize(cand) -> Mapping:
        return cand if isinstance(cand, Mapping) else cand.to_mapping()

    def _key_of(self, cand):
        """Memo-cache key. Mappings use the canonical signature; genomes
        use their (orders, chains) tuple, which determines the signature
        1:1 but is much cheaper to build."""
        if isinstance(cand, Mapping):
            return self.signature(cand)
        return cand.cache_key(self._dims)

    def _seed_for(self, incumbent: float, probe: int) -> Optional[float]:
        """The effective warm-start incumbent for a batch, or None.

        Consumed ONLY on the probe path (``probe > 0`` and no incumbent
        yet) with pruning enabled -- exactly the situation where the
        engine would otherwise bootstrap the incumbent from an unpruned
        probe head. Population fitness calls (``incumbent=inf`` without
        ``probe``) and batches that already carry a finite incumbent are
        never touched, so genetic search semantics are preserved.
        """
        s = self.seed_incumbent
        if (
            probe
            and incumbent == math.inf
            and self.prune
            and s is not None
            and math.isfinite(s)
            and s > 0.0
        ):
            return float(s)
        return None

    def _scalarize(self, lb_cycles: float, lb_energy: float) -> float:
        if self.metric == "latency":
            return lb_cycles
        if self.metric == "energy":
            return lb_energy
        if self.metric == "edp":
            # same association as Cost.edp so lb==true components can never
            # round above the true metric
            return (lb_energy * 1e-12) * (lb_cycles / self._freq)
        return 0.0

    def _scalarize_batch(self, lb_cycles, lb_energy):
        """Vector form of :meth:`_scalarize` -- identical float operations
        per element, so batched admit/reject decisions are bit-identical
        to the scalar filter."""
        if self.metric == "latency":
            return lb_cycles
        if self.metric == "energy":
            return lb_energy
        if self.metric == "edp":
            return (lb_energy * 1e-12) * (lb_cycles / self._freq)
        return lb_cycles * 0.0

    def _should_prune(self, cand, incumbent: float) -> bool:
        if self._lb_chains_fn is not None and not isinstance(cand, Mapping):
            lc, le = self._lb_chains_fn(
                cand.chain_list, cand.orders, incumbent, self._scalarize
            )
        else:
            lc, le = self._lb_fn(self.signature(cand))
        return self._scalarize(lc, le) >= incumbent

    def lower_bound(self, cand, sig: Optional[Signature] = None) -> float:
        """Metric lower bound from the chain alone (no reuse analysis).

        Guaranteed <= ``evaluate(cand).metric(self.metric)``; 0.0 when
        the cost model declines to provide a bound.
        """
        if sig is None:
            sig = self.signature(cand)
        return self._scalarize(*self._lb_fn(sig))

    # -------------------------------------------------------------- #
    def _cache_get(self, sig: Signature) -> Optional[Cost]:
        c = self._cache.get(sig)
        if c is not None:
            self._cache.move_to_end(sig)
            self.stats.cache_hits += 1
        return c

    def _cache_put(self, sig: Signature, cost: Cost) -> None:
        self._cache[sig] = cost
        if len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    def _store_get(self, key, cand) -> Optional[Cost]:
        """Cross-search store probe (memo misses only). A hit is promoted
        into the memo so in-batch duplicates become plain cache hits."""
        if self._store is None:
            return None
        c = self._store.get(self._store_skey, self.signature(cand))
        if c is not None:
            self.stats.store_hits += 1
            self._cache_put(key, c)
        return c

    def _store_put(self, cand, cost: Cost) -> None:
        if self._store is not None:
            self._store.put(self._store_skey, self.signature(cand), cost)

    def _evaluate_one(self, cand) -> Cost:
        c = self.cost_model.evaluate_signature(
            self.problem, self.arch, self.signature(cand)
        )
        if c is None:
            c = self.cost_model.evaluate(self.problem, self._materialize(cand), self.arch)
        return c

    # -------------------------------------------------------------- #
    def evaluate(self, cand) -> Cost:
        """Memoized single evaluation (always admits)."""
        self.stats.considered += 1
        key = self._key_of(cand)
        c = self._cache_get(key)
        if c is not None:
            return c
        c = self._store_get(key, cand)
        if c is not None:
            return c
        c = self._evaluate_one(cand)
        self.stats.evaluated += 1
        self._cache_put(key, c)
        self._store_put(cand, c)
        return c

    def evaluate_admit(self, cand, incumbent: float) -> Optional[Cost]:
        """Evaluate unless the lower bound proves the candidate cannot beat
        ``incumbent`` (returns None in that case). Cached/stored candidates
        are returned directly -- a hit is cheaper than the bound."""
        self.stats.considered += 1
        key = self._key_of(cand)
        c = self._cache_get(key)
        if c is not None:
            return c
        c = self._store_get(key, cand)
        if c is not None:
            return c
        if self.prune and incumbent != math.inf:
            t0 = perf_counter()
            dominated = self._should_prune(cand, incumbent)
            self.stats.admit_s += perf_counter() - t0
            if dominated:
                self.stats.pruned += 1
                return None
        t0 = perf_counter()
        c = self._evaluate_one(cand)
        self.stats.score_s += perf_counter() - t0
        self.stats.evaluated += 1
        self._cache_put(key, c)
        self._store_put(cand, c)
        return c

    def evaluate_genome_batch(
        self,
        gb: GenomeBatch,
        incumbent: float = math.inf,
        probe: int = 0,
        precomputed: Optional[PrecomputedScores] = None,
    ) -> List[Optional[Cost]]:
        """Array-native :meth:`evaluate_batch` over a dense
        :class:`GenomeBatch`: in-batch dedup is one ``np.unique`` row-hash
        program, memo keys are raw row bytes (same granularity as the
        per-genome tuple keys), and the miss-batch's ``StackedBatch`` is a
        row SLICE of the batch matrices -- no per-candidate signature
        tuples, Genome or Mapping objects are built on the batched
        backends (scalar fallbacks materialize rows lazily). Counter
        semantics match the list path exactly: every occurrence of a
        memo-cached candidate counts a cache hit, a store hit counts once
        and promotes (duplicates become cache hits), duplicates of a miss
        or pruned candidate count once per batch.

        ``precomputed`` hands in this batch's rows of an earlier
        mega-batch device dispatch (:class:`PrecomputedScores`, built by
        ``repro.core.device_loop``): memo/store/dedup/admission run
        exactly as usual, but miss scoring reads the precomputed arrays
        instead of dispatching -- results, counters, and side effects are
        identical to a fresh dispatch by construction.
        """
        seed = self._seed_for(incumbent, probe)
        if seed is not None:
            self.stats.seeded_batches += 1
            return self.evaluate_genome_batch(
                gb, incumbent=seed, precomputed=precomputed
            )
        if probe and incumbent == math.inf and len(gb) > probe:
            head = self.evaluate_genome_batch(
                gb.select(slice(0, probe)),
                precomputed=(
                    precomputed.select(slice(0, probe))
                    if precomputed is not None
                    else None
                ),
            )
            inc = incumbent
            for c in head:
                if c is not None:
                    s = c.metric(self.metric)
                    if s < inc:
                        inc = s
            return head + self.evaluate_genome_batch(
                gb.select(slice(probe, len(gb))),
                incumbent=inc,
                precomputed=(
                    precomputed.select(slice(probe, len(gb)))
                    if precomputed is not None
                    else None
                ),
            )

        self.stats.batches += 1
        self.stats.considered += len(gb)
        results: List[Optional[Cost]] = [None] * len(gb)
        rows2d = gb.key_rows()
        pending: Dict = {}
        order: List[Tuple[object, object]] = []
        miss_rows: List[int] = []
        for idx in range(len(gb)):
            key = rows2d[idx].tobytes()
            c = self._cache_get(key)
            if c is not None:
                results[idx] = c
                continue
            dup = pending.get(key)
            if dup is not None:
                dup.append(idx)
                continue
            cand = RowCandidate(gb, idx)
            c = self._store_get(key, cand)
            if c is not None:
                results[idx] = c
                continue
            pending[key] = [idx]
            order.append((key, cand))
            miss_rows.append(idx)

        stacked = (
            gb.stacked(miss_rows)
            if (order and self.backend is not None and precomputed is None)
            else None
        )
        self._serve_order(
            order,
            incumbent,
            results,
            pending,
            stacked=stacked,
            precomputed=precomputed,
        )
        return results

    def evaluate_batch(
        self,
        candidates: Sequence,
        incumbent: float = math.inf,
        probe: int = 0,
        precomputed: Optional[PrecomputedScores] = None,
    ) -> List[Optional[Cost]]:
        """Evaluate a population: dedup within the batch, serve memo/store
        hits, reject bound-dominated candidates (entries come back
        ``None``), and evaluate the misses -- the admission bound runs as
        ONE masked array program over the whole batch (bit-identical
        decisions and counters to the per-candidate filter), the survivors
        as one scoring program (sharing the admission stage's stacked --
        and, on jax, device-resident -- matrices), or on the worker pool.

        ``incumbent=inf`` disables pruning for this batch (population
        mappers that need a true fitness for every member use this).
        ``probe`` is the engine-level warm start: while no incumbent
        exists, the first ``probe`` candidates are scored unpruned and the
        best of them becomes the incumbent for the rest of the batch --
        the candidate stream is untouched and the bound is exact, so
        results are identical for any ``probe``.

        In-batch duplicates of a PRUNED candidate are tracked the same way
        duplicates of a miss are: the bound runs once and ``stats.pruned``
        counts the candidate once per batch, mirroring the dedup semantics
        of ``evaluated``.

        A :class:`GenomeBatch` is dispatched to the array-native
        :meth:`evaluate_genome_batch` (identical semantics, dedup and
        stacking as array programs).
        """
        if isinstance(candidates, GenomeBatch):
            return self.evaluate_genome_batch(
                candidates, incumbent, probe, precomputed=precomputed
            )
        seed = self._seed_for(incumbent, probe)
        if seed is not None:
            self.stats.seeded_batches += 1
            return self.evaluate_batch(candidates, incumbent=seed)
        if probe and incumbent == math.inf and len(candidates) > probe:
            head = self.evaluate_batch(candidates[:probe])
            inc = incumbent
            for c in head:
                if c is not None:
                    s = c.metric(self.metric)
                    if s < inc:
                        inc = s
            return head + self.evaluate_batch(candidates[probe:], incumbent=inc)

        self.stats.batches += 1
        self.stats.considered += len(candidates)
        results: List[Optional[Cost]] = [None] * len(candidates)
        pending: Dict = {}
        order: List[Tuple[object, object]] = []  # unique non-hit (key, cand)
        for idx, cand in enumerate(candidates):
            key = self._key_of(cand)
            c = self._cache_get(key)
            if c is not None:
                results[idx] = c
                continue
            dup = pending.get(key)
            if dup is not None:
                dup.append(idx)
                continue
            c = self._store_get(key, cand)
            if c is not None:
                results[idx] = c
                continue
            pending[key] = [idx]
            order.append((key, cand))

        self._serve_order(order, incumbent, results, pending)
        return results

    def _serve_order(
        self,
        order: List[Tuple[object, object]],
        incumbent: float,
        results: List[Optional[Cost]],
        pending: Dict,
        stacked=None,
        precomputed: Optional[PrecomputedScores] = None,
    ) -> None:
        """Admission + scoring for one batch's unique non-hit candidates:
        the shared tail of :meth:`evaluate_batch` (which stacks lazily
        from signatures) and :meth:`evaluate_genome_batch` (which hands in
        the row-sliced ``StackedBatch``). ``pending`` maps each key to its
        duplicate result slots. ``precomputed`` replaces the dispatch with
        already-materialized arrays (see :class:`PrecomputedScores`)."""
        before = global_trace_count()
        try:
            self._serve_order_impl(
                order, incumbent, results, pending, stacked, precomputed
            )
        finally:
            # delta-sample the process-global trace registry: only programs
            # traced DURING this batch count against this engine (a
            # shape-generic cache hit -- program traced by another engine of
            # the same class -- correctly counts zero)
            self.stats.n_traces += global_trace_count() - before

    def _serve_order_impl(
        self,
        order: List[Tuple[object, object]],
        incumbent: float,
        results: List[Optional[Cost]],
        pending: Dict,
        stacked=None,
        precomputed: Optional[PrecomputedScores] = None,
    ) -> None:
        def commit(misses, costs):
            for (key, cand), c in zip(misses, costs):
                self.stats.evaluated += 1
                self._cache_put(key, c)
                self._store_put(cand, c)
                for idx in pending[key]:
                    results[idx] = c

        if precomputed is not None and order:
            # device-resident loop replay: the scoring ran generations ago
            # as one mega-batch dispatch; admission is recomputed here from
            # the precomputed bound arrays against the CURRENT incumbent,
            # so decisions/counters/side effects equal a fresh dispatch.
            pre = precomputed
            rows = [cand.row for _key, cand in order]
            # count the batches a host loop would have served via its own
            # fused dispatch (>= _BATCH_MIN; smaller ones go scalar there)
            # so the counter is invariant between device and host runs
            if len(order) >= _BATCH_MIN:
                self.stats.fused_dispatches += 1
            if self.prune and incumbent != math.inf:
                t0 = perf_counter()
                scal = self._scalarize_batch(pre.lb_cyc[rows], pre.lb_en[rows])
                admit = [bool(v < incumbent) for v in scal]
                misses, select = self._partition_admitted(order, admit)
                self.stats.admit_s += perf_counter() - t0
            else:
                misses, select = list(order), list(range(len(order)))
            if misses:
                t0 = perf_counter()
                commit(
                    misses,
                    self.cost_model.costs_from_batch(
                        self.problem,
                        self.arch,
                        pre.latency,
                        pre.energy,
                        pre.util,
                        pre.extras,
                        indices=[rows[pos] for pos in select],
                    ),
                )
                self.stats.score_s += perf_counter() - t0
            # precomputed rows exist only because the device mega-dispatch
            # actually served: that is jax evidence too (probe recovery),
            # and a flag tripped since then must still degrade us
            self._check_backend_degraded()
            return

        misses = order
        select: Optional[List[int]] = None
        decided = False  # admission decisions already made by the fused path

        if order and self.backend == "jax" and len(order) >= _BATCH_MIN:
            fused = self._fused_admit_score(order, incumbent, stacked=stacked)
            stacked = fused.stacked  # reused by every fallback below
            self._check_backend_degraded()  # fused path may have broken jax
            if fused.decided:
                decided = True
                misses, select = fused.misses, fused.select
                if misses and fused.arrays is not None:
                    latency, energy, util, extras = fused.arrays
                    t0 = perf_counter()
                    commit(
                        misses,
                        self.cost_model.costs_from_batch(
                            self.problem,
                            self.arch,
                            latency,
                            energy,
                            util,
                            extras,
                            indices=select,
                        ),
                    )
                    self.stats.score_s += perf_counter() - t0
                    return
                # score guard tripped (arrays is None): the decisions
                # stand and the shared scoring path below re-scores the
                # admitted subset through the numpy/scalar flow.

        if not decided and self.prune and incumbent != math.inf and order:
            t0 = perf_counter()
            admit, stacked = self._admit_batch(order, incumbent, stacked=stacked)
            misses, select = self._partition_admitted(order, admit)
            self.stats.admit_s += perf_counter() - t0

        if misses:
            t0 = perf_counter()
            commit(
                misses,
                self._evaluate_misses(
                    misses,
                    stacked=stacked,
                    select=select if stacked is not None else None,
                ),
            )
            self.stats.score_s += perf_counter() - t0
        # scoring (or the batched bound) may have tripped the context's jax
        # flag: degrade now so subsequent batches skip the broken path
        self._check_backend_degraded()

    def _check_backend_degraded(self) -> bool:
        """Degrade a jax engine to the numpy batch path once the analysis
        context has flagged a jax failure (import, trace, compile, or
        dispatch -- the context records all of them as ``_jax_failed``).

        The numpy and jax array programs are bit-identical by the repo's
        backend contract, so the search continues with unchanged results;
        the event is counted (``stats.backend_fallbacks``) and warned once
        per engine so sweep summaries surface the degradation instead of
        it hiding behind silent per-batch fallbacks.
        """
        if self.backend == "jax" and getattr(self._ctx, "_jax_failed", False):
            self.backend = "numpy"
            self.stats.backend_fallbacks += 1
            self._probe_pending = False
            if self._breaker is not None:
                self._breaker.record_failure()
            log.warning(
                "jax backend failed for engine (%s on %s); degraded to the "
                "numpy path -- results identical by the backend contract",
                type(self.cost_model).__name__,
                getattr(self.problem, "name", "?"),
            )
            return True
        if (
            self._probe_pending
            and self.backend == "jax"
            and self.stats.fused_dispatches > self._probe_baseline
        ):
            # the restored jax path actually served a fused dispatch
            # without tripping the context flag: report recovery
            self._probe_pending = False
            if self._breaker is not None:
                self._breaker.record_success()
        return False

    def maybe_restore_backend(self) -> bool:
        """Half-open retry of a degraded jax backend, gated by the
        engine's circuit breaker.

        A breaker-less engine keeps PR 6's one-way degradation (this is a
        no-op). With a breaker, once its deterministic probe schedule
        admits a retry (``allow()``), the engine clears the analysis
        context's failure flag and re-arms the jax fused path; the next
        fused dispatch that completes without re-tripping the flag
        reports ``record_success`` (breaker closes), while a repeat
        failure reports ``record_failure`` through the normal degradation
        path (breaker re-opens). Returns True when a restore was armed.
        Safe to call between batches at any cadence -- long-lived callers
        (the mapping-service daemon) invoke it per query.
        """
        if (
            self._breaker is None
            or self._requested_backend != "jax"
            or self.backend == "jax"
        ):
            return False
        if not self._breaker.allow():
            return False
        self._ctx._jax_failed = False
        self.backend = "jax"
        self._fused_failed = False
        self._fused_runner = None
        self._probe_pending = True
        self._probe_baseline = self.stats.fused_dispatches
        log.info(
            "circuit breaker admitted a jax probe for engine (%s on %s); "
            "re-armed the fused path",
            type(self.cost_model).__name__,
            getattr(self.problem, "name", "?"),
        )
        return True

    def _partition_admitted(self, order, admit):
        """Split a batch's unique candidates by admit flag, counting one
        ``pruned`` tick per rejected candidate -- the single accounting
        path shared by the fused and two-stage admission flows."""
        misses: List[Tuple[object, object]] = []
        select: List[int] = []
        for pos, ((key, cand), ok) in enumerate(zip(order, admit)):
            if ok:
                misses.append((key, cand))
                select.append(pos)
            else:
                self.stats.pruned += 1
        return misses, select

    def _fused_admit_score(
        self, order, incumbent: float, stacked=None
    ) -> "_FusedOutcome":
        """Single-dispatch fused admit+score for one miss-batch (jax
        backend): one jitted program covers bound -> admit mask ->
        traffic -> energy; only per-candidate scalars return to host, and
        decisions/costs/counters are bit-identical to the two-stage flow
        by construction.

        ``decided=False`` means the caller must run its own admission
        (runner unavailable, jax broke mid-flight, or the lower-bound
        exactness guard tripped -- the two-stage bound falls back to the
        scalar bound the same way); any already-stacked batch is returned
        for reuse either way. With ``decided=True``, ``arrays`` holds the
        on-device score results -- or None when the score guard tripped,
        in which case the admitted subset must be re-scored host-side.
        The fused dispatch (and mask derivation) is accounted to
        ``admit_s``; Cost materialization is the caller's ``score_s``.
        """
        runner = self._get_fused_runner()
        if runner is None:
            return _FusedOutcome(False, None, None, stacked, None)
        t0 = perf_counter()
        sb = stacked
        if sb is None:
            sigs = [self.signature(cand) for _key, cand in order]
            sb = self._ctx.stacked_batch(sigs)
        inc = incumbent if (self.prune and incumbent != math.inf) else math.inf
        out = runner(sb, inc)
        if out is None:
            self._fused_failed = True  # jax broke: stop trying
            self.stats.admit_s += perf_counter() - t0
            return _FusedOutcome(False, None, None, sb, None)
        admit, lb_mx, latency, energy, util, score_mx, extras = out
        if not (lb_mx < BATCH_EXACT_LIMIT):
            self.stats.admit_s += perf_counter() - t0
            return _FusedOutcome(False, None, None, sb, None)
        self.stats.fused_dispatches += 1
        misses, select = self._partition_admitted(order, admit)
        self.stats.admit_s += perf_counter() - t0
        arrays = (
            (latency, energy, util, extras)
            if score_mx < BATCH_EXACT_LIMIT
            else None
        )
        return _FusedOutcome(True, misses, select, sb, arrays)

    def _get_fused_runner(self):
        """Lazily build (and memoize) the single-dispatch jitted
        admit+score runner for this (model, metric). None when the model
        does not provide traceable bound/terms programs or JAX cannot
        deliver float64 -- the engine then keeps the two-stage flow."""
        if self._fused_failed:
            return None
        if self._fused_runner is None:
            cache_key = (repr(self.cost_model.store_key_parts()), self.metric)
            # shape-generic first: one process-wide compiled program serves
            # every (problem, arch) of this shape class, so engines after
            # the first trace nothing at all
            generic = self.cost_model.batch_cost_terms_generic(
                self.problem, self.arch
            )
            if generic is not None:
                runner = self._ctx.build_generic_fused_runner(
                    generic, self.metric, cache_key=cache_key
                )
                if runner is not None:
                    self._fused_runner = runner
                    return runner
            terms = self.cost_model.batch_cost_terms_fn(self.problem, self.arch)
            lb_builder = self.cost_model.batch_admit_core_builder(
                self.problem, self.arch
            )
            if terms is None or lb_builder is None:
                self._fused_failed = True
                return None
            runner = self._ctx.build_fused_runner(
                lb_builder, terms, self.metric, cache_key=cache_key
            )
            if runner is None:
                self._fused_failed = True
                return None
            self._fused_runner = runner
        return self._fused_runner

    def warmup(self, batch_sizes: Sequence[int]) -> int:
        """Bucketed warmup: pre-trace the fused jax admit+score program at
        the pow2 buckets the given miss-batch sizes pad to, so first-batch
        retrace stalls disappear from ``admit_s``/``score_s`` during the
        timed search. No-op on non-jax backends or when the model has no
        fused path. Warmup rows are synthetic (the all-serial trivial
        candidate, tiled): results are discarded and neither the memo, the
        store nor the engine counters are touched -- only the context's
        ``jax_dispatches`` advances. Returns the number of buckets traced
        (already-compiled buckets re-dispatch in microseconds, so calling
        this repeatedly is safe)."""
        if self.backend != "jax":
            return 0
        runner = self._get_fused_runner()
        if runner is None:
            # missing/broken jax surfaces here first in warmed-up sweeps
            self._check_backend_degraded()
            return 0
        n = self.arch.n_levels
        D = len(self._dims)
        buckets = sorted(
            {
                1 << max(0, (int(b) - 1).bit_length())
                for b in batch_sizes
                if b and int(b) >= _BATCH_MIN
            }
        )
        # shape-generic runners consult the process-wide trace registry:
        # a bucket already traced for this shape class (by this engine, a
        # prior engine, or a prior warmup) is skipped -- one warmup covers
        # the whole class
        is_traced = getattr(runner, "is_traced", None)
        done = 0
        before = global_trace_count()
        try:
            for b in buckets:
                if is_traced is not None and is_traced(b):
                    continue
                tt = np.ones((b, n, D), dtype=np.int64)
                st = np.ones((b, n, D), dtype=np.int64)
                perm = np.tile(np.arange(D, dtype=np.int64), (b, n, 1))
                if runner(StackedBatch(tt, st, perm), math.inf) is None:
                    # jax broke mid-flight: degrade immediately rather than
                    # rediscovering the failure on the first timed batch
                    self._fused_failed = True
                    self._check_backend_degraded()
                    break
                done += 1
        finally:
            self.stats.n_traces += global_trace_count() - before
        return done

    def _admit_batch(self, order, incumbent: float, stacked=None):
        """Admission decisions for the unique non-hit candidates of one
        batch: True = evaluate, False = prune. One vectorized bound program
        when the model provides it (returning the shared StackedBatch for
        the scoring stage); the per-candidate scalar bound otherwise --
        decisions are bit-identical either way."""
        sb = stacked
        if (
            self.backend is not None
            and self._lb_batch_fn is not None
            and len(order) >= _BATCH_MIN
        ):
            if sb is None:
                sb = self._ctx.stacked_batch(
                    [self.signature(cand) for _key, cand in order]
                )
            lb = self._lb_batch_fn(None, backend=self.backend, stacked=sb)
            if lb is not None:
                scal = self._scalarize_batch(*lb)
                return [bool(v < incumbent) for v in scal], sb
        # scalar fallback (tiny batch, no batched bound, or exactness guard
        # tripped); an already-built StackedBatch is still handed to the
        # scoring stage so the batch is never stacked twice
        return [not self._should_prune(cand, incumbent) for _key, cand in order], sb

    # -------------------------------------------------------------- #
    def _evaluate_misses(
        self,
        misses: List[Tuple[object, object]],
        stacked=None,
        select=None,
    ) -> List[Cost]:
        pool = self._get_pool() if (self.workers and len(misses) >= 8) else None
        if pool is None:
            if self.backend is not None and (
                stacked is not None or len(misses) >= _BATCH_MIN
            ):
                # with a pre-stacked batch the models never touch the
                # signatures -- the array program runs off the matrices
                sigs = (
                    None
                    if stacked is not None
                    else [self.signature(cand) for _key, cand in misses]
                )
                costs = self.cost_model.evaluate_signature_batch(
                    self.problem,
                    self.arch,
                    sigs,
                    backend=self.backend,
                    stacked=stacked,
                    select=select,
                )
                if costs is not None:
                    return list(costs)
            return [self._evaluate_one(cand) for _key, cand in misses]
        mappings = [self._materialize(cand) for _key, cand in misses]
        nchunks = min(len(mappings), self.workers * 4)
        step = math.ceil(len(mappings) / nchunks)
        chunks = [mappings[i : i + step] for i in range(0, len(mappings), step)]
        futs = [pool.submit(_pool_eval, [m.to_dict() for m in ch]) for ch in chunks]
        out: List[Cost] = []
        for f in futs:
            out.extend(f.result())
        return out

    def _get_pool(self):
        if self._pool is not None or self._pool_failed:
            return self._pool
        try:
            import multiprocessing
            from concurrent.futures import ProcessPoolExecutor

            payload = pickle.dumps((self.cost_model, self.problem, self.arch))
            # spawn, not fork: the parent typically has JAX's threads
            # running, and forking a multithreaded process can deadlock
            self._pool = ProcessPoolExecutor(
                max_workers=self.workers,
                mp_context=multiprocessing.get_context("spawn"),
                initializer=_pool_init,
                initargs=(payload,),
            )
        except Exception:
            # unpicklable model / restricted environment: degrade to serial
            self._pool_failed = True
            self._pool = None
        return self._pool

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def __enter__(self) -> "EvaluationEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - best effort
        try:
            self.close()
        except Exception:
            pass
