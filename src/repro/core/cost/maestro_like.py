"""MAESTRO-like cluster data-centric cost model (paper Sec. III-B2, [10]).

Operation-level model: only accepts high-level operations it natively
understands (CONV2D / GEMM / DWCONV / TC-as-GEMM tags) -- the
conformability pass enforces this, mirroring the paper's discussion that
MAESTRO consumes operations while Timeloop consumes loop nests.

Differences from the Timeloop-like model (deliberate -- the two models
bracket reality, which is exactly why Union makes them swappable):

  * NoC multicast is an explicit energy term (data-centric reuse): every
    delivered copy pays a hop cost, but multicast reads the source once.
  * Latency is computed per cluster level as (steps x per-step max of
    compute and fill) with a pipeline-startup term -- MAESTRO's
    double-buffered cluster schedule -- instead of a global roofline max.
  * Edge/utilization effects: partial spatial occupancy directly scales
    the per-step compute time.
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.core.architecture import Architecture
from repro.core.cost.analysis import (
    BATCH_EXACT_LIMIT,
    analyze,
    batch_hierarchical_energy,
    boundary_bytes_per_instance,
    exact_divisor,
    generic_hierarchical_energy,
    get_context,
    hierarchical_lower_bound,
)
from repro.core.cost.base import Cost, CostModel
from repro.core.cost.energy import ACCEL_45NM_UINT8, EnergyTable
from repro.core.mapping import Mapping
from repro.core.problem import Problem

_SUPPORTED_OPS = {"CONV2D", "GEMM", "DWCONV", "TC", "ATTN_QK", "ATTN_PV", "SSD"}


class MaestroLikeModel(CostModel):
    name = "maestro_like"

    def __init__(self, energy_table: EnergyTable = ACCEL_45NM_UINT8) -> None:
        self.etab = energy_table

    def conformable(self, problem: Problem) -> bool:
        return problem.operation in _SUPPORTED_OPS and problem.unit_op == "mac2"

    def lower_bound(self, problem: Problem, mapping, arch: Architecture, sig=None):
        return self._calibrate_bound(
            hierarchical_lower_bound(problem, mapping, arch, sig=sig)
        )

    def lower_bound_fn(self, problem: Problem, arch: Architecture):
        fn = get_context(problem, arch).signature_lower_bound
        if self.calibration is None:
            return fn
        return lambda sig: self._calibrate_bound(fn(sig))

    def lower_bound_chains_fn(self, problem: Problem, arch: Architecture):
        fn = get_context(problem, arch).chains_lower_bound
        if self.calibration is None:
            return fn
        # drop the optional (incumbent, scalarize) early-exit hints: they
        # live in CALIBRATED metric space while fn computes raw bounds --
        # computing the full raw bound and scaling it keeps the bound exact
        return lambda chain_list, orders, *_hints: self._calibrate_bound(
            fn(chain_list, orders)
        )

    def lower_bound_batch_fn(self, problem: Problem, arch: Architecture):
        fn = get_context(problem, arch).lower_bound_batch
        if self.calibration is None:
            return fn
        # same final multiply as the scalar ``_calibrate_bound`` per
        # element, so calibrated batch admission stays bit-identical
        s = float(self.calibration.scale)

        def calibrated(*args, **kwargs):
            out = fn(*args, **kwargs)
            if out is None:
                return None
            cyc, en = out
            return cyc * s, en

        return calibrated

    def batch_admit_core_builder(self, problem: Problem, arch: Architecture):
        builder = get_context(problem, arch)._make_lb_core
        if self.calibration is None:
            return builder
        s = float(self.calibration.scale)

        def calibrated_builder(xp, lax=None):
            core = builder(xp, lax)

            def calibrated_core(tt, st, perm):
                cyc, en, mx = core(tt, st, perm)
                return cyc * s, en, mx

            return calibrated_core

        return calibrated_builder

    def store_key_parts(self):
        return (self.name, self.etab) + self.calibration_key_parts()

    def batch_cost_terms_fn(self, problem: Problem, arch: Architecture):
        """Array-program twin of ``evaluate_signature``'s latency/energy
        accumulation (double-buffered schedule + startup + NoC delivery
        term): same float-op order per row with numpy or jax.numpy. A
        calibration scale is applied as the final latency multiply,
        exactly as ``apply_calibration`` does on the scalar path. See
        ``CostModel.batch_cost_terms_fn``."""
        if not self.conformable(problem):
            return None
        cal_s = (
            float(self.calibration.scale) if self.calibration is not None else None
        )
        ctx = get_context(problem, arch)
        freq = arch.frequency_hz
        clusters = arch.clusters
        real_levels = ctx.real_levels
        spaces = problem.data_spaces
        num_pes = ctx.num_pes
        hop = self.etab.noc_hop_pj_byte

        def terms(bt, xp):
            cc = bt.compute_cycles
            # par is guarded too: utilization must match the scalar path's
            # exact-int parallelism bit for bit
            mx = xp.maximum(
                xp.maximum(xp.max(cc), xp.max(bt.total_trips)), xp.max(bt.par)
            )
            latency = cc
            startup = xp.zeros_like(cc)
            extras = {"compute_cycles": cc}
            for pos, i in enumerate(real_levels):
                if i == 0:
                    continue
                cl = clusters[i]
                if math.isinf(cl.fill_bandwidth):
                    continue
                total_fill = xp.zeros_like(cc)
                tile_bytes = xp.zeros_like(cc)
                for k, ds in enumerate(spaces):
                    r = bt.rows[k]
                    t = (r.fills[:, pos] + r.drains[:, pos]) * ds.word_bytes
                    mx = xp.maximum(mx, xp.max(t))
                    total_fill = total_fill + t
                    tile_bytes = tile_bytes + r.foot[:, pos] * ds.word_bytes
                mx = xp.maximum(mx, xp.max(tile_bytes))
                valid = total_fill > 0
                bw = exact_divisor(xp, cl.fill_bandwidth)
                fill_cycles = total_fill * freq / bw
                startup = startup + xp.where(
                    valid, tile_bytes * freq / bw, 0.0
                )
                extras[f"fill_cycles::{i}"] = fill_cycles
                extras[f"fill_valid::{i}"] = valid
                latency = xp.where(valid, xp.maximum(latency, fill_cycles), latency)
            latency = latency + startup
            energy, noc_energy, _mac, e_mx = batch_hierarchical_energy(
                ctx, arch, problem, bt, hop_pj_byte=hop, xp=xp
            )
            mx = xp.maximum(mx, e_mx)
            energy = energy + noc_energy
            extras["startup_cycles"] = startup
            extras["noc_energy_pj"] = noc_energy
            util = bt.par / exact_divisor(xp, num_pes)
            if cal_s is not None:
                latency = latency * cal_s
            return latency, energy, util, mx, extras

        return terms

    def batch_cost_terms_generic(self, problem: Problem, arch: Architecture):
        """Shape-generic twin of :meth:`batch_cost_terms_fn` (see
        ``CostModel.batch_cost_terms_generic``): structure = which real
        levels carry a finite-bandwidth fill/startup term; bandwidths,
        energies, the NoC hop cost and the calibration scale ride in the
        parameter pack."""
        if not self.conformable(problem):
            return None
        ctx = get_context(problem, arch)
        clusters = arch.clusters
        real_levels = list(ctx.real_levels)
        real_parent = [-1 if p is None else p for p in ctx.real_parent]
        K = len(problem.data_spaces)
        fill_levels = tuple(
            (pos, i)
            for pos, i in enumerate(real_levels)
            if not (i == 0 or math.isinf(clusters[i].fill_bandwidth))
        )
        leaf = clusters[-1]
        cal = self.calibration
        model_key = (self.name, fill_levels)
        model_params = {
            "ms_bw": np.asarray(
                [clusters[i].fill_bandwidth for _pos, i in fill_levels],
                dtype=np.float64,
            ),
            "num_pes": np.float64(ctx.num_pes),
            "lvl_read_e": np.asarray(
                [c.read_energy for c in clusters], dtype=np.float64
            ),
            "lvl_write_e": np.asarray(
                [c.write_energy for c in clusters], dtype=np.float64
            ),
            "l1_terms": np.asarray(
                [
                    ctx.l1_reads[ds.name] * ds.word_bytes * leaf.read_energy
                    for ds in problem.data_spaces
                ],
                dtype=np.float64,
            ),
            "mac_term": np.float64(problem.macs * leaf.mac_energy),
            "hop": np.float64(self.etab.noc_hop_pj_byte),
            "calib_scale": np.float64(cal.scale) if cal is not None else np.float64(1.0),
        }

        def terms(bt, xp, p):
            cc = bt.compute_cycles
            mx = xp.maximum(
                xp.maximum(xp.max(cc), xp.max(bt.total_trips)), xp.max(bt.par)
            )
            latency = cc
            startup = xp.zeros_like(cc)
            extras = {"compute_cycles": cc}
            for t, (pos, i) in enumerate(fill_levels):
                total_fill = xp.zeros_like(cc)
                tile_bytes = xp.zeros_like(cc)
                for k in range(K):
                    r = bt.rows[k]
                    tk = (r.fills[:, pos] + r.drains[:, pos]) * p["wb"][k]
                    mx = xp.maximum(mx, xp.max(tk))
                    total_fill = total_fill + tk
                    tile_bytes = tile_bytes + r.foot[:, pos] * p["wb"][k]
                mx = xp.maximum(mx, xp.max(tile_bytes))
                valid = total_fill > 0
                bw = exact_divisor(xp, p["ms_bw"][t])
                fill_cycles = total_fill * p["freq"] / bw
                startup = startup + xp.where(
                    valid, tile_bytes * p["freq"] / bw, 0.0
                )
                extras[f"fill_cycles::{i}"] = fill_cycles
                extras[f"fill_valid::{i}"] = valid
                latency = xp.where(valid, xp.maximum(latency, fill_cycles), latency)
            latency = latency + startup
            energy, noc_energy, e_mx = generic_hierarchical_energy(
                real_levels, real_parent, K, bt, xp, p, hop=True
            )
            mx = xp.maximum(mx, e_mx)
            energy = energy + noc_energy
            extras["startup_cycles"] = startup
            extras["noc_energy_pj"] = noc_energy
            util = bt.par / exact_divisor(xp, p["num_pes"])
            return latency, energy, util, mx, extras

        return model_key, model_params, terms

    def costs_from_batch(
        self, problem, arch, latency, energy, util, extras, indices=None
    ):
        ctx = get_context(problem, arch)
        clusters = arch.clusters
        freq = arch.frequency_hz
        cal_s = (
            float(self.calibration.scale) if self.calibration is not None else None
        )
        cc = extras["compute_cycles"]
        fills = [
            (clusters[i].name, extras[f"fill_cycles::{i}"], extras[f"fill_valid::{i}"])
            for i in ctx.real_levels
            if f"fill_cycles::{i}" in extras
        ]
        startup = extras["startup_cycles"]
        noc = extras["noc_energy_pj"]
        rows = range(latency.shape[0]) if indices is None else indices
        out = []
        for b in rows:
            breakdown = {"compute_cycles": float(cc[b])}
            for name, cyc, valid in fills:
                if valid[b]:
                    breakdown[f"fill_cycles_{name}"] = float(cyc[b])
            breakdown["startup_cycles"] = float(startup[b])
            breakdown["noc_energy_pj"] = float(noc[b])
            if cal_s is not None:
                # latency is already scaled inside the terms program; the
                # breakdown records the scale exactly like apply_calibration
                breakdown["calibration_scale"] = cal_s
            out.append(
                Cost(
                    latency_cycles=float(latency[b]),
                    energy_pj=float(energy[b]),
                    utilization=float(util[b]),
                    macs=problem.macs,
                    frequency_hz=freq,
                    breakdown=breakdown,
                )
            )
        return out

    def evaluate_signature(self, problem: Problem, arch: Architecture, sig):
        """Fused signature->Cost path: identical math (and float-operation
        order, so bit-identical results) to ``evaluate``, skipping the
        AccessProfile object assembly."""
        if not self.conformable(problem):
            raise ValueError(
                f"{self.name} only supports operations {_SUPPORTED_OPS}, "
                f"got {problem.operation!r} (unit op {problem.unit_op!r})"
            )
        ctx = get_context(problem, arch)
        compute_cycles, par, inst_at, _tl, _sl, rows = ctx.signature_traffic(sig)
        freq = arch.frequency_hz
        clusters = arch.clusters
        real_levels = ctx.real_levels
        real_parent = ctx.real_parent
        spaces = problem.data_spaces
        leaf = clusters[-1]

        latency = float(compute_cycles)
        breakdown = {"compute_cycles": float(compute_cycles)}
        startup = 0.0
        for pos, i in enumerate(real_levels):
            if i == 0:
                continue
            cl = clusters[i]
            if math.isinf(cl.fill_bandwidth):
                continue
            total_fill = 0.0
            tile_bytes = 0
            for ds_idx, ds in enumerate(spaces):
                r = rows[ds_idx][pos]
                total_fill += (r[0] + r[1]) * ds.word_bytes
                tile_bytes += r[5] * ds.word_bytes
            if total_fill <= 0:
                continue
            fill_cycles = total_fill * freq / cl.fill_bandwidth
            startup += tile_bytes * freq / cl.fill_bandwidth
            breakdown[f"fill_cycles_{cl.name}"] = fill_cycles
            latency = max(latency, fill_cycles)
        latency += startup
        breakdown["startup_cycles"] = startup

        energy = 0.0
        noc_energy = 0.0
        hop = self.etab.noc_hop_pj_byte
        for ds_idx, ds in enumerate(spaces):
            wb = ds.word_bytes
            dsr = rows[ds_idx]
            for pos, i in enumerate(real_levels):
                cl = clusters[i]
                fills, drains, preads, pwrites, inst, _foot = dsr[pos]
                energy += fills * inst * wb * cl.write_energy
                energy += drains * inst * wb * cl.read_energy
                parent_idx = real_parent[i]
                if parent_idx is not None:
                    parent = clusters[parent_idx]
                    n_parent = inst_at[parent_idx]
                    # source reads once per distinct datum (multicast-aware)
                    energy += preads * n_parent * wb * parent.read_energy
                    energy += pwrites * n_parent * wb * parent.write_energy
                    # but every DELIVERED copy pays a NoC hop
                    delivered = (fills + drains) * inst
                    noc_energy += delivered * wb * hop
            energy += ctx.l1_reads[ds.name] * wb * leaf.read_energy
        energy += problem.macs * leaf.mac_energy
        energy += noc_energy
        breakdown["noc_energy_pj"] = noc_energy

        return self.apply_calibration(Cost(
            latency_cycles=latency,
            energy_pj=energy,
            utilization=par / ctx.num_pes,
            macs=problem.macs,
            frequency_hz=freq,
            breakdown=breakdown,
        ))

    def evaluate_signature_batch(
        self,
        problem: Problem,
        arch: Architecture,
        sigs,
        backend: str = "numpy",
        stacked=None,
        select=None,
    ):
        """Vectorized ``evaluate_signature`` over a whole miss-batch (same
        float-operation order per candidate; bit-identical results, with a
        BATCH_EXACT_LIMIT guard that falls back to the scalar path). The
        latency/energy accumulation is the SAME array program the fused
        jitted single-dispatch path traces (``batch_cost_terms_fn``), run
        here with numpy over the admitted subset. ``stacked``/``select``
        reuse the engine's admission-stage StackedBatch (see
        ``CostModel.evaluate_signature_batch``)."""
        if not self.conformable(problem):
            raise ValueError(
                f"{self.name} only supports operations {_SUPPORTED_OPS}, "
                f"got {problem.operation!r} (unit op {problem.unit_op!r})"
            )
        ctx = get_context(problem, arch)
        bt = ctx.signature_traffic_batch(
            sigs, backend=backend, stacked=stacked, select=select
        )
        if bt is None:
            return None
        terms = self.batch_cost_terms_fn(problem, arch)
        latency, energy, util, mx, extras = terms(bt, np)
        if not (float(mx) < BATCH_EXACT_LIMIT):
            return None  # exactness not guaranteed: use the scalar path
        return self.costs_from_batch(problem, arch, latency, energy, util, extras)

    def evaluate(self, problem: Problem, mapping: Mapping, arch: Architecture) -> Cost:
        if not self.conformable(problem):
            raise ValueError(
                f"{self.name} only supports operations {_SUPPORTED_OPS}, "
                f"got {problem.operation!r} (unit op {problem.unit_op!r})"
            )
        prof = analyze(problem, mapping, arch)
        freq = arch.frequency_hz
        leaf = arch.clusters[-1]

        # ----- latency: per-level double-buffered schedule ---------------- #
        # steady-state per-outer-step time = max(compute chunk, fill chunk);
        # plus one pipeline-startup fill of the first tile at every level.
        compute_cycles = prof.compute_cycles
        latency = float(compute_cycles)
        breakdown = {"compute_cycles": float(compute_cycles)}
        startup = 0.0
        for i, cl in enumerate(arch.clusters):
            if cl.virtual or i == 0 or math.isinf(cl.fill_bandwidth):
                continue
            total_fill = boundary_bytes_per_instance(prof, problem, i)
            if total_fill <= 0:
                continue
            fill_cycles = total_fill * freq / cl.fill_bandwidth
            # first-tile startup: one tile's worth of fill is exposed
            tile_bytes = sum(
                prof.traffic[(ds.name, i)].tile_elems * ds.word_bytes
                for ds in problem.data_spaces
                if (ds.name, i) in prof.traffic
            )
            startup += tile_bytes * freq / cl.fill_bandwidth
            breakdown[f"fill_cycles_{cl.name}"] = fill_cycles
            latency = max(latency, fill_cycles)
        latency += startup
        breakdown["startup_cycles"] = startup

        # ----- energy: buffer accesses + NoC delivery hops ---------------- #
        energy = 0.0
        noc_energy = 0.0
        for ds in problem.data_spaces:
            wb = ds.word_bytes
            for i, cl in enumerate(arch.clusters):
                lt = prof.traffic.get((ds.name, i))
                if lt is None:
                    continue
                parent_idx = prof.real_parent[i]
                energy += lt.fills_per_instance * lt.instances * wb * cl.write_energy
                energy += lt.drains_per_instance * lt.instances * wb * cl.read_energy
                if parent_idx is not None:
                    parent = arch.clusters[parent_idx]
                    n_parent = prof.instances_at[parent_idx]
                    # source reads once per distinct datum (multicast-aware)
                    energy += lt.parent_reads * n_parent * wb * parent.read_energy
                    energy += lt.parent_writes * n_parent * wb * parent.write_energy
                    # but every DELIVERED copy pays a NoC hop
                    delivered = (lt.fills_per_instance + lt.drains_per_instance) * lt.instances
                    noc_energy += delivered * wb * self.etab.noc_hop_pj_byte
            energy += prof.l1_reads[ds.name] * wb * arch.clusters[-1].read_energy
        energy += problem.macs * leaf.mac_energy
        energy += noc_energy
        breakdown["noc_energy_pj"] = noc_energy

        return self.apply_calibration(Cost(
            latency_cycles=latency,
            energy_pj=energy,
            utilization=prof.utilization,
            macs=problem.macs,
            frequency_hz=freq,
            breakdown=breakdown,
        ))
