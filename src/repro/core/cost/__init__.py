"""Plug-and-play accelerator cost models (paper Sec. III-B2).

All cost models implement the same interface (``base.CostModel``) and
consume the same (Problem, Mapping, Architecture) triple -- that is the
paper's interoperability contribution: any mapper can drive any model.

  timeloop_like -- hierarchical memory-target analytical model
                   (per-level access counts + bandwidth roofline)
  maestro_like  -- cluster data-centric model (NoC multicast energy,
                   per-cluster scheduling)
  roofline      -- TPU v5e three-term roofline (compute/memory/collective)
"""

from repro.core.cost.base import Cost, CostModel  # noqa: F401
from repro.core.cost.engine import EngineStats, EvaluationEngine, mapping_signature  # noqa: F401
from repro.core.cost.store import ResultStore  # noqa: F401
from repro.core.cost.timeloop_like import TimeloopLikeModel  # noqa: F401
from repro.core.cost.maestro_like import MaestroLikeModel  # noqa: F401
from repro.core.cost.roofline import TPURooflineModel  # noqa: F401
