"""Timeloop-like hierarchical cost model (paper Sec. III-B2, [11]).

Loop-level model: accepts any Problem whose data spaces are affine
projections of a perfectly-nested loop iteration space (which is every
``Problem`` built by this repo's IR -- the conformability pass rejects
anything else, e.g. a unit-op mismatch).

Latency: perfect double buffering -- max(compute, per-level fill time).
Energy:  per-level access counts x per-byte access energies + MAC energy.
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.core.architecture import Architecture
from repro.core.cost.analysis import (
    BATCH_EXACT_LIMIT,
    analyze,
    batch_hierarchical_energy,
    boundary_bytes_per_instance,
    exact_divisor,
    generic_hierarchical_energy,
    get_context,
    hierarchical_lower_bound,
)
from repro.core.cost.base import Cost, CostModel
from repro.core.mapping import Mapping
from repro.core.problem import Problem


class TimeloopLikeModel(CostModel):
    name = "timeloop_like"

    def __init__(self, unit_op: str = "mac2") -> None:
        self.unit_op = unit_op

    def conformable(self, problem: Problem) -> bool:
        # loop-level: needs an affine perfectly-nested loop body whose unit
        # operation matches the energy model configuration (paper: MTTKRP is
        # rejected under a mac2-configured model but fine under mac3).
        return problem.unit_op == self.unit_op

    def lower_bound(self, problem: Problem, mapping, arch: Architecture, sig=None):
        return self._calibrate_bound(
            hierarchical_lower_bound(problem, mapping, arch, sig=sig)
        )

    def lower_bound_fn(self, problem: Problem, arch: Architecture):
        fn = get_context(problem, arch).signature_lower_bound
        if self.calibration is None:
            return fn
        return lambda sig: self._calibrate_bound(fn(sig))

    def lower_bound_chains_fn(self, problem: Problem, arch: Architecture):
        fn = get_context(problem, arch).chains_lower_bound
        if self.calibration is None:
            return fn
        # drop the optional (incumbent, scalarize) early-exit hints: they
        # live in CALIBRATED metric space while fn computes raw bounds --
        # computing the full raw bound and scaling it keeps the bound exact
        return lambda chain_list, orders, *_hints: self._calibrate_bound(
            fn(chain_list, orders)
        )

    def lower_bound_batch_fn(self, problem: Problem, arch: Architecture):
        fn = get_context(problem, arch).lower_bound_batch
        if self.calibration is None:
            return fn
        # same final multiply as the scalar ``_calibrate_bound`` per
        # element, so calibrated batch admission stays bit-identical
        s = float(self.calibration.scale)

        def calibrated(*args, **kwargs):
            out = fn(*args, **kwargs)
            if out is None:
                return None
            cyc, en = out
            return cyc * s, en

        return calibrated

    def batch_admit_core_builder(self, problem: Problem, arch: Architecture):
        builder = get_context(problem, arch)._make_lb_core
        if self.calibration is None:
            return builder
        s = float(self.calibration.scale)

        def calibrated_builder(xp, lax=None):
            core = builder(xp, lax)

            def calibrated_core(tt, st, perm):
                cyc, en, mx = core(tt, st, perm)
                return cyc * s, en, mx

            return calibrated_core

        return calibrated_builder

    def store_key_parts(self):
        return (self.name, self.unit_op) + self.calibration_key_parts()

    def batch_cost_terms_fn(self, problem: Problem, arch: Architecture):
        """Array-program twin of ``evaluate_signature``'s latency/energy
        accumulation: same float-operation order per row, runnable with
        numpy (host scoring) or jax.numpy (inside the fused jitted
        core). A calibration scale is applied as the final latency
        multiply, exactly as ``apply_calibration`` does on the scalar
        path. See ``CostModel.batch_cost_terms_fn``."""
        if not self.conformable(problem):
            return None
        cal_s = (
            float(self.calibration.scale) if self.calibration is not None else None
        )
        ctx = get_context(problem, arch)
        freq = arch.frequency_hz
        clusters = arch.clusters
        real_levels = ctx.real_levels
        spaces = problem.data_spaces
        num_pes = ctx.num_pes

        def terms(bt, xp):
            cc = bt.compute_cycles
            # par is guarded too: utilization must match the scalar path's
            # exact-int parallelism bit for bit
            mx = xp.maximum(
                xp.maximum(xp.max(cc), xp.max(bt.total_trips)), xp.max(bt.par)
            )
            worst = xp.zeros_like(cc)
            extras = {"compute_cycles": cc}
            for pos, i in enumerate(real_levels):
                cl = clusters[i]
                # the scalar path computes bts before skipping these levels
                # but never uses it; skipping first is value-identical (the
                # fills/drains factors are exactness-guarded in the energy
                # walk below)
                if i == 0 or math.isinf(cl.fill_bandwidth):
                    continue
                bts = xp.zeros_like(cc)
                for k, ds in enumerate(spaces):
                    t = (
                        bt.rows[k].fills[:, pos] + bt.rows[k].drains[:, pos]
                    ) * ds.word_bytes
                    mx = xp.maximum(mx, xp.max(t))
                    bts = bts + t
                cyc = bts * freq / exact_divisor(xp, cl.fill_bandwidth)
                extras[f"bw_cycles::{i}"] = cyc
                extras[f"bw_bytes::{i}"] = bts
                worst = xp.maximum(worst, xp.where(bts > 0, cyc, 0.0))
            latency = xp.maximum(cc, worst)
            energy, _noc, _mac, e_mx = batch_hierarchical_energy(
                ctx, arch, problem, bt, xp=xp
            )
            mx = xp.maximum(mx, e_mx)
            util = bt.par / exact_divisor(xp, num_pes)
            if cal_s is not None:
                latency = latency * cal_s
            return latency, energy, util, mx, extras

        return terms

    def batch_cost_terms_generic(self, problem: Problem, arch: Architecture):
        """Shape-generic twin of :meth:`batch_cost_terms_fn` (see
        ``CostModel.batch_cost_terms_generic``): structure = which real
        levels carry a finite-bandwidth fill term; every value (bandwidths,
        energies, word widths, calibration) rides in the parameter pack."""
        if not self.conformable(problem):
            return None
        ctx = get_context(problem, arch)
        clusters = arch.clusters
        real_levels = list(ctx.real_levels)
        real_parent = [-1 if p is None else p for p in ctx.real_parent]
        K = len(problem.data_spaces)
        bw_levels = tuple(
            (pos, i)
            for pos, i in enumerate(real_levels)
            if not (i == 0 or math.isinf(clusters[i].fill_bandwidth))
        )
        leaf = clusters[-1]
        cal = self.calibration
        model_key = (self.name, self.unit_op, bw_levels)
        model_params = {
            "tl_bw": np.asarray(
                [clusters[i].fill_bandwidth for _pos, i in bw_levels],
                dtype=np.float64,
            ),
            "num_pes": np.float64(ctx.num_pes),
            "lvl_read_e": np.asarray(
                [c.read_energy for c in clusters], dtype=np.float64
            ),
            "lvl_write_e": np.asarray(
                [c.write_energy for c in clusters], dtype=np.float64
            ),
            # innermost-operand terms precomputed host-side with Python
            # semantics (int products are exact; one final float multiply)
            "l1_terms": np.asarray(
                [
                    ctx.l1_reads[ds.name] * ds.word_bytes * leaf.read_energy
                    for ds in problem.data_spaces
                ],
                dtype=np.float64,
            ),
            "mac_term": np.float64(problem.macs * leaf.mac_energy),
            "calib_scale": np.float64(cal.scale) if cal is not None else np.float64(1.0),
        }

        def terms(bt, xp, p):
            cc = bt.compute_cycles
            mx = xp.maximum(
                xp.maximum(xp.max(cc), xp.max(bt.total_trips)), xp.max(bt.par)
            )
            worst = xp.zeros_like(cc)
            extras = {"compute_cycles": cc}
            for t, (pos, i) in enumerate(bw_levels):
                bts = xp.zeros_like(cc)
                for k in range(K):
                    tk = (
                        bt.rows[k].fills[:, pos] + bt.rows[k].drains[:, pos]
                    ) * p["wb"][k]
                    mx = xp.maximum(mx, xp.max(tk))
                    bts = bts + tk
                cyc = bts * p["freq"] / exact_divisor(xp, p["tl_bw"][t])
                extras[f"bw_cycles::{i}"] = cyc
                extras[f"bw_bytes::{i}"] = bts
                worst = xp.maximum(worst, xp.where(bts > 0, cyc, 0.0))
            latency = xp.maximum(cc, worst)
            energy, _noc, e_mx = generic_hierarchical_energy(
                real_levels, real_parent, K, bt, xp, p
            )
            mx = xp.maximum(mx, e_mx)
            util = bt.par / exact_divisor(xp, p["num_pes"])
            return latency, energy, util, mx, extras

        return model_key, model_params, terms

    def costs_from_batch(
        self, problem, arch, latency, energy, util, extras, indices=None
    ):
        ctx = get_context(problem, arch)
        clusters = arch.clusters
        freq = arch.frequency_hz
        cal_s = (
            float(self.calibration.scale) if self.calibration is not None else None
        )
        mac_term = problem.macs * clusters[-1].mac_energy
        cc = extras["compute_cycles"]
        bw = [
            (clusters[i].name, extras[f"bw_cycles::{i}"], extras[f"bw_bytes::{i}"])
            for i in ctx.real_levels
            if f"bw_cycles::{i}" in extras
        ]
        rows = range(latency.shape[0]) if indices is None else indices
        out = []
        for b in rows:
            breakdown = {"compute_cycles": float(cc[b])}
            for name, cyc, bts in bw:
                if bts[b] > 0:
                    breakdown[f"bw_cycles_{name}"] = float(cyc[b])
            breakdown["energy_mac_pj"] = mac_term
            if cal_s is not None:
                # latency is already scaled inside the terms program; the
                # breakdown records the scale exactly like apply_calibration
                breakdown["calibration_scale"] = cal_s
            out.append(
                Cost(
                    latency_cycles=float(latency[b]),
                    energy_pj=float(energy[b]),
                    utilization=float(util[b]),
                    macs=problem.macs,
                    frequency_hz=freq,
                    breakdown=breakdown,
                )
            )
        return out

    def evaluate_signature(self, problem: Problem, arch: Architecture, sig):
        """Fused signature->Cost path: identical math (and float-operation
        order, so bit-identical results) to ``evaluate``, skipping the
        AccessProfile object assembly."""
        if not self.conformable(problem):
            raise ValueError(
                f"{self.name} configured with unit op {self.unit_op!r} cannot "
                f"evaluate problem with unit op {problem.unit_op!r}"
            )
        ctx = get_context(problem, arch)
        compute_cycles, par, inst_at, _tl, _sl, rows = ctx.signature_traffic(sig)
        freq = arch.frequency_hz
        clusters = arch.clusters
        real_levels = ctx.real_levels
        real_parent = ctx.real_parent
        spaces = problem.data_spaces

        worst_bw_cycles = 0.0
        breakdown = {"compute_cycles": compute_cycles}
        for pos, i in enumerate(real_levels):
            if i == 0:
                continue
            cl = clusters[i]
            bts = 0.0
            for ds_idx, ds in enumerate(spaces):
                r = rows[ds_idx][pos]
                bts += (r[0] + r[1]) * ds.word_bytes
            if bts <= 0 or math.isinf(cl.fill_bandwidth):
                continue
            cyc = bts * freq / cl.fill_bandwidth
            breakdown[f"bw_cycles_{cl.name}"] = cyc
            worst_bw_cycles = max(worst_bw_cycles, cyc)
        latency = max(compute_cycles, worst_bw_cycles)

        energy = 0.0
        leaf = clusters[-1]
        for ds_idx, ds in enumerate(spaces):
            wb = ds.word_bytes
            dsr = rows[ds_idx]
            for pos, i in enumerate(real_levels):
                cl = clusters[i]
                fills, drains, preads, pwrites, inst, _foot = dsr[pos]
                energy += fills * inst * wb * cl.write_energy
                energy += drains * inst * wb * cl.read_energy
                parent_idx = real_parent[i]
                if parent_idx is not None:
                    parent = clusters[parent_idx]
                    n_parent = inst_at[parent_idx]
                    energy += preads * n_parent * wb * parent.read_energy
                    energy += pwrites * n_parent * wb * parent.write_energy
            energy += ctx.l1_reads[ds.name] * wb * leaf.read_energy
        energy += problem.macs * leaf.mac_energy
        breakdown["energy_mac_pj"] = problem.macs * leaf.mac_energy

        return self.apply_calibration(Cost(
            latency_cycles=latency,
            energy_pj=energy,
            utilization=par / ctx.num_pes,
            macs=problem.macs,
            frequency_hz=freq,
            breakdown=breakdown,
        ))

    def evaluate_signature_batch(
        self,
        problem: Problem,
        arch: Architecture,
        sigs,
        backend: str = "numpy",
        stacked=None,
        select=None,
    ):
        """Vectorized ``evaluate_signature`` over a whole miss-batch: same
        float-operation order per candidate, so results are bit-identical
        whenever every integer-valued product stays float64-exact (checked
        against BATCH_EXACT_LIMIT; returns None otherwise). The latency/
        energy accumulation is the SAME array program the fused jitted
        single-dispatch path traces (``batch_cost_terms_fn``), run here
        with numpy over the admitted subset. ``stacked``/``select`` reuse
        the engine's admission-stage StackedBatch (see
        ``CostModel.evaluate_signature_batch``)."""
        if not self.conformable(problem):
            raise ValueError(
                f"{self.name} configured with unit op {self.unit_op!r} cannot "
                f"evaluate problem with unit op {problem.unit_op!r}"
            )
        ctx = get_context(problem, arch)
        bt = ctx.signature_traffic_batch(
            sigs, backend=backend, stacked=stacked, select=select
        )
        if bt is None:
            return None
        terms = self.batch_cost_terms_fn(problem, arch)
        latency, energy, util, mx, extras = terms(bt, np)
        if not (float(mx) < BATCH_EXACT_LIMIT):
            return None  # exactness not guaranteed: use the scalar path
        return self.costs_from_batch(problem, arch, latency, energy, util, extras)

    def evaluate(self, problem: Problem, mapping: Mapping, arch: Architecture) -> Cost:
        if not self.conformable(problem):
            raise ValueError(
                f"{self.name} configured with unit op {self.unit_op!r} cannot "
                f"evaluate problem with unit op {problem.unit_op!r}"
            )
        prof = analyze(problem, mapping, arch)
        freq = arch.frequency_hz

        # ---------------- latency: compute vs per-level bandwidth ------- #
        compute_cycles = prof.compute_cycles
        worst_bw_cycles = 0.0
        breakdown = {"compute_cycles": compute_cycles}
        for i, cl in enumerate(arch.clusters):
            if cl.virtual or i == 0:
                continue
            bts = boundary_bytes_per_instance(prof, problem, i)
            if bts <= 0 or math.isinf(cl.fill_bandwidth):
                continue
            cyc = bts * freq / cl.fill_bandwidth
            breakdown[f"bw_cycles_{cl.name}"] = cyc
            worst_bw_cycles = max(worst_bw_cycles, cyc)
        latency = max(compute_cycles, worst_bw_cycles)

        # ---------------- energy ---------------------------------------- #
        energy = 0.0
        for ds in problem.data_spaces:
            for i, cl in enumerate(arch.clusters):
                lt = prof.traffic.get((ds.name, i))
                if lt is None:
                    continue
                parent_idx = prof.real_parent[i]
                wb = ds.word_bytes
                # writes into this buffer + reads back out of it on drain
                energy += lt.fills_per_instance * lt.instances * wb * cl.write_energy
                energy += lt.drains_per_instance * lt.instances * wb * cl.read_energy
                if parent_idx is not None:
                    parent = arch.clusters[parent_idx]
                    n_parent = prof.instances_at[parent_idx]
                    # parent_reads/writes are per-parent-instance counts with
                    # ideal multicast (irrelevant spatial splits read once)
                    energy += lt.parent_reads * n_parent * wb * parent.read_energy
                    energy += lt.parent_writes * n_parent * wb * parent.write_energy
            # innermost operand movement (L1 -> MAC datapath)
            leaf = arch.clusters[-1]
            energy += prof.l1_reads[ds.name] * ds.word_bytes * leaf.read_energy
        energy += problem.macs * arch.clusters[-1].mac_energy
        breakdown["energy_mac_pj"] = problem.macs * arch.clusters[-1].mac_energy

        return self.apply_calibration(Cost(
            latency_cycles=latency,
            energy_pj=energy,
            utilization=prof.utilization,
            macs=problem.macs,
            frequency_hz=freq,
            breakdown=breakdown,
        ))
