"""Shared reuse/traffic analysis over an expanded mapping loop nest.

This module turns (Problem, Mapping, Architecture) into per-buffer-level
access counts per data space, using the classic analytical-cost-model
reuse rules (Timeloop/Interstellar style):

  * A buffer at cluster level i holds one temporal tile TT^i per data space.
  * The tile held changes whenever a RELEVANT temporal loop above the
    residency advances (relevant = the loop's dim projects into the data
    space), or when an IRRELEVANT temporal loop that encloses a deeper
    relevant temporal loop advances (re-walk => refetch).
  * Relevant spatial distribution partitions data across instances;
    irrelevant spatial distribution multicasts the same tile (distinct
    parent reads are counted once under ideal multicast; per-instance
    fills are always counted).
  * Output data spaces additionally pay read-modify-write traffic when
    reduction loops enclose their residency.

The analysis is the hot path of every mapper search, so it is organised
around :class:`AnalysisContext`: all (problem, arch)-dependent metadata is
computed once and reused across the thousands of mappings a search
evaluates, and the per-mapping pass runs on the canonical signature (flat
int tuples in problem-dim order) with prefix products -- all-integer, so
results are exactly the ones the naive nested-loop formulation produces.
"""

from __future__ import annotations

import math
import os
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core.architecture import Architecture
from repro.core.mapping import Mapping, mapping_signature
from repro.core.problem import DataSpace, Problem

# Exactness headroom for the vectorized (float64) batch path: every
# integer-valued product the scalar analysis computes exactly (arbitrary-
# precision Python ints) must stay below 2**53 for the float pipeline to be
# bit-identical. Models reject the batch result (falling back to the scalar
# path) when any guarded quantity reaches this threshold; the extra factor
# of 2 absorbs rounding drift in the guard computation itself.
BATCH_EXACT_LIMIT = float(1 << 52)

# ---------------------------------------------------------------------- #
# Process-global trace registry. Every jitted dispatch registers its
# (program identity, padded batch size) combination here; the set's size
# is therefore the number of DISTINCT XLA traces the process has paid
# for. Shape-generic programs register under their structural
# ShapeClassKey -- content-different contexts in one shape class share a
# single entry per bucket -- while the legacy per-context programs
# register under the context's identity (each context is its own trace).
# Engines sample ``global_trace_count()`` deltas around their dispatches
# to attribute traces to a search (``EngineStats.n_traces``).
# ---------------------------------------------------------------------- #
_GENERIC_PROGRAMS: Dict[tuple, object] = {}
_TRACE_COMBOS: set = set()


def global_trace_count() -> int:
    """Number of distinct (program, padded batch size) jit traces this
    process has dispatched (shape-generic programs count once per shape
    class, not once per context)."""
    return len(_TRACE_COMBOS)


def _record_trace(program_key, padded_batch: int) -> None:
    _TRACE_COMBOS.add((program_key, int(padded_batch)))


def reset_trace_registry() -> None:
    """Drop trace accounting AND the shared generic-program cache (test
    isolation helper; compiled programs are rebuilt on demand)."""
    _TRACE_COMBOS.clear()
    _GENERIC_PROGRAMS.clear()


def exact_divisor(xp, v):
    """A host constant to DIVIDE by inside a traced array program.

    numpy returns the plain value. Under a jax trace the constant is
    wrapped in an optimization barrier so XLA's simplifier cannot fold
    ``x / c`` into ``x * (1/c)`` -- that rewrite is exact only for powers
    of two and would break bit-identity with the host numpy division for
    every other bandwidth/frequency/PE-count constant.
    """
    if xp is np:
        return v
    from jax import lax

    # asarray (not the float64 constructor) so TRACED scalars -- the
    # shape-generic cores divide by parameter values -- pass through the
    # barrier unchanged; host constants take the same asarray path.
    return lax.optimization_barrier(xp.asarray(v, dtype=xp.float64))


def ordered_sum(xp, init, addends):
    """Left-associated ``((init + a0) + a1) + ...`` with numpy semantics.

    On numpy this is the plain accumulation loop. Under a jax trace the
    addends are stacked and summed by ``lax.scan``: the while-loop
    boundary forces every addend (typically an ``int_counts * energy``
    product) to be materialized -- i.e. ROUNDED -- before the sequential
    adds, and XLA cannot fuse producer multiplies into the loop body, so
    the LLVM backend can never contract ``acc + a*b`` into an FMA. This
    is what keeps fractional (energy) accumulations bit-identical between
    the host numpy program and the fused jitted core; integer-valued
    accumulations don't need it (exact under FMA or not).
    """
    if xp is np:
        acc = init
        for a in addends:
            acc = acc + a
        return acc
    if not addends:
        return init
    from jax import lax

    stacked = xp.stack([xp.broadcast_to(a, init.shape) for a in addends])
    out, _ = lax.scan(lambda acc, a: (acc + a, None), init, stacked)
    return out


def ordered_pair_sum(xp, init, pairs):
    """Left-associated ``acc + (x + y)`` accumulation over ``pairs``, with
    the same contraction-proof scan structure as :func:`ordered_sum` (the
    inner ``x + y`` rounds first, exactly as the scalar/numpy programs
    associate their two-term energy addends). Pass ``y = 0.0`` for single
    addends: ``x + 0.0`` is exact for the non-negative energy terms."""
    if xp is np:
        acc = init
        for x, y in pairs:
            acc = acc + (x + y)
        return acc
    if not pairs:
        return init
    from jax import lax

    stacked = xp.stack(
        [
            xp.stack(
                [xp.broadcast_to(x, init.shape), xp.broadcast_to(y, init.shape)]
            )
            for x, y in pairs
        ]
    )
    out, _ = lax.scan(
        lambda acc, p: (acc + (p[0] + p[1]), None), init, stacked
    )
    return out


def batch_projection_footprint(axes, ttf_lvl, xp=np):
    """Batched data-space footprint over one level's tile rows.

    ``axes`` is one entry of :attr:`AnalysisContext.ds_projection_axes`
    (lists of ``(|coeff|, dim_index)`` terms per projection axis);
    ``ttf_lvl`` is the clamped float64 tile matrix ``[B, D]`` of one
    level. Replays the scalar span math (``span = 1 + sum(coeff *
    (tt[j] - 1))``, footprint = product of spans) in the same float-op
    order, so results are exact below :data:`BATCH_EXACT_LIMIT`. The one
    batched form of the projection-span product -- the lower-bound cores
    and the roofline bound all consume it.
    """
    B = ttf_lvl.shape[0]
    foot = xp.ones(B, dtype=xp.float64)
    for ax in axes:
        span = xp.ones(B, dtype=xp.float64)
        for coeff, j in ax:
            span = span + coeff * (ttf_lvl[:, j] - 1.0)
        foot = foot * span
    return foot


class StackedBatch:
    """Stacked (tt, st, perm) matrices for one batch of signatures.

    One StackedBatch is built per engine miss-batch and SHARED between the
    admission stage (:meth:`AnalysisContext.lower_bound_batch`) and the
    scoring stage (:meth:`AnalysisContext.signature_traffic_batch`), so the
    batch is stacked exactly once. On the JAX backend the matrices are
    additionally uploaded to the device once (``dev``) and reused by both
    jitted programs; the scoring stage gathers the admitted subset directly
    on device (``select``), so only the admitted candidates' traffic ever
    returns to host.
    """

    __slots__ = ("tt", "st", "perm", "dev", "devp")

    def __init__(self, tt: np.ndarray, st: np.ndarray, perm: np.ndarray) -> None:
        self.tt = tt
        self.st = st
        self.perm = perm
        self.dev = None  # (tt, st, perm) device arrays, uploaded lazily
        # pow2-PADDED device arrays for the fused full-batch programs
        # (padding runs host-side in numpy before ONE upload: traced
        # pad ops cost ~1ms/dispatch on CPU jax, numpy pads in ~2us)
        self.devp = None

    @property
    def size(self) -> int:
        return int(self.tt.shape[0])


class DsTrafficBatch(NamedTuple):
    """Per-data-space traffic arrays over a signature batch.

    Every array is float64 of shape ``[B, L]`` where ``L`` indexes
    ``AnalysisContext.real_levels``. Values are exact integers as long as
    they stay below :data:`BATCH_EXACT_LIMIT` (the models enforce this).
    """

    fills: np.ndarray
    drains: np.ndarray
    parent_reads: np.ndarray
    parent_writes: np.ndarray
    foot: np.ndarray


class BatchTraffic(NamedTuple):
    """Stacked result of :meth:`AnalysisContext.signature_traffic_batch`.

    The float arrays mirror the tuples :meth:`signature_traffic` returns
    per candidate; ``tt``/``st``/``fans`` are the clamped int64 tile
    matrices (``[B, n_levels, D]``) so model-specific terms (e.g. the
    roofline collective model) can derive further quantities without
    re-stacking the signatures.
    """

    compute_cycles: np.ndarray  # [B] float64
    total_trips: np.ndarray  # [B] float64
    par: np.ndarray  # [B] float64
    inst_at: np.ndarray  # [B, n_levels] float64 (instances above each level)
    tt: np.ndarray  # [B, n_levels, D] int64
    st: np.ndarray  # [B, n_levels, D] int64
    fans: np.ndarray  # [B, n_levels, D] int64
    rows: Tuple[DsTrafficBatch, ...]  # one entry per data space


class Loop(NamedTuple):
    level: int  # mapping/cluster level index (0 = outermost)
    kind: str  # "temporal" | "spatial"
    dim: str
    trips: int


class LevelTraffic(NamedTuple):
    """Per-buffer-level traffic for ONE data space (elements, not bytes)."""

    fills_per_instance: int = 0  # elements read into one instance from parent
    drains_per_instance: int = 0  # output elements written back to parent
    parent_reads: int = 0  # distinct element-reads served by ONE parent instance
    parent_writes: int = 0  # distinct element-writes absorbed by ONE parent instance
    instances: int = 1  # number of instances of this level in the machine
    tile_elems: int = 0  # resident tile footprint (elements)


@dataclass
class AccessProfile:
    """Full result of the analysis."""

    loops: List[Loop]
    # traffic[(ds_name, level_idx)] -> LevelTraffic; only non-virtual levels
    traffic: Dict[Tuple[str, int], LevelTraffic] = field(default_factory=dict)
    compute_cycles: float = 0.0
    leaf_tile_macs: int = 0
    total_temporal_trips: int = 1
    parallelism: int = 1
    utilization: float = 0.0
    l1_reads: Dict[str, int] = field(default_factory=dict)  # innermost accesses per ds
    # convenience lookups the cost models would otherwise re-derive per level:
    instances_at: List[int] = field(default_factory=list)  # spatial instances above each level
    real_parent: List[Optional[int]] = field(default_factory=list)  # nearest non-virtual level above


def expand_loops(problem: Problem, mapping: Mapping) -> List[Loop]:
    loops: List[Loop] = []
    for i, lm in enumerate(mapping.levels):
        trips = mapping.temporal_trips(i, problem)
        order = list(lm.temporal_order) + [d for d in problem.dims if d not in lm.temporal_order]
        for d in order:
            if trips[d] > 1:
                loops.append(Loop(i, "temporal", d, trips[d]))
        fan = mapping.spatial_fanout(i, problem)
        for d in problem.dims:
            if fan[d] > 1:
                loops.append(Loop(i, "spatial", d, fan[d]))
    return loops


def _real_parent(arch: Architecture, i: int) -> Optional[int]:
    """Nearest non-virtual cluster level above i (list index)."""
    for j in range(i - 1, -1, -1):
        if not arch.clusters[j].virtual:
            return j
    return None


class AnalysisContext:
    """Precomputed (Problem, Architecture) metadata for fast repeated analysis.

    One context is built per (problem, arch) pair and amortised over every
    mapping a search evaluates. ``analyze`` on a context produces results
    identical to evaluating the classic formulation loop by loop (the
    module-level :func:`analyze` delegates here).
    """

    def __init__(self, problem: Problem, arch: Architecture) -> None:
        self.problem = problem
        self.arch = arch
        self.dims: List[str] = list(problem.dims.keys())
        self.dim_sizes: Dict[str, int] = dict(problem.dims)
        self.n_levels = arch.n_levels
        self.virtual: List[bool] = [cl.virtual for cl in arch.clusters]
        self.real_levels: List[int] = [
            i for i in range(self.n_levels) if not self.virtual[i]
        ]
        self.real_parent: List[Optional[int]] = [
            _real_parent(arch, i) for i in range(self.n_levels)
        ]
        self.macs_per_cycle = max(1, arch.clusters[-1].macs_per_cycle)
        self.num_pes = max(1, arch.num_pes)
        self.total_macs = problem.macs
        self._dims_t: Tuple[str, ...] = tuple(self.dims)
        self._dim_index = {d: j for j, d in enumerate(self.dims)}
        # order tuple -> dim-index tuple memo (orders repeat heavily)
        self._order_idx: Dict[Tuple[str, ...], Tuple[int, ...]] = {}
        self._size_tuple: Tuple[int, ...] = tuple(problem.dims[d] for d in self.dims)
        # per data space: relevance (names + dim indices) + innermost accesses
        self.ds_rel: List[Tuple[DataSpace, frozenset]] = [
            (ds, frozenset(ds.dims)) for ds in problem.data_spaces
        ]
        self._ds_rel_idx: List[Tuple[int, ...]] = [
            tuple(sorted(self._dim_index[d] for d in ds.dims))
            for ds in problem.data_spaces
        ]
        self._ds_rel_sets: List[set] = [set(t) for t in self._ds_rel_idx]
        self.l1_reads: Dict[str, int] = {
            ds.name: (2 * self.total_macs if ds.is_output else self.total_macs)
            for ds in problem.data_spaces
        }
        # footprint memo: (ds index, level tile tuple) -> elements. Level
        # tiles recur heavily across candidates (elites, crossover reuse
        # whole per-dim chains), so this short-circuits most extent math.
        self._foot_cache: Dict[Tuple[int, Tuple[int, ...]], int] = {}
        # --- signature-based lower-bound machinery (engine hot path) ---- #
        freq = arch.frequency_hz
        self._lb_bw_levels: List[Tuple[int, float]] = [
            (i, freq / arch.clusters[i].fill_bandwidth)
            for i in self.real_levels
            if i > 0 and not math.isinf(arch.clusters[i].fill_bandwidth)
        ]
        self._ds_axes_idx: List[Tuple[int, List[List[Tuple[int, int]]], Tuple[int, ...]]] = [
            (
                ds.word_bytes,
                [
                    [(abs(t.coeff), self._dim_index[t.dim]) for t in expr.terms]
                    for expr in ds.projection
                ],
                self._ds_rel_idx[k],
            )
            for k, ds in enumerate(problem.data_spaces)
        ]
        leaf = arch.clusters[-1]
        self._lb_energy_base: float = problem.macs * leaf.mac_energy + sum(
            self.l1_reads[ds.name] * ds.word_bytes * leaf.read_energy
            for ds in problem.data_spaces
        )
        # The first real level whose parent is the (real) outermost level:
        # its parent_reads/parent_writes energy terms can be reproduced
        # exactly in the lower bound (n_parent == 1 there). When the
        # architecture has no such level the energy floor degrades to the
        # base (MAC + innermost) term.
        self._lb_dram_child: Optional[int] = None
        self._top_read_e = 0.0
        self._top_write_e = 0.0
        if len(self.real_levels) >= 2 and self.real_levels[0] == 0:
            self._lb_dram_child = self.real_levels[1]
            self._top_read_e = arch.clusters[0].read_energy
            self._top_write_e = arch.clusters[0].write_energy
        # --- vectorized batch-analysis state (built lazily) ------------- #
        self._np_batch_core = None
        self._jax_batch_core = None
        self._np_lb_core = None
        self._jax_lb_core = None
        self._jax = None
        self._jax_failed = False
        self._jax_core_donates = False
        # jitted-program invocations (lb, traffic, or fused admit+score):
        # the observable "dispatches per batch" count tests probe.
        self.jax_dispatches = 0
        # fused admit+score runners, keyed by (model store-key parts,
        # metric): engines come and go per search, the compiled program
        # is reused (equal store_key_parts => bit-identical costs, so
        # sharing is sound by the same contract the ResultStore relies on)
        self._fused_runners: Dict[Tuple, object] = {}
        # shape-generic machinery (lazy): the structural key + traced
        # parameter pack that let ONE process-global compiled program
        # serve every context in this shape class
        self._shape_class_key: Optional[tuple] = None
        self._shape_params: Optional[Dict[str, np.ndarray]] = None

    @property
    def ds_projection_axes(self) -> List[Tuple[int, List[List[Tuple[int, int]]], Tuple[int, ...]]]:
        """Per data space (problem order): ``(word_bytes, axes, rel_idx)``.

        ``axes`` holds one list of ``(|coeff|, dim_index)`` terms per
        projection axis (the span of axis ``a`` over a tile ``tt`` is
        ``1 + sum(coeff * (tt[j] - 1))``); ``rel_idx`` is the sorted tuple
        of dim indices that project into the data space. This is the public
        form of the projection metadata the footprint/bound math consumes
        -- model-specific terms (e.g. the roofline collective sharding
        spans) should use it instead of the private ``_ds_axes_idx``.
        """
        return self._ds_axes_idx

    # ------------------------------------------------------------------ #
    # Shape-generic program support. ``shape_class_key`` captures every
    # STRUCTURAL property the batch/lower-bound cores branch or reshape
    # on (ranks, level topology, projection term layout, which levels
    # carry bandwidth terms); ``shape_params`` packs every VALUE those
    # cores consume (dim sizes, projection coefficients, energies,
    # bandwidth reciprocals) as arrays whose shapes are fully determined
    # by the key. Two contexts with equal keys therefore run the SAME
    # compiled program -- only the parameter pack differs -- and because
    # the generic cores replay the per-context closures' float operations
    # in the identical order, results stay bit-identical per row.
    # ------------------------------------------------------------------ #
    def shape_class_key(self) -> tuple:
        """Structural identity of this context's array programs (hashable;
        equal keys <=> one compiled shape-generic program serves both
        contexts)."""
        if self._shape_class_key is None:
            axes_struct = tuple(
                tuple(tuple(j for _c, j in ax) for ax in axes)
                for _wb, axes, _rel in self._ds_axes_idx
            )
            self._shape_class_key = (
                self.n_levels,
                len(self.dims),
                len(self._ds_rel_sets),
                tuple(self.real_levels),
                tuple(-1 if p is None else p for p in self.real_parent),
                tuple(bool(ds.is_output) for ds, _rel in self.ds_rel),
                axes_struct,
                -1 if self._lb_dram_child is None else self._lb_dram_child,
                tuple(lv for lv, _c in self._lb_bw_levels),
            )
        return self._shape_class_key

    def shape_params(self) -> Dict[str, np.ndarray]:
        """Traced parameter pack for the shape-generic cores: every value
        the per-context closures bake in as Python constants, as arrays
        keyed/shaped by :meth:`shape_class_key` (content may differ across
        contexts of one class; shapes never do)."""
        if self._shape_params is None:
            D = len(self.dims)
            coeffs = [
                float(c)
                for _wb, axes, _rel in self._ds_axes_idx
                for ax in axes
                for c, _j in ax
            ]
            self._shape_params = {
                "sizes": np.asarray(self._size_tuple, dtype=np.int64),
                "mpc": np.float64(self.macs_per_cycle),
                "rel": np.array(
                    [[j in rset for j in range(D)] for rset in self._ds_rel_sets],
                    dtype=bool,
                ),
                "coeffs": np.asarray(coeffs, dtype=np.float64),
                "wb": np.asarray(
                    [wb for wb, _a, _r in self._ds_axes_idx], dtype=np.float64
                ),
                "e_base": np.float64(self._lb_energy_base),
                "tre": np.float64(self._top_read_e),
                "twe": np.float64(self._top_write_e),
                "bw_cpb": np.asarray(
                    [c for _lv, c in self._lb_bw_levels], dtype=np.float64
                ),
                "freq": np.float64(self.arch.frequency_hz),
            }
        return self._shape_params

    # ------------------------------------------------------------------ #
    def analyze(self, mapping: Mapping) -> AccessProfile:
        # the engine / Genome stash the already-computed signature on the
        # mapping object; mappings are treated as immutable once evaluated
        cached = mapping.__dict__.get("_sig_cache")
        if cached is not None and cached[0] == self._dims_t:
            return self.analyze_signature(cached[1])
        return self.analyze_signature(mapping_signature(mapping, self.dims))

    def signature_traffic(self, sig):
        """The reuse core, off the canonical signature, as plain arrays.

        ``sig`` is ``mapping_signature(mapping, self.dims)``: per level the
        (effective order, TT tuple, ST tuple) in problem-dim order.

        Returns ``(compute_cycles, par, inst_at, tloops, sloops, rows)``:
        ``rows[ds_idx]`` lists, per entry of ``self.real_levels``, the tuple
        ``(fills, drains, parent_reads, parent_writes, instances, foot)``.
        Both :meth:`analyze_signature` (object form) and the cost models'
        fused ``evaluate_signature`` paths consume THIS single core, so the
        reuse rules live in exactly one place.
        """
        dims = self.dims
        dim_index = self._dim_index
        D = len(dims)
        n = self.n_levels

        # ---- loop nest expansion (identical to expand_loops) ----------- #
        order_idx = self._order_idx
        tloops: List[Tuple[int, int, int]] = []  # (level, dim_idx, trips)
        sloops: List[Tuple[int, int, int]] = []
        outer = self._size_tuple
        for i in range(n):
            order, tt, st = sig[i]
            trips = [0] * D
            for j in range(D):
                trips[j] = max(1, outer[j] // max(1, tt[j]))
            oidx = order_idx.get(order)
            if oidx is None:
                oidx = tuple(dim_index[d] for d in order)
                order_idx[order] = oidx
            for j in oidx:
                q = trips[j]
                if q > 1:
                    tloops.append((i, j, q))
            for j in range(D):
                f = max(1, tt[j]) // max(1, st[j])
                if f > 1:
                    sloops.append((i, j, f))
            outer = st

        # ---- totals ---------------------------------------------------- #
        total_trips = 1
        for _lv, _j, q in tloops:
            total_trips *= q
        par = 1
        for _lv, _j, f in sloops:
            par *= f
        leaf_macs = 1
        for t in sig[-1][1]:
            leaf_macs *= t
        compute_cycles = total_trips * math.ceil(leaf_macs / self.macs_per_cycle)

        # ---- per-level shared precomputation --------------------------- #
        # tloops/sloops are ordered by level, so the loops "above" a level's
        # residency are a PREFIX of each list:
        #   temporal prefix at level i = tloops with level <= i
        #   spatial  prefix at level i = sloops with level <  i
        t_prefix = [0] * n
        s_prefix = [0] * n
        k = 0
        for i in range(n):
            while k < len(tloops) and tloops[k][0] <= i:
                k += 1
            t_prefix[i] = k
        c = 0
        for i in range(n):
            while c < len(sloops) and sloops[c][0] < i:
                c += 1
            s_prefix[i] = c
        # product of ALL spatial trips in each prefix (= instances)
        sall = [1] * (len(sloops) + 1)
        for j, (_lv, _dj, f) in enumerate(sloops):
            sall[j + 1] = sall[j] * f
        inst_at = [sall[s_prefix[i]] for i in range(n)]

        foot_cache = self._foot_cache
        if len(foot_cache) > (1 << 17):
            foot_cache.clear()
        tiles_dicts: List[Optional[Dict[str, int]]] = [None] * n
        real_levels = self.real_levels
        real_parent = self.real_parent

        # ---- per data space -------------------------------------------- #
        rows: List[List[Tuple[int, int, int, int, int, int]]] = []
        for ds_idx, (ds, _rel) in enumerate(self.ds_rel):
            rel_set = self._ds_rel_sets[ds_idx]
            # temporal prefix products:
            #   relprod[j] = prod of RELEVANT trips among first j temporal loops
            #   chgprod[j] = relprod[j] * (irrelevant trips enclosing a deeper
            #                relevant loop) -- i.e. irrelevant loops positioned
            #                before the LAST relevant loop in the prefix.
            T = len(tloops)
            relprod = [1] * (T + 1)
            chgprod = [1] * (T + 1)
            rp = 1
            ip = 1  # running product of irrelevant trips seen so far
            lastrel_ip = 1  # irrelevant product at the most recent relevant loop
            for j, (_lv, dj, q) in enumerate(tloops):
                if dj in rel_set:
                    rp *= q
                    lastrel_ip = ip
                else:
                    ip *= q
                relprod[j + 1] = rp
                chgprod[j + 1] = rp * lastrel_ip
            # spatial prefix products restricted to relevant dims
            srel = [1] * (len(sloops) + 1)
            for j, (_lv, dj, f) in enumerate(sloops):
                srel[j + 1] = srel[j] * (f if dj in rel_set else 1)

            is_out = ds.is_output
            ds_rows: List[Tuple[int, int, int, int, int, int]] = []
            for i in real_levels:
                kT = t_prefix[i]
                changes = chgprod[kT]
                unique = relprod[kT]
                tt = sig[i][1]
                fkey = (ds_idx, tt)
                foot = foot_cache.get(fkey)
                if foot is None:
                    tile = tiles_dicts[i]
                    if tile is None:
                        tile = {dims[j]: tt[j] for j in range(D)}
                        tiles_dicts[i] = tile
                    foot = ds.footprint(tile)
                    foot_cache[fkey] = foot
                cS = s_prefix[i]
                inst = sall[cS]
                pr = real_parent[i]
                if pr is None:
                    rel_spatial = 1
                else:
                    rel_spatial = srel[cS] // srel[s_prefix[pr]]

                cf = changes * foot
                if not is_out:
                    # one parent instance serves the instances between parent
                    # and i; ideal multicast: only RELEVANT spatial splits are
                    # distinct.
                    ds_rows.append((cf, 0, cf * rel_spatial, 0, inst, foot))
                else:
                    rmw = max(0, changes - unique) * foot  # RMW refills
                    ds_rows.append(
                        (rmw, cf, rmw * rel_spatial, cf * rel_spatial, inst, foot)
                    )
            rows.append(ds_rows)
        return compute_cycles, par, inst_at, tloops, sloops, rows

    def analyze_signature(self, sig) -> AccessProfile:
        """Object form of :meth:`signature_traffic` (AccessProfile API)."""
        dims = self.dims
        compute_cycles, par, inst_at, tloops, sloops, rows = self.signature_traffic(sig)
        # rebuild the interleaved loop list (temporal then spatial per level)
        loops: List[Loop] = []
        ti = si = 0
        for i in range(self.n_levels):
            while ti < len(tloops) and tloops[ti][0] == i:
                _lv, j, q = tloops[ti]
                loops.append(Loop(i, "temporal", dims[j], q))
                ti += 1
            while si < len(sloops) and sloops[si][0] == i:
                _lv, j, f = sloops[si]
                loops.append(Loop(i, "spatial", dims[j], f))
                si += 1
        prof = AccessProfile(loops=loops)
        total_trips = 1
        for _lv, _j, q in tloops:
            total_trips *= q
        leaf_macs = 1
        for t in sig[-1][1]:
            leaf_macs *= t
        prof.leaf_tile_macs = leaf_macs
        prof.total_temporal_trips = total_trips
        prof.parallelism = par
        prof.utilization = par / self.num_pes
        prof.compute_cycles = compute_cycles
        prof.l1_reads = dict(self.l1_reads)
        prof.instances_at = inst_at
        prof.real_parent = self.real_parent
        for ds_idx, (ds, _rel) in enumerate(self.ds_rel):
            ds_rows = rows[ds_idx]
            for pos, i in enumerate(self.real_levels):
                prof.traffic[(ds.name, i)] = LevelTraffic(*ds_rows[pos])
        return prof

    # ------------------------------------------------------------------ #
    # Vectorized batch analysis: a whole miss-batch of signatures scored
    # as one array program. ``signature_traffic_batch`` stacks the batch
    # into dense [B, n_levels, D] tile/order matrices and runs the same
    # reuse rules as ``signature_traffic`` over all candidates at once --
    # numpy by default, optionally a jitted JAX program for device
    # sweeps. All quantities are integer-valued and computed in float64;
    # they are exact (bit-identical to the scalar path) as long as they
    # stay below BATCH_EXACT_LIMIT, which the cost models enforce before
    # trusting a batch result.
    # ------------------------------------------------------------------ #
    def stack_signatures(self, sigs):
        """Dense (tt, st, perm) int64 matrices ``[B, n_levels, D]`` for a
        batch of canonical signatures. ``perm[b, i, p]`` is the dim index
        at position ``p`` of level ``i``'s effective temporal order."""
        n = self.n_levels
        order_idx = self._order_idx
        dim_index = self._dim_index
        B = len(sigs)
        D = len(self.dims)
        count = B * n * D
        tt = np.fromiter(
            (v for sig in sigs for lvl in sig for v in lvl[1]),
            dtype=np.int64,
            count=count,
        ).reshape(B, n, D)
        st = np.fromiter(
            (v for sig in sigs for lvl in sig for v in lvl[2]),
            dtype=np.int64,
            count=count,
        ).reshape(B, n, D)

        def idx_of(order):
            oidx = order_idx.get(order)
            if oidx is None:
                oidx = tuple(dim_index[d] for d in order)
                order_idx[order] = oidx
            return oidx

        perm = np.fromiter(
            (j for sig in sigs for lvl in sig for j in idx_of(lvl[0])),
            dtype=np.int64,
            count=count,
        ).reshape(B, n, D)
        return tt, st, perm

    def stacked_batch(self, sigs) -> StackedBatch:
        """One :class:`StackedBatch` handle over ``stack_signatures(sigs)``,
        shareable between the admission and scoring array programs."""
        return StackedBatch(*self.stack_signatures(sigs))

    def _make_batch_core(self, xp, lax=None):
        """Build the (tt, st, perm) -> stacked-traffic array program.

        ``xp`` is numpy or jax.numpy; ``lax`` supplies ``cummax`` on the
        JAX path. The program is the exact vectorization of
        :meth:`signature_traffic`: same trip/fan derivation, same
        relevant/irrelevant prefix products (the order-dependent
        ``changes`` term uses a cummax over the last relevant loop
        position), same footprint spans.
        """
        sizes_row = np.asarray(self._size_tuple, dtype=np.int64)[None, None, :]
        n = self.n_levels
        D = len(self.dims)
        real_levels = list(self.real_levels)
        L = len(real_levels)
        real_parent = self.real_parent
        mpc = self.macs_per_cycle
        K = len(self._ds_rel_sets)
        # [K, D] relevance mask, stacked over data spaces: the reuse
        # cumprods below run for ALL data spaces in one array op.
        rel_stack = np.array(
            [[j in rset for j in range(D)] for rset in self._ds_rel_sets], dtype=bool
        )
        ds_axes = [axes for _wb, axes, _rel in self._ds_axes_idx]
        ds_out = [ds.is_output for ds, _rel in self.ds_rel]
        ends = np.asarray([(i + 1) * D - 1 for i in real_levels])
        real_arr = np.asarray(real_levels)
        # parent gather indices for rel_spatial (parentless levels divide by
        # themselves -> ratio 1.0 exactly)
        parent_arr = np.asarray(
            [real_parent[i] if real_parent[i] is not None else i for i in real_levels]
        )
        pos_seq = np.arange(n * D)

        def core(tt, st, perm):
            B = tt.shape[0]
            tt = xp.maximum(tt, 1)
            st = xp.maximum(st, 1)
            outer = xp.concatenate(
                [xp.broadcast_to(xp.asarray(sizes_row), (B, 1, D)), st[:, :-1, :]],
                axis=1,
            )
            trips = xp.maximum(outer // tt, 1)
            fans = xp.maximum(tt // st, 1)
            tripsf = trips.astype(xp.float64)
            fansf = fans.astype(xp.float64)
            total_trips = xp.prod(tripsf.reshape(B, n * D), axis=1)
            leaf_macs = xp.prod(tt[:, -1, :].astype(xp.float64), axis=1)
            compute_cycles = total_trips * xp.ceil(leaf_macs / exact_divisor(xp, mpc))
            par = xp.prod(fansf.reshape(B, n * D), axis=1)
            lvl_all = xp.prod(fansf, axis=2)  # [B, n]
            cp_all = xp.cumprod(lvl_all, axis=1)
            inst_at = xp.concatenate(
                [xp.ones((B, 1), dtype=xp.float64), cp_all[:, :-1]], axis=1
            )
            # temporal loop sequence in emission order (order-major per level)
            perm_flat = perm.reshape(B, n * D)
            tseqf = xp.take_along_axis(trips, perm, axis=2).reshape(B, n * D).astype(
                xp.float64
            )
            # ---- all data spaces at once: [K, B, S] ---------------------- #
            rel_seq = xp.asarray(rel_stack)[:, perm_flat]  # [K, B, S]
            present = (tseqf > 1.0)[None, :, :]
            relm = rel_seq & present
            irrm = (~rel_seq) & present
            tseq_b = xp.broadcast_to(tseqf[None, :, :], (K, B, n * D))
            relprod = xp.cumprod(xp.where(relm, tseq_b, 1.0), axis=2)
            irrprod = xp.cumprod(xp.where(irrm, tseq_b, 1.0), axis=2)
            # irrelevant-trip product at the LAST relevant loop <= s: gather
            # the (exclusive == inclusive, s is relevant) irrprod at that
            # position, 1.0 when no relevant loop yet.
            idx = xp.where(relm, pos_seq[None, None, :], -1)
            if lax is None:
                lastrel = np.maximum.accumulate(idx, axis=2)
            else:
                lastrel = lax.cummax(idx, axis=2)
            gathered = xp.take_along_axis(irrprod, xp.maximum(lastrel, 0), axis=2)
            ip = xp.where(lastrel >= 0, gathered, 1.0)
            unique = relprod[:, :, ends]  # [K, B, L]
            changes = unique * ip[:, :, ends]
            # spatial: relevant-fan products per level, exclusive cumprod
            lvl_rel = xp.prod(
                xp.where(xp.asarray(rel_stack)[:, None, None, :], fansf[None], 1.0),
                axis=3,
            )  # [K, B, n]
            cp_rel = xp.cumprod(lvl_rel, axis=2)
            srel_excl = xp.concatenate(
                [xp.ones((K, B, 1), dtype=xp.float64), cp_rel[:, :, :-1]], axis=2
            )
            # exact: srel_excl at the parent divides srel_excl at the level
            rel_sp = srel_excl[:, :, real_arr] / srel_excl[:, :, parent_arr]
            # footprints per data space (projections differ per ds)
            ttf_real = tt[:, real_arr, :].astype(xp.float64)  # [B, L, D]
            rows = []
            for k in range(K):
                foot = xp.ones((B, L), dtype=xp.float64)
                for ax in ds_axes[k]:
                    span = xp.ones((B, L), dtype=xp.float64)
                    for coeff, j in ax:
                        span = span + coeff * (ttf_real[:, :, j] - 1.0)
                    foot = foot * span
                cf = changes[k] * foot
                if ds_out[k]:
                    rmw = xp.maximum(changes[k] - unique[k], 0.0) * foot
                    rows.append((rmw, cf, rmw * rel_sp[k], cf * rel_sp[k], foot))
                else:
                    z = xp.zeros_like(cf)
                    rows.append((cf, z, cf * rel_sp[k], z, foot))
            return compute_cycles, total_trips, par, inst_at, tt, st, fans, tuple(rows)

        return core

    def _ensure_jax(self):
        """Import JAX lazily; memoized on the context.

        ``UNION_FAULT_JAX=1`` simulates a broken jax install (import/trace
        failure) at the exact point every jax path funnels through: the
        raise is caught by the callers' degradation handling, which sets
        ``_jax_failed`` and falls back to numpy -- the path the sweep
        executor's ``jaxfail`` fault spec and the CI fault-injection tests
        exercise without needing a genuinely broken toolchain.
        """
        if os.environ.get("UNION_FAULT_JAX"):
            raise RuntimeError("injected jax backend failure (UNION_FAULT_JAX)")
        if self._jax is None:
            import jax

            self._jax = jax
        return self._jax

    def _jax_device_arrays(self, sb: StackedBatch):
        """Upload a StackedBatch's matrices to the device once (int64; the
        caller holds ``enable_x64``) and memoize them on the handle, so the
        admission and scoring programs share one transfer."""
        if sb.dev is None:
            jax = self._ensure_jax()
            sb.dev = tuple(jax.device_put(a) for a in (sb.tt, sb.st, sb.perm))
        return sb.dev

    def _jax_device_padded(self, sb: StackedBatch):
        """Pow2-padded device matrices for the fused full-batch programs:
        ``(tt, st, perm, B)`` with the batch axis padded to the next power
        of two by repeating row 0 (a real candidate -- identical to
        :meth:`_pad_pow2`, so guards and results are bit-identical).
        Padding runs HOST-SIDE in numpy and the three matrices ship as a
        single transfer, memoized on the handle: device-side pad ops cost
        ~1ms of dispatch overhead per call on CPU jax, which dominated the
        per-generation cost of the device-resident search loops."""
        if sb.devp is None:
            jax = self._ensure_jax()
            B = sb.size
            B2 = 1 << max(0, (B - 1).bit_length())
            if B2 == B:
                mats = (sb.tt, sb.st, sb.perm)
            else:
                padn = B2 - B
                mats = tuple(
                    np.ascontiguousarray(
                        np.concatenate(
                            [a, np.broadcast_to(a[:1], (padn,) + a.shape[1:])]
                        )
                    )
                    for a in (sb.tt, sb.st, sb.perm)
                )
            sb.devp = jax.device_put(mats) + (B,)
        return sb.devp

    @staticmethod
    def _pad_pow2(tt, st, perm, xp):
        """Pad the batch axis to the next power of two (bounding jit
        retraces) by repeating row 0 -- a real candidate, so padding can
        never trip the exactness guard (the lb core's guard reduces over
        the padded batch) -- and return the original size too."""
        B = int(tt.shape[0])
        B2 = 1 << max(0, (B - 1).bit_length())
        if B2 == B:
            return tt, st, perm, B
        padn = B2 - B

        def pad(a):
            return xp.concatenate(
                [a, xp.broadcast_to(a[:1], (padn,) + tuple(a.shape[1:]))]
            )

        return pad(tt), pad(st), pad(perm), B

    def _run_jax_core(self, sb: StackedBatch, select=None):
        """JAX-jitted batch core over a (device-resident) StackedBatch:
        optionally gathers the ``select`` row subset ON DEVICE, pads the
        batch to a power of two (bounding retraces), runs in float64 under
        ``enable_x64``, returns numpy arrays of the unpadded (selected)
        batch -- or None so the caller falls back to numpy (missing jax,
        trace failure, restricted platform)."""
        if self._jax_failed:
            return None
        try:
            jax = self._ensure_jax()
            from jax import lax
            import jax.numpy as jnp

            if self._jax_batch_core is None:
                # Buffer donation lets XLA reuse the input matrices' device
                # memory for the program's temporaries; it is unsupported
                # (and warns) on CPU, so only accelerator backends request
                # it. The donated buffers are the batch matrices, which are
                # re-uploaded from the host copy if the handle is reused.
                donate = (0, 1, 2) if jax.default_backend() != "cpu" else ()
                self._jax_core_donates = bool(donate)
                self._jax_batch_core = jax.jit(
                    self._make_batch_core(jnp, lax), donate_argnums=donate
                )
            from jax.experimental import enable_x64

            with enable_x64():
                tt, st, perm = self._jax_device_arrays(sb)
                if select is not None:
                    sel = jnp.asarray(np.asarray(select, dtype=np.int64))
                    tt, st, perm = tt[sel], st[sel], perm[sel]
                tt, st, perm, B = self._pad_pow2(tt, st, perm, jnp)
                self.jax_dispatches += 1
                _record_trace(("ctx-core", id(self)), int(tt.shape[0]))
                out = self._jax_batch_core(tt, st, perm)
            if self._jax_core_donates and select is None:
                sb.dev = None  # donated away; re-upload on next use
            out = jax.tree_util.tree_map(np.asarray, out)
            if out[0].dtype != np.float64:
                # x64 unavailable on this build: results are float32 and
                # cannot honour the bit-identity contract
                self._jax_failed = True
                return None
            if out[0].shape[0] != B:
                out = _tree_slice(out, B)
            return out
        except Exception:
            self._jax_failed = True
            return None

    def signature_traffic_batch(
        self,
        sigs=None,
        backend: str = "numpy",
        stacked: Optional[StackedBatch] = None,
        select=None,
    ) -> Optional[BatchTraffic]:
        """Stacked :meth:`signature_traffic` over a batch of signatures.

        ``backend`` selects the array program: ``"numpy"`` (default) or
        ``"jax"`` (jitted, falls back to numpy when JAX cannot deliver
        float64). ``stacked`` reuses an already-stacked batch -- the
        evaluation engine stacks each miss-batch ONCE and shares the handle
        between the admission filter and this scoring pass. ``select``
        restricts the program to the given row indices of the stacked
        batch (on the jax backend the gather runs on device, so pruned
        candidates' traffic never returns to host). Returns None for an
        empty batch/selection.
        """
        sb = stacked
        if sb is None:
            if not sigs:
                return None
            sb = self.stacked_batch(sigs)
        if sb.size == 0 or (select is not None and len(select) == 0):
            return None
        out = None
        if backend == "jax":
            out = self._run_jax_core(sb, select=select)
        if out is None:
            if self._np_batch_core is None:
                self._np_batch_core = self._make_batch_core(np)
            tt, st, perm = sb.tt, sb.st, sb.perm
            if select is not None:
                idx = np.asarray(select, dtype=np.int64)
                tt, st, perm = tt[idx], st[idx], perm[idx]
            out = self._np_batch_core(tt, st, perm)
        compute_cycles, total_trips, par, inst_at, tt_c, st_c, fans, rows = out
        return BatchTraffic(
            compute_cycles=np.asarray(compute_cycles),
            total_trips=np.asarray(total_trips),
            par=np.asarray(par),
            inst_at=np.asarray(inst_at),
            tt=np.asarray(tt_c),
            st=np.asarray(st_c),
            fans=np.asarray(fans),
            rows=tuple(DsTrafficBatch(*(np.asarray(a) for a in r)) for r in rows),
        )

    # ------------------------------------------------------------------ #
    # Cheap chain-only bounds (no reuse analysis). Used by the evaluation
    # engine's admission filter: every quantity here is a LOWER bound on
    # the corresponding quantity of the full analysis. All operate on the
    # canonical signature, so the engine reuses the tuple it already
    # computed for the cache probe.
    # ------------------------------------------------------------------ #
    def signature_compute_cycles(self, sig) -> float:
        """Exactly ``AccessProfile.compute_cycles``, without the analysis."""
        outer = self._size_tuple
        D = len(outer)
        total_trips = 1
        for _order, tt, st in sig:
            for j in range(D):
                q = outer[j] // (tt[j] or 1)
                if q > 1:
                    total_trips *= q
            outer = st
        leaf_macs = 1
        for t in sig[-1][1]:
            leaf_macs *= max(1, t)
        return total_trips * math.ceil(leaf_macs / self.macs_per_cycle)

    def signature_min_boundary_bytes(self, sig, level: int) -> float:
        """Lower bound on fill+drain bytes into one instance of ``level``
        from compulsory traffic alone (one tile footprint per data space)."""
        tt = sig[level][1]
        total = 0.0
        for wb, axes, _rel in self._ds_axes_idx:
            foot = 1
            for ax in axes:
                span = 1
                for coeff, j in ax:
                    span += coeff * (max(1, tt[j]) - 1)
                foot *= span
            total += foot * wb
        return total

    def signature_lower_bound(self, sig) -> Tuple[float, float]:
        """(cycles, energy_pj) lower bounds for the hierarchical models.

        cycles: max of the exact compute cycles and, per bandwidth-limited
        level, a fill-time floor of ``unique x footprint`` bytes per data
        space -- ``unique`` (the product of relevant temporal trips above
        the residency) never exceeds ``changes``, and both fills (inputs)
        and drains (outputs) scale with ``changes``, so this stays a true
        lower bound while discriminating much harder against reuse-poor
        tilings than compulsory traffic alone.

        energy: MAC + innermost-operand terms plus the EXACT outermost-
        memory access term (parent reads/writes of the level right below
        the top real memory, where ``n_parent == 1``); remaining buffer and
        NoC terms are non-negative, so the sum stays a true lower bound.
        At that same level the fill-cycle floor uses the exact ``changes``
        too.
        """
        outer = self._size_tuple
        D = len(outer)
        total_trips = 1
        trips_rows: List[List[int]] = []
        for _order, tt, st in sig:
            row = [1] * D
            for j in range(D):
                q = outer[j] // (tt[j] or 1)
                if q > 1:
                    row[j] = q
                    total_trips *= q
            trips_rows.append(row)
            outer = st
        leaf_macs = 1
        for t in sig[-1][1]:
            leaf_macs *= max(1, t)
        cycles = total_trips * math.ceil(leaf_macs / self.macs_per_cycle)

        energy = self._lb_energy_base
        dc = self._lb_dram_child
        dc_boundary = 0.0
        if dc is not None:
            # temporal loops of levels <= dc in effective emission order and
            # spatial fans of levels < dc: enough to reproduce the model's
            # changes/unique/rel_spatial at the dram-child level exactly.
            order_idx = self._order_idx
            dim_index = self._dim_index
            tl: List[Tuple[int, int]] = []
            for i in range(dc + 1):
                row = trips_rows[i]
                order = sig[i][0]
                oidx = order_idx.get(order)
                if oidx is None:
                    oidx = tuple(dim_index[d] for d in order)
                    order_idx[order] = oidx
                for j in oidx:
                    q = row[j]
                    if q > 1:
                        tl.append((j, q))
            fans: List[Tuple[int, int]] = []
            for i in range(dc):
                _o, tt_i, st_i = sig[i]
                for j in range(D):
                    f = max(1, tt_i[j]) // max(1, st_i[j])
                    if f > 1:
                        fans.append((j, f))
            tt_dc = sig[dc][1]
            tre = self._top_read_e
            twe = self._top_write_e
            for ds_idx, (ds, _r) in enumerate(self.ds_rel):
                rel_set = self._ds_rel_sets[ds_idx]
                rp = 1
                ip = 1
                lastrel = 1
                for j, q in tl:
                    if j in rel_set:
                        rp *= q
                        lastrel = ip
                    else:
                        ip *= q
                changes = rp * lastrel
                unique = rp
                wb, axes, _rel = self._ds_axes_idx[ds_idx]
                foot = 1
                for ax in axes:
                    span = 1
                    for coeff, j in ax:
                        span += coeff * (max(1, tt_dc[j]) - 1)
                    foot *= span
                rel_sp = 1
                for j, f in fans:
                    if j in rel_set:
                        rel_sp *= f
                cf = changes * foot
                if ds.is_output:
                    rmw = max(0, changes - unique) * foot
                    energy += cf * rel_sp * wb * twe + rmw * rel_sp * wb * tre
                    dc_boundary += (cf + rmw) * wb
                else:
                    energy += cf * rel_sp * wb * tre
                    dc_boundary += cf * wb

        for level, cyc_per_byte in self._lb_bw_levels:
            if level == dc:
                cyc = dc_boundary * cyc_per_byte  # exact fill bytes there
                if cyc > cycles:
                    cycles = cyc
                continue
            b = 0
            tt = sig[level][1]
            for wb, axes, rel in self._ds_axes_idx:
                unique = 1
                for r in range(level + 1):
                    row = trips_rows[r]
                    for j in rel:
                        unique *= row[j]
                foot = 1
                for ax in axes:
                    span = 1
                    for coeff, j in ax:
                        span += coeff * (max(1, tt[j]) - 1)
                    foot *= span
                b += unique * foot * wb
            cyc = b * cyc_per_byte
            if cyc > cycles:
                cycles = cyc
        return cycles, energy

    # ------------------------------------------------------------------ #
    # Batched lower bounds: the admission filter's counterpart of
    # ``signature_traffic_batch``. One array program reproduces
    # ``signature_lower_bound`` for a whole stacked batch -- same integer
    # quantities, same float-operation order -- so the engine admits or
    # rejects an entire miss-batch with one masked program instead of a
    # per-candidate Python walk. All guarded quantities are integer-valued;
    # the program tracks their max and the wrapper rejects the batch
    # (caller falls back to the scalar bound) beyond BATCH_EXACT_LIMIT.
    # ------------------------------------------------------------------ #
    def _make_lb_core(self, xp, lax=None):
        """Build the (tt, st, perm) -> (cycles[B], energy_pj[B], guard_max)
        program: the exact vectorization of :meth:`signature_lower_bound`."""
        sizes_row = np.asarray(self._size_tuple, dtype=np.int64)[None, None, :]
        n = self.n_levels
        D = len(self.dims)
        mpc = self.macs_per_cycle
        K = len(self._ds_rel_sets)
        rel_stack = np.array(
            [[j in rset for j in range(D)] for rset in self._ds_rel_sets], dtype=bool
        )
        wb_list = [wb for wb, _axes, _rel in self._ds_axes_idx]
        ds_axes = [axes for _wb, axes, _rel in self._ds_axes_idx]
        ds_out = [ds.is_output for ds, _rel in self.ds_rel]
        e_base = self._lb_energy_base
        dc = self._lb_dram_child
        tre = self._top_read_e
        twe = self._top_write_e
        bw_levels = list(self._lb_bw_levels)
        pos_seq = np.arange(n * D)

        def ds_foot(ttf_lvl, k):
            return batch_projection_footprint(ds_axes[k], ttf_lvl, xp)

        def core(tt, st, perm):
            B = tt.shape[0]
            tt = xp.maximum(tt, 1)
            st = xp.maximum(st, 1)
            outer = xp.concatenate(
                [xp.broadcast_to(xp.asarray(sizes_row), (B, 1, D)), st[:, :-1, :]],
                axis=1,
            )
            trips = xp.maximum(outer // tt, 1)
            tripsf = trips.astype(xp.float64)
            total_trips = xp.prod(tripsf.reshape(B, n * D), axis=1)
            leaf_macs = xp.prod(tt[:, -1, :].astype(xp.float64), axis=1)
            cycles = total_trips * xp.ceil(leaf_macs / exact_divisor(xp, mpc))
            # fractional energy addends are collected as (x, y) pairs and
            # summed through ordered_pair_sum -- contraction-proof on the
            # jitted path, plain left-associated adds on numpy
            e_pairs = []
            mx = xp.maximum(xp.maximum(total_trips, leaf_macs), cycles)

            dc_boundary = None
            if dc is not None:
                # temporal loops of levels <= dc in effective emission order
                # (order-major): enough to reproduce changes/unique exactly.
                S = (dc + 1) * D
                perm_pref = perm[:, : dc + 1, :]
                tseqf = (
                    xp.take_along_axis(trips[:, : dc + 1, :], perm_pref, axis=2)
                    .reshape(B, S)
                    .astype(xp.float64)
                )
                rel_seq = xp.asarray(rel_stack)[:, perm_pref.reshape(B, S)]  # [K,B,S]
                present = (tseqf > 1.0)[None, :, :]
                relm = rel_seq & present
                irrm = (~rel_seq) & present
                tseq_b = xp.broadcast_to(tseqf[None, :, :], (K, B, S))
                unique = xp.prod(xp.where(relm, tseq_b, 1.0), axis=2)  # [K, B]
                irrprod = xp.cumprod(xp.where(irrm, tseq_b, 1.0), axis=2)
                # irrelevant-trip product at the LAST relevant loop: position
                # itself is relevant, so the inclusive irrprod there equals
                # the scalar path's exclusive ``lastrel_ip``; 1.0 when no
                # relevant loop exists.
                idx = xp.where(relm, pos_seq[None, None, :S], -1)
                lastrel = xp.max(idx, axis=2)
                gathered = xp.take_along_axis(
                    irrprod, xp.maximum(lastrel, 0)[:, :, None], axis=2
                )[:, :, 0]
                changes = unique * xp.where(lastrel >= 0, gathered, 1.0)
                ttf_dc = tt[:, dc, :].astype(xp.float64)
                if dc > 0:
                    fans_pref = xp.maximum(tt[:, :dc, :] // st[:, :dc, :], 1).astype(
                        xp.float64
                    )
                dc_boundary = xp.zeros(B, dtype=xp.float64)
                for k in range(K):
                    foot = ds_foot(ttf_dc, k)
                    if dc > 0:
                        rel_sp = xp.prod(
                            xp.where(
                                xp.asarray(rel_stack[k])[None, None, :], fans_pref, 1.0
                            ).reshape(B, dc * D),
                            axis=1,
                        )
                    else:
                        rel_sp = xp.ones(B, dtype=xp.float64)
                    cf = changes[k] * foot
                    mx = xp.maximum(mx, changes[k])
                    t1 = cf * rel_sp * wb_list[k]
                    mx = xp.maximum(mx, t1)
                    if ds_out[k]:
                        rmw = xp.maximum(changes[k] - unique[k], 0.0) * foot
                        t2 = rmw * rel_sp * wb_list[k]
                        mx = xp.maximum(mx, t2)
                        e_pairs.append((t1 * twe, t2 * tre))
                        dc_boundary = dc_boundary + (cf + rmw) * wb_list[k]
                    else:
                        # x + 0.0 is exact for the non-negative term, so the
                        # pair form reproduces ``energy + t1 * tre``
                        e_pairs.append((t1 * tre, 0.0))
                        dc_boundary = dc_boundary + cf * wb_list[k]
                mx = xp.maximum(mx, dc_boundary)
            energy = ordered_pair_sum(
                xp, xp.full((B,), e_base, dtype=xp.float64), e_pairs
            )

            for level, cyc_per_byte in bw_levels:
                if level == dc:
                    cycles = xp.maximum(cycles, dc_boundary * cyc_per_byte)
                    continue
                ttf_lvl = tt[:, level, :].astype(xp.float64)
                # unique per ds: product of relevant trips of levels <= level
                relprod_lvl = xp.prod(
                    xp.where(
                        xp.asarray(rel_stack)[:, None, None, :],
                        tripsf[None, :, : level + 1, :],
                        1.0,
                    ).reshape(K, B, (level + 1) * D),
                    axis=2,
                )
                b = xp.zeros(B, dtype=xp.float64)
                for k in range(K):
                    term = relprod_lvl[k] * ds_foot(ttf_lvl, k) * wb_list[k]
                    mx = xp.maximum(mx, term)
                    b = b + term
                mx = xp.maximum(mx, b)
                cycles = xp.maximum(cycles, b * cyc_per_byte)
            return cycles, energy, xp.max(mx)

        return core

    def _run_jax_lb(self, sb: StackedBatch):
        """Jitted lower-bound core over a device-resident StackedBatch; the
        uploaded matrices stay on ``sb.dev`` for the scoring pass. Returns
        numpy (cycles, energy, guard) or None (fallback to numpy)."""
        if self._jax_failed:
            return None
        try:
            jax = self._ensure_jax()
            from jax import lax
            import jax.numpy as jnp

            if self._jax_lb_core is None:
                # never donate here: the scoring pass reuses sb.dev
                self._jax_lb_core = jax.jit(self._make_lb_core(jnp, lax))
            from jax.experimental import enable_x64

            with enable_x64():
                tt, st, perm = self._jax_device_arrays(sb)
                tt, st, perm, B = self._pad_pow2(tt, st, perm, jnp)
                self.jax_dispatches += 1
                _record_trace(("ctx-lb", id(self)), int(tt.shape[0]))
                cyc, en, mx = self._jax_lb_core(tt, st, perm)
            cyc = np.asarray(cyc)
            if cyc.dtype != np.float64:
                self._jax_failed = True
                return None
            return cyc[:B], np.asarray(en)[:B], np.asarray(mx)
        except Exception:
            self._jax_failed = True
            return None

    def lower_bound_batch(
        self,
        sigs=None,
        backend: str = "numpy",
        stacked: Optional[StackedBatch] = None,
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Stacked :meth:`signature_lower_bound`: float64 ``(cycles[B],
        energy_pj[B])`` arrays, bit-identical per candidate to the scalar
        bound, or None when the batch is empty or exactness cannot be
        guaranteed (any guarded integer quantity at/above
        :data:`BATCH_EXACT_LIMIT` -- the caller then falls back to the
        per-candidate bound). ``stacked`` shares an already-stacked batch
        with the scoring pass (see :meth:`signature_traffic_batch`)."""
        sb = stacked
        if sb is None:
            if not sigs:
                return None
            sb = self.stacked_batch(sigs)
        if sb.size == 0:
            return None
        out = None
        if backend == "jax":
            out = self._run_jax_lb(sb)
        if out is None:
            if self._np_lb_core is None:
                self._np_lb_core = self._make_lb_core(np)
            out = self._np_lb_core(sb.tt, sb.st, sb.perm)
        cycles, energy, mx = out
        if not (float(mx) < BATCH_EXACT_LIMIT):
            return None
        return np.asarray(cycles), np.asarray(energy)

    # ------------------------------------------------------------------ #
    # Single-dispatch fused admit+score. One jitted program runs the
    # model's lower-bound core, derives the admit mask, runs the traffic
    # core, and accumulates the model's latency/energy/utilization terms
    # -- so one dispatch per miss-batch covers the whole pipeline and only
    # per-candidate scalars (plus small [B] breakdown arrays) ever return
    # to host. The numpy backend keeps the two-stage flow but runs the
    # SAME terms array program per row, so values are bit-identical.
    # ------------------------------------------------------------------ #
    def _metric_scalarize(self, metric: str, xp):
        """Device-traceable twin of ``EvaluationEngine._scalarize_batch``:
        identical float operations per element (the frequency divisor goes
        through :func:`exact_divisor`), so on-device admit/reject decisions
        are bit-identical to the host filter."""
        freq = self.arch.frequency_hz
        if metric == "latency":
            return lambda cyc, en: cyc
        if metric == "energy":
            return lambda cyc, en: en
        if metric == "edp":
            return lambda cyc, en: (en * 1e-12) * (cyc / exact_divisor(xp, freq))
        return lambda cyc, en: cyc * 0.0

    def _make_fused_core(self, xp, lax, lb_builder, terms, metric: str):
        """Build the (tt, st, perm, incumbent) -> (admit[B], lb_guard,
        latency[B], energy[B], util[B], score_guard, extras) program.

        ``lb_builder(xp, lax)`` yields the model's admission-bound core
        (``CostModel.batch_admit_core_builder``); ``terms`` is the model's
        cost-terms program (``CostModel.batch_cost_terms_fn``). Both guard
        maxes come back so the host can fall back exactly where the
        two-stage path would (lb guard -> scalar bound; score guard ->
        scalar/numpy scoring of the admitted subset).
        """
        lb_core = lb_builder(xp, lax)
        traffic_core = self._make_batch_core(xp, lax)
        scalarize = self._metric_scalarize(metric, xp)

        def core(tt, st, perm, incumbent):
            lb_cyc, lb_en, lb_mx = lb_core(tt, st, perm)
            admit = scalarize(lb_cyc, lb_en) < incumbent
            out = traffic_core(tt, st, perm)
            bt = BatchTraffic(
                compute_cycles=out[0],
                total_trips=out[1],
                par=out[2],
                inst_at=out[3],
                tt=out[4],
                st=out[5],
                fans=out[6],
                rows=tuple(DsTrafficBatch(*r) for r in out[7]),
            )
            latency, energy, util, score_mx, extras = terms(bt, xp)
            return admit, lb_mx, latency, energy, util, score_mx, extras

        return core

    def build_fused_runner(self, lb_builder, terms, metric: str, cache_key=None):
        """Jitted single-dispatch admit+score runner for one (model,
        metric): ``run(sb, incumbent) -> (admit[B] bool, lb_guard float,
        latency[B], energy[B], util[B], score_guard float, extras)`` as
        host numpy, or None (jax unavailable / x64 undeliverable / trace
        failure -- the engine then keeps the two-stage flow). The stacked
        batch is uploaded once and padded to a power of two (padding
        repeats row 0, a real candidate, so neither guard can trip on
        padding); only [B]-sized result arrays cross back to host.

        ``cache_key`` (model store-key parts + metric, from the engine)
        memoizes the runner on the context so repeated searches over the
        same (problem, arch, model, metric) reuse the compiled program
        instead of re-tracing per engine.
        """
        if self._jax_failed:
            return None
        if cache_key is not None:
            cached = self._fused_runners.get(cache_key)
            if cached is not None:
                return cached
        try:
            jax = self._ensure_jax()
            from jax import lax
            import jax.numpy as jnp
        except Exception:
            self._jax_failed = True
            return None
        try:
            # Donation mirrors the traffic core: XLA may reuse the batch
            # matrices' device memory on accelerator backends (unsupported
            # on CPU); the incumbent scalar (arg 3) is never donated.
            donate = (0, 1, 2) if jax.default_backend() != "cpu" else ()
            core = jax.jit(
                self._make_fused_core(jnp, lax, lb_builder, terms, metric),
                donate_argnums=donate,
            )
        except Exception:
            self._jax_failed = True
            return None

        def run(sb: StackedBatch, incumbent: float):
            if self._jax_failed:
                return None
            try:
                from jax.experimental import enable_x64

                with enable_x64():
                    tt, st, perm, B = self._jax_device_padded(sb)
                    inc = jnp.asarray(float(incumbent), dtype=jnp.float64)
                    self.jax_dispatches += 1
                    _record_trace(
                        ("ctx-fused", id(self), cache_key), int(tt.shape[0])
                    )
                    out = core(tt, st, perm, inc)
                if donate:
                    sb.devp = None  # donated away; fallbacks re-upload
                admit, lb_mx, latency, energy, util, score_mx, extras = out
                latency = np.asarray(latency)
                if latency.dtype != np.float64:
                    # x64 unavailable: cannot honour bit-identity
                    self._jax_failed = True
                    return None
                return (
                    np.asarray(admit)[:B],
                    float(np.asarray(lb_mx)),
                    latency[:B],
                    np.asarray(energy)[:B],
                    np.asarray(util)[:B],
                    float(np.asarray(score_mx)),
                    {k: np.asarray(v)[:B] for k, v in extras.items()},
                )
            except Exception:
                self._jax_failed = True
                return None

        if cache_key is not None:
            self._fused_runners[cache_key] = run
        return run

    def build_generic_fused_runner(self, generic, metric: str, cache_key=None):
        """Shape-generic twin of :meth:`build_fused_runner`: the jitted
        program is compiled ONCE per (shape class, model structure,
        metric) process-wide (``_GENERIC_PROGRAMS``) and this context's
        values enter as a traced parameter pack, so content-different
        sweep points in one shape class share a single trace.

        ``generic`` is ``CostModel.batch_cost_terms_generic`` output:
        ``(model_struct_key, model_params, terms)`` with
        ``terms(bt, xp, p)``. Returns a :class:`GenericFusedRunner`
        (same call protocol as the per-context runner) or None (jax
        unavailable / trace failure -- callers fall back exactly as for
        the per-context builder). ``cache_key`` memoizes the runner on
        the context as the lookup tier ABOVE the global program cache.
        """
        if self._jax_failed:
            return None
        if cache_key is not None:
            cached = self._fused_runners.get(cache_key)
            if cached is not None:
                return cached
        model_key, model_params, terms = generic
        try:
            jax = self._ensure_jax()
            from jax import lax
            import jax.numpy as jnp
        except Exception:
            self._jax_failed = True
            return None
        skey = self.shape_class_key()
        pkey = ("generic-fused", skey, model_key, metric)
        entry = _GENERIC_PROGRAMS.get(pkey)
        if entry is None:
            try:
                donate = (0, 1, 2) if jax.default_backend() != "cpu" else ()
                core = jax.jit(
                    _make_generic_fused_core(skey, terms, metric, jnp, lax),
                    donate_argnums=donate,
                )
            except Exception:
                self._jax_failed = True
                return None
            entry = (core, bool(donate))
            _GENERIC_PROGRAMS[pkey] = entry
        params = dict(self.shape_params())
        params.update(model_params)
        runner = GenericFusedRunner(self, entry[0], params, pkey, entry[1])
        if cache_key is not None:
            self._fused_runners[cache_key] = runner
        return runner

    def chains_lower_bound(
        self, chain_list, orders, incumbent: float = math.inf, scalarize=None
    ) -> Tuple[float, float]:
        """``signature_lower_bound`` computed directly off per-dim divisor
        chains (in problem-dim order) + per-level orders -- the genome fast
        path, skipping signature construction for candidates that will be
        pruned. Returns exactly what ``signature_lower_bound`` returns for
        the equivalent signature, EXCEPT when the caller provides
        ``(incumbent, scalarize)`` and the compute-cycles term alone already
        proves domination: then the boundary/energy refinements are skipped
        and a smaller (still valid) energy floor is returned.
        """
        sizes = self._size_tuple
        D = len(sizes)
        n = self.n_levels
        trips_rows: List[List[int]] = [[1] * D for _ in range(n)]
        total_trips = 1
        leaf_macs = 1
        last = 2 * n - 2
        for j in range(D):
            ch = chain_list[j]
            prev = sizes[j]
            for i in range(n):
                q = prev // (ch[2 * i] or 1)
                if q > 1:
                    trips_rows[i][j] = q
                    total_trips *= q
                prev = ch[2 * i + 1]
            leaf_macs *= max(1, ch[last])
        cycles = total_trips * math.ceil(leaf_macs / self.macs_per_cycle)

        energy = self._lb_energy_base
        if scalarize is not None and scalarize(cycles, energy) >= incumbent:
            # already dominated by the cheap floor -- skip the refinements
            return cycles, energy
        dc = self._lb_dram_child
        dc_boundary = 0.0
        if dc is not None:
            order_idx = self._order_idx
            dim_index = self._dim_index
            tl: List[Tuple[int, int]] = []
            for i in range(dc + 1):
                row = trips_rows[i]
                order = orders[i]
                oidx = order_idx.get(order)
                if oidx is None:
                    oidx = tuple(dim_index[d] for d in order)
                    order_idx[order] = oidx
                for j in oidx:
                    q = row[j]
                    if q > 1:
                        tl.append((j, q))
            fans: List[Tuple[int, int]] = []
            for i in range(dc):
                k = 2 * i
                for j in range(D):
                    ch = chain_list[j]
                    f = max(1, ch[k]) // max(1, ch[k + 1])
                    if f > 1:
                        fans.append((j, f))
            kdc = 2 * dc
            tre = self._top_read_e
            twe = self._top_write_e
            for ds_idx, (ds, _r) in enumerate(self.ds_rel):
                rel_set = self._ds_rel_sets[ds_idx]
                rp = 1
                ip = 1
                lastrel = 1
                for j, q in tl:
                    if j in rel_set:
                        rp *= q
                        lastrel = ip
                    else:
                        ip *= q
                changes = rp * lastrel
                unique = rp
                wb, axes, _rel = self._ds_axes_idx[ds_idx]
                foot = 1
                for ax in axes:
                    span = 1
                    for coeff, j in ax:
                        span += coeff * (max(1, chain_list[j][kdc]) - 1)
                    foot *= span
                rel_sp = 1
                for j, f in fans:
                    if j in rel_set:
                        rel_sp *= f
                cf = changes * foot
                if ds.is_output:
                    rmw = max(0, changes - unique) * foot
                    energy += cf * rel_sp * wb * twe + rmw * rel_sp * wb * tre
                    dc_boundary += (cf + rmw) * wb
                else:
                    energy += cf * rel_sp * wb * tre
                    dc_boundary += cf * wb

        for level, cyc_per_byte in self._lb_bw_levels:
            if level == dc:
                cyc = dc_boundary * cyc_per_byte  # exact fill bytes there
                if cyc > cycles:
                    cycles = cyc
                continue
            kl = 2 * level
            b = 0
            for wb, axes, rel in self._ds_axes_idx:
                unique = 1
                for r in range(level + 1):
                    row = trips_rows[r]
                    for j in rel:
                        unique *= row[j]
                foot = 1
                for ax in axes:
                    span = 1
                    for coeff, j in ax:
                        span += coeff * (max(1, chain_list[j][kl]) - 1)
                    foot *= span
                b += unique * foot * wb
            cyc = b * cyc_per_byte
            if cyc > cycles:
                cycles = cyc
        return cycles, energy

    # Mapping-object conveniences (tests / non-engine callers)
    def cheap_compute_cycles(self, mapping: Mapping) -> float:
        return self.signature_compute_cycles(mapping_signature(mapping, self.dims))

    def min_boundary_bytes(self, mapping: Mapping, level: int) -> float:
        return self.signature_min_boundary_bytes(
            mapping_signature(mapping, self.dims), level
        )


def _tree_slice(out, B: int):
    """Slice the leading (batch) axis of every array in the core's output
    tuple to ``B`` entries (drops JAX padding)."""
    compute_cycles, total_trips, par, inst_at, tt, st, fans, rows = out
    return (
        compute_cycles[:B],
        total_trips[:B],
        par[:B],
        inst_at[:B],
        tt[:B],
        st[:B],
        fans[:B],
        tuple(tuple(a[:B] for a in r) for r in rows),
    )


# ---------------------------------------------------------------------- #
# Shape-generic array programs. These are the per-context closures
# (``_make_lb_core`` / ``_make_batch_core`` / the fused admit+score core)
# re-derived from a structural ShapeClassKey plus a traced parameter pack
# ``p`` (see ``AnalysisContext.shape_class_key`` / ``shape_params``): the
# loop/branch/reshape STRUCTURE comes from the key, every VALUE from
# ``p``. Because the float operations run in the identical order with
# identical values, the per-row results are bit-identical to the
# per-context closures -- but one compiled program now serves every
# context in the shape class.
# ---------------------------------------------------------------------- #
def _axes_coeff_layout(axes_struct):
    """Per ds/axis/term: ``(flat coeff index, dim index)`` -- the build
    order of ``shape_params()['coeffs']``, so generic span math consumes
    coefficients exactly where the closures baked them in."""
    layout = []
    fi = 0
    for axes in axes_struct:
        ds_list = []
        for ax in axes:
            ax_list = []
            for j in ax:
                ax_list.append((fi, j))
                fi += 1
            ds_list.append(ax_list)
        layout.append(ds_list)
    return layout


def _generic_ds_foot(coeff_layout, k, ttf_lvl, xp, p):
    """Generic :func:`batch_projection_footprint`: identical span math
    over ``[..., D]`` tiles with traced coefficients."""
    shape = ttf_lvl.shape[:-1]
    foot = xp.ones(shape, dtype=xp.float64)
    for ax in coeff_layout[k]:
        span = xp.ones(shape, dtype=xp.float64)
        for ci, j in ax:
            span = span + p["coeffs"][ci] * (ttf_lvl[..., j] - 1.0)
        foot = foot * span
    return foot


def _make_generic_lb_core(skey, xp, lax=None):
    """Shape-generic ``_make_lb_core``: ``core(tt, st, perm, p) ->
    (cycles[B], energy_pj[B], guard_max)``."""
    n, D, K, _real_levels, _real_parent, ds_out, axes_struct, dc, bw_lvls = skey
    if dc < 0:
        dc = None
    coeff_layout = _axes_coeff_layout(axes_struct)
    pos_seq = np.arange(n * D)

    def core(tt, st, perm, p):
        B = tt.shape[0]
        rel_stack = p["rel"]
        wb = p["wb"]
        tt = xp.maximum(tt, 1)
        st = xp.maximum(st, 1)
        sizes_row = xp.reshape(p["sizes"], (1, 1, D))
        outer = xp.concatenate(
            [xp.broadcast_to(sizes_row, (B, 1, D)), st[:, :-1, :]], axis=1
        )
        trips = xp.maximum(outer // tt, 1)
        tripsf = trips.astype(xp.float64)
        total_trips = xp.prod(tripsf.reshape(B, n * D), axis=1)
        leaf_macs = xp.prod(tt[:, -1, :].astype(xp.float64), axis=1)
        cycles = total_trips * xp.ceil(leaf_macs / exact_divisor(xp, p["mpc"]))
        e_pairs = []
        mx = xp.maximum(xp.maximum(total_trips, leaf_macs), cycles)

        dc_boundary = None
        if dc is not None:
            S = (dc + 1) * D
            perm_pref = perm[:, : dc + 1, :]
            tseqf = (
                xp.take_along_axis(trips[:, : dc + 1, :], perm_pref, axis=2)
                .reshape(B, S)
                .astype(xp.float64)
            )
            rel_seq = rel_stack[:, perm_pref.reshape(B, S)]  # [K, B, S]
            present = (tseqf > 1.0)[None, :, :]
            relm = rel_seq & present
            irrm = (~rel_seq) & present
            tseq_b = xp.broadcast_to(tseqf[None, :, :], (K, B, S))
            unique = xp.prod(xp.where(relm, tseq_b, 1.0), axis=2)  # [K, B]
            irrprod = xp.cumprod(xp.where(irrm, tseq_b, 1.0), axis=2)
            idx = xp.where(relm, pos_seq[None, None, :S], -1)
            lastrel = xp.max(idx, axis=2)
            gathered = xp.take_along_axis(
                irrprod, xp.maximum(lastrel, 0)[:, :, None], axis=2
            )[:, :, 0]
            changes = unique * xp.where(lastrel >= 0, gathered, 1.0)
            ttf_dc = tt[:, dc, :].astype(xp.float64)
            if dc > 0:
                fans_pref = xp.maximum(tt[:, :dc, :] // st[:, :dc, :], 1).astype(
                    xp.float64
                )
            dc_boundary = xp.zeros(B, dtype=xp.float64)
            for k in range(K):
                foot = _generic_ds_foot(coeff_layout, k, ttf_dc, xp, p)
                if dc > 0:
                    rel_sp = xp.prod(
                        xp.where(
                            rel_stack[k][None, None, :], fans_pref, 1.0
                        ).reshape(B, dc * D),
                        axis=1,
                    )
                else:
                    rel_sp = xp.ones(B, dtype=xp.float64)
                cf = changes[k] * foot
                mx = xp.maximum(mx, changes[k])
                t1 = cf * rel_sp * wb[k]
                mx = xp.maximum(mx, t1)
                if ds_out[k]:
                    rmw = xp.maximum(changes[k] - unique[k], 0.0) * foot
                    t2 = rmw * rel_sp * wb[k]
                    mx = xp.maximum(mx, t2)
                    e_pairs.append((t1 * p["twe"], t2 * p["tre"]))
                    dc_boundary = dc_boundary + (cf + rmw) * wb[k]
                else:
                    e_pairs.append((t1 * p["tre"], 0.0))
                    dc_boundary = dc_boundary + cf * wb[k]
            mx = xp.maximum(mx, dc_boundary)
        energy = ordered_pair_sum(
            xp, xp.full((B,), p["e_base"], dtype=xp.float64), e_pairs
        )

        for bw_pos, level in enumerate(bw_lvls):
            cyc_per_byte = p["bw_cpb"][bw_pos]
            if level == dc:
                cycles = xp.maximum(cycles, dc_boundary * cyc_per_byte)
                continue
            ttf_lvl = tt[:, level, :].astype(xp.float64)
            relprod_lvl = xp.prod(
                xp.where(
                    rel_stack[:, None, None, :],
                    tripsf[None, :, : level + 1, :],
                    1.0,
                ).reshape(K, B, (level + 1) * D),
                axis=2,
            )
            b = xp.zeros(B, dtype=xp.float64)
            for k in range(K):
                term = (
                    relprod_lvl[k]
                    * _generic_ds_foot(coeff_layout, k, ttf_lvl, xp, p)
                    * wb[k]
                )
                mx = xp.maximum(mx, term)
                b = b + term
            mx = xp.maximum(mx, b)
            cycles = xp.maximum(cycles, b * cyc_per_byte)
        return cycles, energy, xp.max(mx)

    return core


def _make_generic_batch_core(skey, xp, lax=None):
    """Shape-generic ``_make_batch_core``: ``core(tt, st, perm, p) ->``
    the stacked-traffic 8-tuple."""
    n, D, K, real_levels, real_parent, ds_out, axes_struct, _dc, _bw = skey
    real_levels = list(real_levels)
    L = len(real_levels)
    coeff_layout = _axes_coeff_layout(axes_struct)
    ends = np.asarray([(i + 1) * D - 1 for i in real_levels])
    real_arr = np.asarray(real_levels)
    parent_arr = np.asarray(
        [real_parent[i] if real_parent[i] >= 0 else i for i in real_levels]
    )
    pos_seq = np.arange(n * D)

    def core(tt, st, perm, p):
        B = tt.shape[0]
        rel_stack = p["rel"]
        tt = xp.maximum(tt, 1)
        st = xp.maximum(st, 1)
        sizes_row = xp.reshape(p["sizes"], (1, 1, D))
        outer = xp.concatenate(
            [xp.broadcast_to(sizes_row, (B, 1, D)), st[:, :-1, :]], axis=1
        )
        trips = xp.maximum(outer // tt, 1)
        fans = xp.maximum(tt // st, 1)
        tripsf = trips.astype(xp.float64)
        fansf = fans.astype(xp.float64)
        total_trips = xp.prod(tripsf.reshape(B, n * D), axis=1)
        leaf_macs = xp.prod(tt[:, -1, :].astype(xp.float64), axis=1)
        compute_cycles = total_trips * xp.ceil(
            leaf_macs / exact_divisor(xp, p["mpc"])
        )
        par = xp.prod(fansf.reshape(B, n * D), axis=1)
        lvl_all = xp.prod(fansf, axis=2)  # [B, n]
        cp_all = xp.cumprod(lvl_all, axis=1)
        inst_at = xp.concatenate(
            [xp.ones((B, 1), dtype=xp.float64), cp_all[:, :-1]], axis=1
        )
        perm_flat = perm.reshape(B, n * D)
        tseqf = xp.take_along_axis(trips, perm, axis=2).reshape(B, n * D).astype(
            xp.float64
        )
        rel_seq = rel_stack[:, perm_flat]  # [K, B, S]
        present = (tseqf > 1.0)[None, :, :]
        relm = rel_seq & present
        irrm = (~rel_seq) & present
        tseq_b = xp.broadcast_to(tseqf[None, :, :], (K, B, n * D))
        relprod = xp.cumprod(xp.where(relm, tseq_b, 1.0), axis=2)
        irrprod = xp.cumprod(xp.where(irrm, tseq_b, 1.0), axis=2)
        idx = xp.where(relm, pos_seq[None, None, :], -1)
        if lax is None:
            lastrel = np.maximum.accumulate(idx, axis=2)
        else:
            lastrel = lax.cummax(idx, axis=2)
        gathered = xp.take_along_axis(irrprod, xp.maximum(lastrel, 0), axis=2)
        ip = xp.where(lastrel >= 0, gathered, 1.0)
        unique = relprod[:, :, ends]  # [K, B, L]
        changes = unique * ip[:, :, ends]
        lvl_rel = xp.prod(
            xp.where(rel_stack[:, None, None, :], fansf[None], 1.0),
            axis=3,
        )  # [K, B, n]
        cp_rel = xp.cumprod(lvl_rel, axis=2)
        srel_excl = xp.concatenate(
            [xp.ones((K, B, 1), dtype=xp.float64), cp_rel[:, :, :-1]], axis=2
        )
        rel_sp = srel_excl[:, :, real_arr] / srel_excl[:, :, parent_arr]
        ttf_real = tt[:, real_arr, :].astype(xp.float64)  # [B, L, D]
        rows = []
        for k in range(K):
            foot = _generic_ds_foot(coeff_layout, k, ttf_real, xp, p)
            cf = changes[k] * foot
            if ds_out[k]:
                rmw = xp.maximum(changes[k] - unique[k], 0.0) * foot
                rows.append((rmw, cf, rmw * rel_sp[k], cf * rel_sp[k], foot))
            else:
                z = xp.zeros_like(cf)
                rows.append((cf, z, cf * rel_sp[k], z, foot))
        return compute_cycles, total_trips, par, inst_at, tt, st, fans, tuple(rows)

    return core


def generic_hierarchical_energy(real_levels, real_parent, K, bt, xp, p, hop=False):
    """Shape-generic :func:`batch_hierarchical_energy`: the identical
    level-walk float-operation sequence with energies / word widths /
    precomputed innermost+MAC terms read from the parameter pack
    (``lvl_read_e`` / ``lvl_write_e`` / ``wb`` / ``l1_terms`` /
    ``mac_term`` / ``hop``). ``real_parent`` uses -1 for parentless.
    Returns ``(energy[B], noc_energy[B] or None, mx)``."""
    inst_at = bt.inst_at
    mx = xp.zeros(())
    e_terms = []
    noc_terms = [] if hop else None
    for k in range(K):
        wbk = p["wb"][k]
        r = bt.rows[k]
        for pos, i in enumerate(real_levels):
            t = r.fills[:, pos] * inst_at[:, i] * wbk
            mx = xp.maximum(mx, xp.max(t))
            e_terms.append(t * p["lvl_write_e"][i])
            t = r.drains[:, pos] * inst_at[:, i] * wbk
            mx = xp.maximum(mx, xp.max(t))
            e_terms.append(t * p["lvl_read_e"][i])
            parent_idx = real_parent[i]
            if parent_idx >= 0:
                n_parent = inst_at[:, parent_idx]
                t = r.parent_reads[:, pos] * n_parent * wbk
                mx = xp.maximum(mx, xp.max(t))
                e_terms.append(t * p["lvl_read_e"][parent_idx])
                t = r.parent_writes[:, pos] * n_parent * wbk
                mx = xp.maximum(mx, xp.max(t))
                e_terms.append(t * p["lvl_write_e"][parent_idx])
                if noc_terms is not None:
                    t = (r.fills[:, pos] + r.drains[:, pos]) * inst_at[:, i] * wbk
                    mx = xp.maximum(mx, xp.max(t))
                    noc_terms.append(t * p["hop"])
        e_terms.append(p["l1_terms"][k])
    e_terms.append(p["mac_term"])
    energy = ordered_sum(xp, xp.zeros_like(bt.compute_cycles), e_terms)
    noc_energy = (
        ordered_sum(xp, xp.zeros_like(energy), noc_terms)
        if noc_terms is not None
        else None
    )
    return energy, noc_energy, mx


def _generic_scalarize(metric: str, xp):
    """Shape-generic ``_metric_scalarize``: frequency comes from the
    parameter pack (same exact-divisor barrier, so decisions stay
    bit-identical to the host filter)."""
    if metric == "latency":
        return lambda cyc, en, p: cyc
    if metric == "energy":
        return lambda cyc, en, p: en
    if metric == "edp":
        return lambda cyc, en, p: (en * 1e-12) * (
            cyc / exact_divisor(xp, p["freq"])
        )
    return lambda cyc, en, p: cyc * 0.0


def _make_generic_fused_core(skey, terms, metric: str, xp, lax):
    """Shape-generic fused admit+score core: ``core(tt, st, perm,
    incumbent, p) -> (admit, lb_guard, latency, energy, util,
    score_guard, extras)``.

    The calibration scale enters as the traced ``p['calib_scale']``
    parameter (1.0 when uncalibrated -- ``x * 1.0`` is bit-exact, so the
    uncalibrated program matches the unscaled per-context path and ONE
    compiled program serves every calibration value). Extras additionally
    carry the raw admission-bound arrays (``lb_cycles`` / ``lb_energy``,
    already calibrated) and the scalarized ``metric_score`` so
    device-resident loops can replay admission and selection host-side
    without a second dispatch.
    """
    lb_core = _make_generic_lb_core(skey, xp, lax)
    traffic_core = _make_generic_batch_core(skey, xp, lax)
    scalarize = _generic_scalarize(metric, xp)

    def core(tt, st, perm, incumbent, p):
        lb_cyc, lb_en, lb_mx = lb_core(tt, st, perm, p)
        lb_cyc = lb_cyc * p["calib_scale"]
        admit = scalarize(lb_cyc, lb_en, p) < incumbent
        out = traffic_core(tt, st, perm, p)
        bt = BatchTraffic(
            compute_cycles=out[0],
            total_trips=out[1],
            par=out[2],
            inst_at=out[3],
            tt=out[4],
            st=out[5],
            fans=out[6],
            rows=tuple(DsTrafficBatch(*r) for r in out[7]),
        )
        latency, energy, util, score_mx, extras = terms(bt, xp, p)
        latency = latency * p["calib_scale"]
        extras = dict(extras)
        extras["lb_cycles"] = lb_cyc
        extras["lb_energy"] = lb_en
        extras["metric_score"] = scalarize(latency, energy, p)
        return admit, lb_mx, latency, energy, util, score_mx, extras

    return core


class GenericFusedRunner:
    """Dispatch handle for one (context, model, metric) over a SHARED
    shape-generic compiled program: the program lives in the process-wide
    ``_GENERIC_PROGRAMS`` cache keyed by (shape class, model structure,
    metric); this object carries the context's parameter pack (uploaded
    to device once, lazily) and implements the same ``(sb, incumbent) ->
    7-tuple or None`` protocol as ``build_fused_runner``'s closures, plus
    the device-resident extensions the search loops use
    (:meth:`dispatch_device`, :meth:`is_traced`)."""

    supports_precompute = True

    def __init__(self, ctx, core, params, pkey, donates: bool) -> None:
        self._ctx = ctx
        self._core = core
        self._params = params
        self._pkey = pkey
        self._donates = donates
        self._dev_params = None
        self._dev_inf = None  # cached device scalar for incumbent=inf

    @property
    def program_key(self):
        return self._pkey

    def is_traced(self, padded_batch: int) -> bool:
        """Whether the shared program has already been traced at this
        pow2 bucket (by ANY context in the shape class) -- lets warmup
        skip re-dispatching buckets the class already covers."""
        return (self._pkey, int(padded_batch)) in _TRACE_COMBOS

    def dispatch_device(self, sb: StackedBatch):
        """One fused dispatch, results left ON DEVICE: returns the raw
        (possibly padded -- callers slice to the batch size) output
        tuple, or None on failure. Device-resident loops use this to
        fetch only small scalars per generation and defer full
        materialization to the K-generation sync."""
        ctx = self._ctx
        if ctx._jax_failed:
            return None
        try:
            jax = ctx._ensure_jax()
            import jax.numpy as jnp
            from jax.experimental import enable_x64

            with enable_x64():
                tt, st, perm, B = ctx._jax_device_padded(sb)
                if self._dev_params is None:
                    self._dev_params = jax.device_put(self._params)
                if self._dev_inf is None:
                    self._dev_inf = jnp.asarray(math.inf, dtype=jnp.float64)
                inc = self._dev_inf
                ctx.jax_dispatches += 1
                _record_trace(self._pkey, int(tt.shape[0]))
                out = self._core(tt, st, perm, inc, self._dev_params)
            if self._donates:
                sb.devp = None  # donated away; fallbacks re-upload
            return out
        except Exception:
            ctx._jax_failed = True
            return None

    def __call__(self, sb: StackedBatch, incumbent: float):
        ctx = self._ctx
        if ctx._jax_failed:
            return None
        try:
            jax = ctx._ensure_jax()
            import jax.numpy as jnp
            from jax.experimental import enable_x64

            with enable_x64():
                tt, st, perm, B = ctx._jax_device_padded(sb)
                if self._dev_params is None:
                    self._dev_params = jax.device_put(self._params)
                inc = jnp.asarray(float(incumbent), dtype=jnp.float64)
                ctx.jax_dispatches += 1
                _record_trace(self._pkey, int(tt.shape[0]))
                out = self._core(tt, st, perm, inc, self._dev_params)
            if self._donates:
                sb.devp = None  # donated away; fallbacks re-upload
            admit, lb_mx, latency, energy, util, score_mx, extras = out
            latency = np.asarray(latency)
            if latency.dtype != np.float64:
                # x64 unavailable: cannot honour bit-identity
                ctx._jax_failed = True
                return None
            return (
                np.asarray(admit)[:B],
                float(np.asarray(lb_mx)),
                latency[:B],
                np.asarray(energy)[:B],
                np.asarray(util)[:B],
                float(np.asarray(score_mx)),
                {k: np.asarray(v)[:B] for k, v in extras.items()},
            )
        except Exception:
            ctx._jax_failed = True
            return None


# ---------------------------------------------------------------------- #
# Two-tier context cache. The fast tier is identity-keyed: entries pin
# strong references to the exact (problem, arch) objects they were looked
# up with, so an id() key can never alias a dead object while resident.
# Identity misses fall back to a CONTENT digest (problems and archs with
# equal cost-relevant content produce bit-identical analyses), so the many
# content-equal instances a figure sweep builds -- dnn_layers() re-invoked
# per benchmark, repeated accelerator constructors -- all alias ONE
# context, sharing its numpy cores, jitted programs, fused runners and
# footprint memos instead of re-tracing per instance. Digests are memoized
# on the objects themselves (falling back to recomputation for immutable
# types).
# ---------------------------------------------------------------------- #
_CTX_CACHE: "OrderedDict[Tuple[int, int], Tuple[Problem, Architecture, AnalysisContext]]" = (
    OrderedDict()
)
_CTX_BY_CONTENT: "OrderedDict[Tuple[str, str], AnalysisContext]" = OrderedDict()
_CTX_CACHE_SIZE = 64


def _content_digest(obj, canon) -> str:
    d = getattr(obj, "_ctx_digest", None)
    if d is None:
        import hashlib
        import json

        d = hashlib.sha256(
            json.dumps(canon(obj), sort_keys=True, default=repr).encode()
        ).hexdigest()
        try:
            obj._ctx_digest = d
        except Exception:
            pass  # immutable/slots type: recompute next time
    return d


def get_context(problem: Problem, arch: Architecture) -> AnalysisContext:
    key = (id(problem), id(arch))
    entry = _CTX_CACHE.get(key)
    if entry is not None and entry[0] is problem and entry[1] is arch:
        _CTX_CACHE.move_to_end(key)
        return entry[2]
    from repro.core.cost.store import _canon_arch, _canon_problem

    ckey = (
        _content_digest(problem, _canon_problem),
        _content_digest(arch, _canon_arch),
    )
    ctx = _CTX_BY_CONTENT.get(ckey)
    if ctx is None:
        ctx = AnalysisContext(problem, arch)
        _CTX_BY_CONTENT[ckey] = ctx
        while len(_CTX_BY_CONTENT) > _CTX_CACHE_SIZE:
            _CTX_BY_CONTENT.popitem(last=False)
    else:
        _CTX_BY_CONTENT.move_to_end(ckey)
    _CTX_CACHE[key] = (problem, arch, ctx)
    while len(_CTX_CACHE) > _CTX_CACHE_SIZE:
        _CTX_CACHE.popitem(last=False)
    return ctx


def analyze(problem: Problem, mapping: Mapping, arch: Architecture) -> AccessProfile:
    return get_context(problem, arch).analyze(mapping)


def hierarchical_lower_bound(
    problem: Problem, mapping: Optional[Mapping], arch: Architecture, sig=None
) -> Tuple[float, float]:
    """(cycles, energy_pj) lower bounds for the hierarchical models.

    Valid for both the Timeloop-like and MAESTRO-like models:

      * cycles: both take max(compute, per-level fill time) or add
        non-negative terms on top, and per-level fill bytes are bounded
        below by ``unique x tile footprint`` per data space;
      * energy: both include the innermost operand movement and MAC energy
        exactly, plus non-negative buffer/NoC terms.

    ``sig`` short-circuits signature extraction when the caller (the
    evaluation engine) already computed it for the cache probe.
    """
    ctx = get_context(problem, arch)
    if sig is None:
        sig = mapping_signature(mapping, ctx.dims)
    return ctx.signature_lower_bound(sig)


def batch_hierarchical_energy(
    ctx: AnalysisContext,
    arch: Architecture,
    problem: Problem,
    bt: BatchTraffic,
    hop_pj_byte: Optional[float] = None,
    xp=np,
):
    """Shared level-walk energy accumulation for the hierarchical models'
    ``evaluate_signature_batch`` (timeloop_like and maestro_like run the
    identical sequence of float operations here; maestro additionally
    accumulates the NoC delivery term, enabled via ``hop_pj_byte``).

    ``xp`` selects the array stack: numpy for host-side scoring, jax.numpy
    when the walk runs inside the fused single-dispatch jitted core (the
    per-element float-operation order is identical either way).

    Returns ``(energy[B], noc_energy[B] or None, mac_term, mx)`` where
    ``energy`` already includes the innermost-operand and MAC terms (the
    scalar paths add them in exactly this order) and ``mx`` is an xp
    scalar holding the max of every guarded integer-valued product (the
    caller folds it into its BATCH_EXACT_LIMIT check host-side). NoC
    energy is NOT folded into ``energy`` -- maestro adds it after the MAC
    term, as its scalar path does.
    """
    clusters = arch.clusters
    real_levels = ctx.real_levels
    real_parent = ctx.real_parent
    leaf = clusters[-1]
    inst_at = bt.inst_at
    mx = xp.zeros(())
    # The access-count products (t) are integer-valued and exact, but the
    # per-byte energies are fractional: each ``t * energy`` product must be
    # ROUNDED before it joins the accumulator, exactly as numpy does.
    # Addends are collected and summed through :func:`ordered_sum`, whose
    # scan structure stops XLA's LLVM backend from contracting
    # ``acc + t * e`` into an FMA (one rounding instead of two) on the
    # fused jitted path.
    e_terms = []
    noc_terms = [] if hop_pj_byte is not None else None
    for k, ds in enumerate(problem.data_spaces):
        wb = ds.word_bytes
        r = bt.rows[k]
        for pos, i in enumerate(real_levels):
            cl = clusters[i]
            t = r.fills[:, pos] * inst_at[:, i] * wb
            mx = xp.maximum(mx, xp.max(t))
            e_terms.append(t * cl.write_energy)
            t = r.drains[:, pos] * inst_at[:, i] * wb
            mx = xp.maximum(mx, xp.max(t))
            e_terms.append(t * cl.read_energy)
            parent_idx = real_parent[i]
            if parent_idx is not None:
                parent = clusters[parent_idx]
                n_parent = inst_at[:, parent_idx]
                t = r.parent_reads[:, pos] * n_parent * wb
                mx = xp.maximum(mx, xp.max(t))
                e_terms.append(t * parent.read_energy)
                t = r.parent_writes[:, pos] * n_parent * wb
                mx = xp.maximum(mx, xp.max(t))
                e_terms.append(t * parent.write_energy)
                if noc_terms is not None:
                    # every DELIVERED copy pays a NoC hop (multicast reads
                    # the parent once; see maestro_like)
                    t = (r.fills[:, pos] + r.drains[:, pos]) * inst_at[:, i] * wb
                    mx = xp.maximum(mx, xp.max(t))
                    noc_terms.append(t * hop_pj_byte)
        e_terms.append(ctx.l1_reads[ds.name] * wb * leaf.read_energy)
    mac_term = problem.macs * leaf.mac_energy
    e_terms.append(mac_term)
    energy = ordered_sum(xp, xp.zeros_like(bt.compute_cycles), e_terms)
    noc_energy = (
        ordered_sum(xp, xp.zeros_like(energy), noc_terms)
        if noc_terms is not None
        else None
    )
    return energy, noc_energy, mac_term, mx


def boundary_bytes_per_instance(
    prof: AccessProfile, problem: Problem, level: int
) -> float:
    """Total fill+drain bytes crossing INTO one instance of `level`."""
    total = 0.0
    for ds in problem.data_spaces:
        lt = prof.traffic.get((ds.name, level))
        if lt is None:
            continue
        total += (lt.fills_per_instance + lt.drains_per_instance) * ds.word_bytes
    return total
