"""Shared reuse/traffic analysis over an expanded mapping loop nest.

This module turns (Problem, Mapping, Architecture) into per-buffer-level
access counts per data space, using the classic analytical-cost-model
reuse rules (Timeloop/Interstellar style):

  * A buffer at cluster level i holds one temporal tile TT^i per data space.
  * The tile held changes whenever a RELEVANT temporal loop above the
    residency advances (relevant = the loop's dim projects into the data
    space), or when an IRRELEVANT temporal loop that encloses a deeper
    relevant temporal loop advances (re-walk => refetch).
  * Relevant spatial distribution partitions data across instances;
    irrelevant spatial distribution multicasts the same tile (distinct
    parent reads are counted once under ideal multicast; per-instance
    fills are always counted).
  * Output data spaces additionally pay read-modify-write traffic when
    reduction loops enclose their residency.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.architecture import Architecture
from repro.core.mapping import Mapping
from repro.core.problem import DataSpace, Problem


@dataclass(frozen=True)
class Loop:
    level: int  # mapping/cluster level index (0 = outermost)
    kind: str  # "temporal" | "spatial"
    dim: str
    trips: int


@dataclass
class LevelTraffic:
    """Per-buffer-level traffic for ONE data space (elements, not bytes)."""

    fills_per_instance: int = 0  # elements read into one instance from parent
    drains_per_instance: int = 0  # output elements written back to parent
    parent_reads: int = 0  # distinct element-reads served by ONE parent instance
    parent_writes: int = 0  # distinct element-writes absorbed by ONE parent instance
    instances: int = 1  # number of instances of this level in the machine
    tile_elems: int = 0  # resident tile footprint (elements)


@dataclass
class AccessProfile:
    """Full result of the analysis."""

    loops: List[Loop]
    # traffic[(ds_name, level_idx)] -> LevelTraffic; only non-virtual levels
    traffic: Dict[Tuple[str, int], LevelTraffic] = field(default_factory=dict)
    compute_cycles: float = 0.0
    leaf_tile_macs: int = 0
    total_temporal_trips: int = 1
    parallelism: int = 1
    utilization: float = 0.0
    l1_reads: Dict[str, int] = field(default_factory=dict)  # innermost accesses per ds


def expand_loops(problem: Problem, mapping: Mapping) -> List[Loop]:
    loops: List[Loop] = []
    for i, lm in enumerate(mapping.levels):
        trips = mapping.temporal_trips(i, problem)
        order = list(lm.temporal_order) + [d for d in problem.dims if d not in lm.temporal_order]
        for d in order:
            if trips[d] > 1:
                loops.append(Loop(i, "temporal", d, trips[d]))
        fan = mapping.spatial_fanout(i, problem)
        for d in problem.dims:
            if fan[d] > 1:
                loops.append(Loop(i, "spatial", d, fan[d]))
    return loops


def _real_parent(arch: Architecture, i: int) -> Optional[int]:
    """Nearest non-virtual cluster level above i (list index)."""
    for j in range(i - 1, -1, -1):
        if not arch.clusters[j].virtual:
            return j
    return None


def analyze(problem: Problem, mapping: Mapping, arch: Architecture) -> AccessProfile:
    loops = expand_loops(problem, mapping)
    prof = AccessProfile(loops=loops)

    n_levels = arch.n_levels
    # compute totals
    total_trips = 1
    for lp in loops:
        if lp.kind == "temporal":
            total_trips *= lp.trips
    par = mapping.total_parallelism(problem)
    leaf = arch.clusters[-1]
    leaf_tile = {d: mapping.levels[-1].tt(d) for d in problem.dims}
    leaf_macs = math.prod(leaf_tile.values())
    prof.leaf_tile_macs = leaf_macs
    prof.total_temporal_trips = total_trips
    prof.parallelism = par
    prof.utilization = par / max(1, arch.num_pes)
    prof.compute_cycles = total_trips * math.ceil(leaf_macs / max(1, leaf.macs_per_cycle))

    reduction = set(problem.reduction_dims())

    for ds in problem.data_spaces:
        rel = set(ds.dims)
        for i in range(n_levels):
            if arch.clusters[i].virtual:
                continue
            # loops above the residency at level i: all loops of levels < i,
            # plus temporal loops of level i itself.
            above = [
                lp for lp in loops
                if lp.level < i or (lp.level == i and lp.kind == "temporal")
            ]
            # tile changes: relevant temporal loops, or irrelevant temporal
            # loops enclosing a deeper relevant temporal loop.
            changes = 1
            unique = 1
            for p, lp in enumerate(above):
                if lp.kind != "temporal":
                    continue
                if lp.dim in rel:
                    changes *= lp.trips
                    unique *= lp.trips
                else:
                    deeper_relevant = any(
                        q.kind == "temporal" and q.dim in rel for q in above[p + 1 :]
                    )
                    if deeper_relevant:
                        changes *= lp.trips
            tile = {d: mapping.levels[i].tt(d) for d in problem.dims}
            foot = ds.footprint(tile)
            # spatial multipliers between the real parent and this level
            pr = _real_parent(arch, i)
            rel_spatial = 1
            all_spatial_above = 1
            inst = 1
            for lp in loops:
                if lp.kind != "spatial":
                    continue
                if lp.level < i:
                    inst *= lp.trips
                if pr is not None and pr <= lp.level < i:
                    all_spatial_above *= lp.trips
                    if lp.dim in rel:
                        rel_spatial *= lp.trips

            lt = LevelTraffic(instances=inst, tile_elems=foot)
            if not ds.is_output:
                lt.fills_per_instance = changes * foot
                # one parent instance serves (instances between parent and i);
                # ideal multicast: only RELEVANT spatial splits are distinct.
                lt.parent_reads = changes * foot * rel_spatial
            else:
                lt.drains_per_instance = changes * foot
                lt.fills_per_instance = max(0, changes - unique) * foot  # RMW refills
                lt.parent_writes = changes * foot * rel_spatial
                lt.parent_reads = max(0, changes - unique) * foot * rel_spatial
            prof.traffic[(ds.name, i)] = lt

        # innermost (register/MAC) accesses: one operand access per MAC
        total_macs = problem.macs
        prof.l1_reads[ds.name] = 2 * total_macs if ds.is_output else total_macs
    return prof


def boundary_bytes_per_instance(
    prof: AccessProfile, problem: Problem, level: int
) -> float:
    """Total fill+drain bytes crossing INTO one instance of `level`."""
    total = 0.0
    for ds in problem.data_spaces:
        lt = prof.traffic.get((ds.name, level))
        if lt is None:
            continue
        total += (lt.fills_per_instance + lt.drains_per_instance) * ds.word_bytes
    return total
