"""Cost model interface + result record."""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.architecture import Architecture
from repro.core.mapping import Mapping
from repro.core.problem import Problem


@dataclass
class Cost:
    """Result of evaluating one mapping on one architecture."""

    latency_cycles: float
    energy_pj: float
    utilization: float
    macs: int
    frequency_hz: float
    breakdown: Dict[str, float] = field(default_factory=dict)

    @property
    def latency_s(self) -> float:
        return self.latency_cycles / self.frequency_hz

    @property
    def energy_j(self) -> float:
        return self.energy_pj * 1e-12

    @property
    def edp(self) -> float:
        """Energy-Delay Product in J*s (paper's headline metric)."""
        return self.energy_j * self.latency_s

    def metric(self, name: str) -> float:
        if name == "latency":
            return self.latency_cycles
        if name == "energy":
            return self.energy_pj
        if name == "edp":
            return self.edp
        raise ValueError(f"unknown metric {name!r}")

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"Cost(cycles={self.latency_cycles:.3g}, E={self.energy_pj:.3g}pJ, "
            f"EDP={self.edp:.3g}Js, util={self.utilization:.2%})"
        )


class CostModel(abc.ABC):
    """Every cost model: conformability check + evaluate."""

    name: str = "base"

    @abc.abstractmethod
    def evaluate(self, problem: Problem, mapping: Mapping, arch: Architecture) -> Cost:
        ...

    def conformable(self, problem: Problem) -> bool:
        """Whether this model can evaluate the problem at all.

        Overridden per model; see also repro.core.ir.conformability which
        runs these checks as compiler passes.
        """
        return True

    def evaluate_metric(
        self, problem: Problem, mapping: Mapping, arch: Architecture, metric: str = "edp"
    ) -> float:
        return self.evaluate(problem, mapping, arch).metric(metric)
