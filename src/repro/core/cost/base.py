"""Cost model interface + result record."""

from __future__ import annotations

import abc
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.architecture import Architecture
from repro.core.mapping import Mapping
from repro.core.problem import Problem


@dataclass
class Cost:
    """Result of evaluating one mapping on one architecture."""

    latency_cycles: float
    energy_pj: float
    utilization: float
    macs: int
    frequency_hz: float
    breakdown: Dict[str, float] = field(default_factory=dict)

    @property
    def latency_s(self) -> float:
        return self.latency_cycles / self.frequency_hz

    @property
    def energy_j(self) -> float:
        return self.energy_pj * 1e-12

    @property
    def edp(self) -> float:
        """Energy-Delay Product in J*s (paper's headline metric)."""
        return self.energy_j * self.latency_s

    def metric(self, name: str) -> float:
        if name == "latency":
            return self.latency_cycles
        if name == "energy":
            return self.energy_pj
        if name == "edp":
            return self.edp
        raise ValueError(f"unknown metric {name!r}")

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"Cost(cycles={self.latency_cycles:.3g}, E={self.energy_pj:.3g}pJ, "
            f"EDP={self.edp:.3g}Js, util={self.utilization:.2%})"
        )


class CostModel(abc.ABC):
    """Every cost model: conformability check + evaluate (+ lower bound).

    **Calibration hook.** A model may carry an optional calibration (a
    measured-vs-modeled latency scale produced by
    ``repro.codesign.calibrate``; any object with a positive-float
    ``scale`` and a ``key_parts()`` tuple works). A calibrated model
    multiplies every latency prediction by that scale as the FINAL
    operation of EVERY path -- scalar (``evaluate``,
    ``evaluate_signature``, the ``lower_bound*`` family) and vectorized
    (``lower_bound_batch_fn``, ``batch_admit_core_builder``,
    ``batch_cost_terms_fn``, ``evaluate_signature_batch``,
    ``batch_cost_terms_generic``) alike. A uniform positive final
    multiply keeps the admission invariant (bound <= evaluate, since
    IEEE multiply by the same positive factor is monotone) and never
    changes which mapping is argmin; and because the batch paths apply
    the IDENTICAL final ``latency * scale`` per element, the calibrated
    batch results stay bit-identical to the calibrated scalar path
    (same two float64 operands, same single rounding). The shape-generic
    path traces the scale as a parameter (1.0 when uncalibrated --
    ``x * 1.0`` is IEEE-exact), so one compiled program serves every
    calibration value. ``store_key_parts()`` includes
    ``calibration_key_parts()``, so calibrated and raw results never
    alias in a ResultStore.
    """

    name: str = "base"
    #: optional calibration scale (None = raw model, byte-identical to
    #: the pre-calibration behavior); set via :meth:`set_calibration`
    calibration = None

    @abc.abstractmethod
    def evaluate(self, problem: Problem, mapping: Mapping, arch: Architecture) -> Cost:
        ...

    # ------------------------------------------------------------------ #
    # calibration
    # ------------------------------------------------------------------ #
    def set_calibration(self, calibration) -> "CostModel":
        """Attach (or with None, remove) a calibration; returns self for
        chaining: ``TimeloopLikeModel().set_calibration(scale)``."""
        if calibration is not None:
            s = float(calibration.scale)
            if not (s > 0.0 and math.isfinite(s)):
                raise ValueError(
                    f"calibration scale must be finite and positive, got {s!r}"
                )
            calibration.key_parts()  # fail fast on a malformed object
        self.calibration = calibration
        return self

    @property
    def calibration_scale(self) -> float:
        """The latency multiplier in effect (1.0 when uncalibrated)."""
        return float(self.calibration.scale) if self.calibration is not None else 1.0

    def calibration_key_parts(self) -> "tuple":
        """Store-key suffix identifying the active calibration (empty when
        uncalibrated, so raw-model keys are unchanged by this feature)."""
        if self.calibration is None:
            return ()
        return tuple(self.calibration.key_parts())

    def apply_calibration(self, cost: Cost) -> Cost:
        """Rescale a raw Cost's latency by the calibration scale (identity
        when uncalibrated -- the raw object passes through untouched). The
        scale is recorded in the breakdown for provenance."""
        if self.calibration is None:
            return cost
        s = float(self.calibration.scale)
        breakdown = dict(cost.breakdown)
        breakdown["calibration_scale"] = s
        return Cost(
            latency_cycles=cost.latency_cycles * s,
            energy_pj=cost.energy_pj,
            utilization=cost.utilization,
            macs=cost.macs,
            frequency_hz=cost.frequency_hz,
            breakdown=breakdown,
        )

    def _calibrate_bound(self, bound: "tuple[float, float]") -> "tuple[float, float]":
        """Apply the calibration scale to a ``(cycles, energy_pj)`` lower
        bound -- same final multiply as :meth:`apply_calibration`, so the
        admission invariant (bound <= evaluate) survives calibration."""
        if self.calibration is None:
            return bound
        cycles, energy = bound
        return cycles * float(self.calibration.scale), energy

    def lower_bound(
        self,
        problem: Problem,
        mapping: Optional[Mapping],
        arch: Architecture,
        sig=None,
    ) -> "tuple[float, float]":
        """Cheap ``(latency_cycles, energy_pj)`` lower bounds for a mapping.

        Must be computable from the tile chain alone (no reuse analysis)
        and must never exceed the corresponding ``evaluate`` results -- the
        evaluation engine uses it as an incumbent-aware admission filter.
        ``sig`` is the engine's canonical signature when already available
        (implementations may consume it instead of ``mapping``). The
        default declines to bound (never prunes).
        """
        return 0.0, 0.0

    def lower_bound_fn(self, problem: Problem, arch: Architecture):
        """Bound ``lower_bound`` to (problem, arch) once; the evaluation
        engine calls the returned ``sig -> (cycles, energy_pj)`` closure per
        candidate. Models with precomputed per-problem state override this
        to skip the per-call dispatch."""
        return lambda sig: self.lower_bound(problem, None, arch, sig=sig)

    def lower_bound_chains_fn(self, problem: Problem, arch: Architecture):
        """Optional chain-level variant: a ``(chain_list, orders) ->
        (cycles, energy_pj)`` closure matching ``lower_bound_fn`` on the
        equivalent signature, letting the engine bound genome candidates
        without building their signature. None when unsupported."""
        return None

    def lower_bound_batch_fn(self, problem: Problem, arch: Architecture):
        """Optional vectorized admission bound: a closure
        ``(sigs, backend=..., stacked=...) -> Optional[(cycles[B],
        energy_pj[B]))`` producing, for every signature of a stacked batch,
        exactly the values ``lower_bound_fn`` produces per candidate (the
        engine admits a whole miss-batch with one masked array program).
        Implementations MUST return None whenever bit-identity with the
        scalar bound cannot be guaranteed (the engine then falls back to
        the per-candidate bound). None when unsupported."""
        return None

    def evaluate_signature(
        self, problem: Problem, arch: Architecture, sig
    ) -> Optional[Cost]:
        """Fused fast path: produce the same Cost ``evaluate`` would for a
        mapping with canonical signature ``sig``, without materializing the
        Mapping object. Return None when unsupported (the engine falls back
        to ``evaluate``). Implementations MUST be bit-identical to
        ``evaluate``."""
        return None

    def evaluate_signature_batch(
        self,
        problem: Problem,
        arch: Architecture,
        sigs,
        backend: str = "numpy",
        stacked=None,
        select=None,
    ) -> Optional[List[Cost]]:
        """Vectorized fast path: the Costs ``evaluate_signature`` (or
        ``evaluate``) would produce for every signature in ``sigs``,
        computed as one array program over the stacked batch.

        ``backend`` selects the array stack (``"numpy"`` or ``"jax"``).
        ``stacked``/``select`` let the evaluation engine share the
        admission stage's already-stacked (device-resident, on jax)
        ``StackedBatch`` and score only the admitted row indices; ``sigs``
        must then be the corresponding subset, in ``select`` order.
        Return None when unsupported OR when exactness cannot be
        guaranteed for this batch (values beyond the float64-exact integer
        range) -- the engine then falls back to per-candidate evaluation.
        Implementations MUST be bit-identical to the scalar path whenever
        they return a result."""
        return None

    def batch_admit_core_builder(self, problem: Problem, arch: Architecture):
        """Optional traceable admission-bound core builder for the fused
        single-dispatch pipeline: an ``(xp, lax=None) -> core`` callable
        where ``core(tt, st, perm) -> (cycles[B], energy_pj[B], guard)``
        reproduces ``lower_bound_fn`` per row bit-identically (``guard``
        is the running max of every guarded integer-valued quantity; the
        host rejects the dispatch at BATCH_EXACT_LIMIT). The hierarchical
        models return ``AnalysisContext._make_lb_core``; None disables the
        fused path for this model."""
        return None

    def batch_cost_terms_fn(self, problem: Problem, arch: Architecture):
        """Optional array-program cost terms: a traceable closure
        ``terms(bt: BatchTraffic, xp) -> (latency[B], energy_pj[B],
        util[B], guard, extras)`` accumulating this model's latency/energy
        over the stacked traffic with ``xp`` ops only (numpy host-side,
        jax.numpy inside the fused jitted core -- the per-row float-op
        order must equal ``evaluate_signature``'s). ``guard`` is an xp
        scalar (max of guarded integer-valued products, checked host-side
        against BATCH_EXACT_LIMIT); ``extras`` is a str->array[B] dict
        carrying whatever :meth:`costs_from_batch` needs to rebuild
        breakdown dicts. None when unsupported (disables both the shared
        numpy scoring program and the fused jax path)."""
        return None

    def batch_cost_terms_generic(self, problem: Problem, arch: Architecture):
        """Optional SHAPE-GENERIC cost terms for the process-wide trace
        cache: ``(model_struct_key, model_params, terms)`` or None.

        ``model_struct_key`` is a hashable tuple of every STRUCTURAL
        property the terms program branches on (it joins the
        ``AnalysisContext.shape_class_key()`` in the compiled-program
        key); ``model_params`` is a dict of numpy arrays/scalars merged
        into the context's ``shape_params()`` pack and passed as a traced
        argument; ``terms(bt, xp, p)`` mirrors
        :meth:`batch_cost_terms_fn`'s closure but reads every VALUE from
        ``p`` instead of Python closure constants, so one jitted program
        serves every (problem, arch) pair with equal keys (the closure of
        the FIRST such pair gets traced; it must not capture values that
        can differ within the key class). ``model_params`` must include
        ``calib_scale`` (1.0 when uncalibrated) -- the generic fused core
        applies it as the final latency multiply. None when unsupported;
        the engine then falls back to the per-context
        :meth:`batch_cost_terms_fn` pipeline."""
        return None

    def costs_from_batch(
        self,
        problem: Problem,
        arch: Architecture,
        latency,
        energy,
        util,
        extras,
        indices=None,
    ) -> List[Cost]:
        """Materialize Cost objects (scalar-path breakdown layout
        included) from :meth:`batch_cost_terms_fn` output arrays --
        ``indices`` restricts materialization to the given rows (the
        engine's fused path builds Costs only for ADMITTED candidates)."""
        raise NotImplementedError

    def store_key_parts(self) -> "tuple":
        """Model-configuration part of the persistent ResultStore key (see
        ``repro.core.cost.store``). Two model instances with equal parts
        MUST produce bit-identical Costs for every (problem, arch,
        signature); models with scoring-relevant configuration override
        this to include it (and must append ``calibration_key_parts()``
        like this default does, so calibrated results never alias raw
        ones)."""
        return (self.name,) + self.calibration_key_parts()

    def conformable(self, problem: Problem) -> bool:
        """Whether this model can evaluate the problem at all.

        Overridden per model; see also repro.core.ir.conformability which
        runs these checks as compiler passes.
        """
        return True

    def evaluate_metric(
        self, problem: Problem, mapping: Mapping, arch: Architecture, metric: str = "edp"
    ) -> float:
        return self.evaluate(problem, mapping, arch).metric(metric)
