"""Persistent cross-search result store.

Figure sweeps (fig3/fig8/fig10/fig11) and ``mappers_bench`` re-run searches
over the same (problem, arch, cost model) spaces -- across aspect ratios,
bandwidth points, repeats, and whole benchmark invocations -- and a large
fraction of the signatures they score are identical between runs. The
:class:`ResultStore` memoizes ``signature -> Cost`` ACROSS searches and
(optionally) across processes:

  * **in-memory tier** -- a dict per *space key*, always on;
  * **on-disk tier** -- one versioned JSON file per space key under a
    directory, loaded lazily on first probe and written by :meth:`flush`
    (atomic tmp+rename under an advisory per-space lock). JSON, not
    pickle: a store directory is meant to be shared (between processes,
    or as a CI cache artifact), and loading it must never be a
    code-execution surface -- the records are plain numbers + a
    ``str -> float`` breakdown dict. Corrupt, truncated, or
    version-mismatched files are ignored (counted, never raised) and
    rewritten on the next flush.

The **space key** digests everything that determines a Cost besides the
mapping signature: problem dims/data-space projections/unit op, every
cost-relevant cluster attribute of the architecture, and the cost model's
``store_key_parts()``. Problem and architecture *names* that do not affect
scoring are excluded, so identical shapes share entries; cluster names ARE
included because they appear in Cost breakdown keys.

Correctness: a store hit returns the exact Cost an evaluation would have
produced (same engine, deterministic models), so search results are
unchanged -- only the ``pruned``/``analyzed`` counter split can shift,
because a stored candidate is served before the admission filter runs.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import os
import uuid
from pathlib import Path
from typing import Dict, Optional

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX platform
    fcntl = None

from repro.core.architecture import Architecture
from repro.core.cost.base import Cost, CostModel
from repro.core.problem import Problem

# Bump whenever the Cost record layout or any scoring semantics change in a
# way older entries cannot represent: mismatched files are discarded whole.
STORE_VERSION = 1


def _canon_problem(problem: Problem) -> dict:
    return {
        "dims": list(problem.dims.items()),
        "operation": problem.operation,
        "unit_op": problem.unit_op,
        "data_spaces": [
            {
                "name": ds.name,
                "out": ds.is_output,
                "wb": ds.word_bytes,
                "proj": [
                    [(t.coeff, t.dim) for t in expr.terms] for expr in ds.projection
                ],
            }
            for ds in problem.data_spaces
        ],
    }


def _canon_arch(arch: Architecture) -> dict:
    return {
        "freq": arch.frequency_hz,
        "attrs": sorted((k, repr(v)) for k, v in arch.attrs.items()),
        "clusters": [
            [
                c.name,  # appears in Cost breakdown keys
                c.fanout,
                c.dimension,
                c.memory_bytes,
                repr(c.fill_bandwidth),  # repr: json keeps inf stable
                c.read_energy,
                c.write_energy,
                c.macs_per_cycle,
                c.mac_energy,
            ]
            for c in arch.clusters
        ],
    }


def space_key(cost_model: CostModel, problem: Problem, arch: Architecture) -> str:
    """Stable digest of the (cost model, problem, arch) triple."""
    desc = json.dumps(
        {
            "version": STORE_VERSION,
            "model": [repr(p) for p in cost_model.store_key_parts()],
            "problem": _canon_problem(problem),
            "arch": _canon_arch(arch),
        },
        sort_keys=True,
        default=repr,
    )
    return hashlib.sha256(desc.encode()).hexdigest()[:32]


def _cost_to_record(c: Cost) -> list:
    return [
        c.latency_cycles,
        c.energy_pj,
        c.utilization,
        c.macs,
        c.frequency_hz,
        dict(c.breakdown),
    ]


def _cost_from_record(rec) -> Cost:
    latency, energy, util, macs, freq, breakdown = rec
    return Cost(
        latency_cycles=latency,
        energy_pj=energy,
        utilization=util,
        macs=macs,
        frequency_hz=freq,
        breakdown=breakdown,
    )


def _sig_to_key(sig) -> str:
    """Canonical signature tuple -> stable JSON string (dict key form)."""
    return json.dumps(sig, separators=(",", ":"))


def _sig_from_key(s: str):
    """Inverse of :func:`_sig_to_key`: rebuild the exact nested tuples."""
    return tuple(
        (tuple(order), tuple(tt), tuple(st)) for order, tt, st in json.loads(s)
    )


class ResultStore:
    """Cross-search ``(space key, signature) -> Cost`` store.

    One instance is shared across every search of a benchmark sweep (pass
    it to ``union_opt(result_store=...)``); the engine probes it on memo
    misses and feeds every fresh evaluation back. Thread-compatibility
    matches the engine's (single-threaded use per store).
    """

    def __init__(self, path: Optional[str] = None) -> None:
        self.path = Path(path) if path else None
        self._spaces: Dict[str, Dict[object, Cost]] = {}
        self._loaded: set = set()  # space keys whose disk tier was read
        self._dirty: set = set()
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.disk_loaded = 0  # entries brought in from disk
        self.corrupt = 0  # unreadable or version-mismatched files skipped

    # -------------------------------------------------------------- #
    def space_key(
        self, cost_model: CostModel, problem: Problem, arch: Architecture
    ) -> str:
        return space_key(cost_model, problem, arch)

    def _space(self, skey: str) -> Dict[object, Cost]:
        d = self._spaces.get(skey)
        if d is None:
            d = self._spaces[skey] = {}
        if self.path is not None and skey not in self._loaded:
            self._loaded.add(skey)
            f = self.path / f"{skey}.json"
            try:
                payload = json.loads(f.read_text())
                if (
                    isinstance(payload, dict)
                    and payload.get("version") == STORE_VERSION
                ):
                    for key, rec in payload["costs"].items():
                        sig = _sig_from_key(key)
                        if sig not in d:
                            d[sig] = _cost_from_record(rec)
                            self.disk_loaded += 1
                else:
                    self.corrupt += 1  # stale version: discard, rewrite later
            except FileNotFoundError:
                pass
            except Exception:
                self.corrupt += 1  # truncated/garbled file: start fresh
        return d

    def get(self, skey: str, sig) -> Optional[Cost]:
        c = self._space(skey).get(sig)
        if c is None:
            self.misses += 1
        else:
            self.hits += 1
        return c

    def put(self, skey: str, sig, cost: Cost) -> None:
        d = self._space(skey)
        if sig not in d:
            d[sig] = cost
            self.puts += 1
            self._dirty.add(skey)

    # -------------------------------------------------------------- #
    @contextlib.contextmanager
    def _store_lock(self):
        """Advisory exclusive lock serializing read-merge-replace across
        processes (POSIX flock; no-op where unavailable). One lock file
        per DIRECTORY, deliberately never unlinked: unlink-and-recreate
        races would break flock's mutual exclusion, and a single constant
        file cannot litter a long-lived shared store."""
        if fcntl is None:
            yield
            return
        with open(self.path / ".store.lock", "w") as lf:
            fcntl.flock(lf, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf, fcntl.LOCK_UN)

    def flush(self) -> int:
        """Write dirty spaces to the disk tier (atomic per space); returns
        the number of entries persisted. No-op without a path.

        Concurrent writers sharing a directory are lossless: under an
        advisory per-space lock, the on-disk file is re-read and UNIONED
        with the in-memory view right before the atomic replace, so
        entries another process flushed since our lazy load are preserved
        (identical keys are identical Costs by construction, so merge
        order is immaterial)."""
        if self.path is None:
            self._dirty.clear()
            return 0
        self.path.mkdir(parents=True, exist_ok=True)
        written = 0
        for skey in sorted(self._dirty):
            d = self._spaces[skey]
            costs = {_sig_to_key(sig): _cost_to_record(c) for sig, c in d.items()}
            with self._store_lock():
                try:
                    prior = json.loads((self.path / f"{skey}.json").read_text())
                    if (
                        isinstance(prior, dict)
                        and prior.get("version") == STORE_VERSION
                    ):
                        for key, rec in prior["costs"].items():
                            costs.setdefault(key, rec)
                except Exception:
                    pass  # absent/corrupt prior file: nothing to merge
                payload = {"version": STORE_VERSION, "costs": costs}
                # writer-unique tmp name: scratch files are never shared
                # even if a non-POSIX platform skipped the lock
                tmp = self.path / f".{skey}.{os.getpid()}.{uuid.uuid4().hex[:8]}.tmp"
                tmp.write_text(json.dumps(payload, separators=(",", ":")))
                tmp.replace(self.path / f"{skey}.json")
            written += len(costs)
        self._dirty.clear()
        return written

    def stats_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "puts": self.puts,
            "disk_loaded": self.disk_loaded,
            "corrupt": self.corrupt,
            "spaces": len(self._spaces),
            "entries": sum(len(d) for d in self._spaces.values()),
        }

    def __enter__(self) -> "ResultStore":
        return self

    def __exit__(self, *exc) -> None:
        self.flush()
