"""Persistent cross-search result store.

Figure sweeps (fig3/fig8/fig10/fig11) and ``mappers_bench`` re-run searches
over the same (problem, arch, cost model) spaces -- across aspect ratios,
bandwidth points, repeats, and whole benchmark invocations -- and a large
fraction of the signatures they score are identical between runs. The
:class:`ResultStore` memoizes ``signature -> Cost`` ACROSS searches and
(optionally) across processes:

  * **in-memory tier** -- a dict per *space key*, always on;
  * **on-disk tier** -- one versioned JSON file per space key under a
    directory, loaded lazily on first probe and written by :meth:`flush`
    (atomic tmp+rename under an advisory per-space lock). JSON, not
    pickle: a store directory is meant to be shared (between processes,
    or as a CI cache artifact), and loading it must never be a
    code-execution surface -- the records are plain numbers + a
    ``str -> float`` breakdown dict. Corrupt, truncated, or
    version-mismatched files are ignored (counted, never raised) and
    rewritten on the next flush.

The **space key** digests everything that determines a Cost besides the
mapping signature: problem dims/data-space projections/unit op, every
cost-relevant cluster attribute of the architecture, and the cost model's
``store_key_parts()``. Problem and architecture *names* that do not affect
scoring are excluded, so identical shapes share entries; cluster names ARE
included because they appear in Cost breakdown keys.

Correctness: a store hit returns the exact Cost an evaluation would have
produced (same engine, deterministic models), so search results are
unchanged -- only the ``pruned``/``analyzed`` counter split can shift,
because a stored candidate is served before the admission filter runs.
``SearchResult.considered`` (candidates submitted by the mapper) is the
warm/cold-INVARIANT total to compare runs by; throughput reporting
excludes store-served candidates from its denominator for the same
reason (see ``benchmarks/mappers_bench.py``).

Eviction: with ``max_entries_per_space`` set, each space is an LRU --
``get`` refreshes recency, the in-memory tier evicts past the cap, and
``flush`` compacts the disk tier to the cap AFTER the concurrent-writer
union (prior-file entries rank least recent), so the newest entries
survive and another writer's fresh results are never silently dropped
below the cap.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import logging
import math
import os
import time
import uuid
from collections import OrderedDict
from pathlib import Path
from typing import Dict, Optional

import numpy as np

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX platform
    fcntl = None

from repro.core.architecture import Architecture
from repro.core.cost.base import Cost, CostModel
from repro.core.problem import Problem

log = logging.getLogger("repro.store")

# Bump whenever the Cost record layout or any scoring semantics change in a
# way older entries cannot represent: mismatched files are discarded whole.
STORE_VERSION = 1

# Journal file format version (see SweepJournal); independent of the Cost
# record layout so store entries survive journal-schema changes.
JOURNAL_VERSION = 1


def _canon_num(v):
    """Canonical digest form for a (possibly numpy) numeric attribute.

    ``repr`` forks the key between equal values of different types --
    ``repr(np.float64(2.0))`` is ``'np.float64(2.0)'`` on numpy>=2 while
    ``repr(2.0)`` is ``'2.0'`` -- silently orphaning disk entries between
    writers that load the same architecture through different code paths.
    Numerics are therefore collapsed to plain Python ints/floats before
    the JSON digest, with explicit ``'inf'``/``'-inf'``/``'nan'`` string
    encodings (JSON has no literal for them). Non-numeric values keep
    their repr.
    """
    if isinstance(v, bool):
        return v
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        f = float(v)
        if math.isinf(f):
            return "inf" if f > 0 else "-inf"
        if math.isnan(f):
            return "nan"
        return f
    return repr(v)


def _canon_problem(problem: Problem) -> dict:
    return {
        "dims": [(d, _canon_num(s)) for d, s in problem.dims.items()],
        "operation": problem.operation,
        "unit_op": problem.unit_op,
        "data_spaces": [
            {
                "name": ds.name,
                "out": ds.is_output,
                "wb": _canon_num(ds.word_bytes),
                "proj": [
                    [(_canon_num(t.coeff), t.dim) for t in expr.terms]
                    for expr in ds.projection
                ],
            }
            for ds in problem.data_spaces
        ],
    }


def _canon_arch(arch: Architecture) -> dict:
    return {
        "freq": _canon_num(arch.frequency_hz),
        "attrs": sorted((k, _canon_num(v)) for k, v in arch.attrs.items()),
        "clusters": [
            [
                c.name,  # appears in Cost breakdown keys
                _canon_num(c.fanout),
                c.dimension,
                _canon_num(c.memory_bytes),
                _canon_num(c.fill_bandwidth),
                _canon_num(c.read_energy),
                _canon_num(c.write_energy),
                _canon_num(c.macs_per_cycle),
                _canon_num(c.mac_energy),
            ]
            for c in arch.clusters
        ],
    }


def space_key(cost_model: CostModel, problem: Problem, arch: Architecture) -> str:
    """Stable digest of the (cost model, problem, arch) triple."""
    desc = json.dumps(
        {
            "version": STORE_VERSION,
            "model": [repr(p) for p in cost_model.store_key_parts()],
            "problem": _canon_problem(problem),
            "arch": _canon_arch(arch),
        },
        sort_keys=True,
        default=repr,
    )
    return hashlib.sha256(desc.encode()).hexdigest()[:32]


def _cost_to_record(c: Cost) -> list:
    return [
        c.latency_cycles,
        c.energy_pj,
        c.utilization,
        c.macs,
        c.frequency_hz,
        dict(c.breakdown),
    ]


def _cost_from_record(rec) -> Cost:
    latency, energy, util, macs, freq, breakdown = rec
    return Cost(
        latency_cycles=latency,
        energy_pj=energy,
        utilization=util,
        macs=macs,
        frequency_hz=freq,
        breakdown=breakdown,
    )


def _sig_to_key(sig) -> str:
    """Canonical signature tuple -> stable JSON string (dict key form)."""
    return json.dumps(sig, separators=(",", ":"))


def _sig_from_key(s: str):
    """Inverse of :func:`_sig_to_key`: rebuild the exact nested tuples."""
    return tuple(
        (tuple(order), tuple(tt), tuple(st)) for order, tt, st in json.loads(s)
    )


def _model_digest(cost_model: CostModel) -> str:
    return hashlib.sha256(
        json.dumps([repr(p) for p in cost_model.store_key_parts()]).encode()
    ).hexdigest()[:16]


def _arch_digest(arch: Architecture) -> str:
    return hashlib.sha256(
        json.dumps(_canon_arch(arch), sort_keys=True, default=repr).encode()
    ).hexdigest()[:16]


def _problem_features(problem: Problem) -> dict:
    """Content features of a problem for nearest-neighbor space lookup.

    Dim NAMES are deliberately dropped: a 512x512x256 GEMM should be a
    near neighbor of a conv whose iteration space factors the same way,
    because what transfers between spaces is the *scale* of the search
    landscape, not the labels. Sorted log2 sizes make the vector
    permutation-invariant; macs (= iteration-space volume) rides along
    for incumbent scaling at the call site.
    """
    sizes = sorted(max(int(s), 1) for s in problem.dims.values())
    macs = 1.0
    for s in sizes:
        macs *= float(s)
    return {
        "ndims": len(sizes),
        "logdims": [round(math.log2(s), 6) for s in sizes],
        "macs": macs,
    }


def _feature_distance(a: dict, b: dict) -> float:
    """L2 over aligned sorted log2-size vectors + a rank-mismatch penalty.

    Vectors are right-aligned (largest dims paired with largest) and the
    shorter one zero-padded on the left, so a GEMM and a conv with the
    same dominant extents land close while a genuinely different scale
    stays far. Deterministic: pure arithmetic on stored floats.
    """
    la, lb = list(a["logdims"]), list(b["logdims"])
    n = max(len(la), len(lb))
    la = [0.0] * (n - len(la)) + la
    lb = [0.0] * (n - len(lb)) + lb
    d2 = sum((x - y) ** 2 for x, y in zip(la, lb))
    d2 += 4.0 * (a["ndims"] - b["ndims"]) ** 2
    return math.sqrt(d2)


class ResultStore:
    """Cross-search ``(space key, signature) -> Cost`` store.

    One instance is shared across every search of a benchmark sweep (pass
    it to ``union_opt(result_store=...)``); the engine probes it on memo
    misses and feeds every fresh evaluation back. Thread-compatibility
    matches the engine's (single-threaded use per store).

    ``max_entries_per_space`` caps both tiers per space key: the
    in-memory tier evicts least-recently-used entries as it grows past
    the cap (``get`` refreshes recency), and :meth:`flush` compacts the
    disk tier to the cap AFTER unioning with the on-disk file -- prior
    entries another writer flushed rank as least recent, then this
    store's entries in LRU order, and the newest ``cap`` survive. With
    the default (None) both tiers grow without bound, as before.
    """

    def __init__(
        self,
        path: Optional[str] = None,
        max_entries_per_space: Optional[int] = None,
        refresh: bool = False,
    ) -> None:
        self.path = Path(path) if path else None
        self.max_entries_per_space = (
            int(max_entries_per_space) if max_entries_per_space else None
        )
        # read-refresh mode for LONG-LIVED processes (the mapping-service
        # daemon): a get() miss re-stats the space's on-disk file and, when
        # another process's flush has bumped its mtime since our load,
        # reloads and unions the new entries -- daemon warm hits see
        # sweep-written results without a restart. Off by default: batch
        # sweeps load each space once and the extra stat per miss would be
        # pure overhead.
        self.refresh = bool(refresh)
        self._spaces: Dict[str, "OrderedDict[object, Cost]"] = {}
        self._loaded: set = set()  # space keys whose disk tier was read
        self._dirty: set = set()
        self._space_mtime: Dict[str, float] = {}  # disk mtime at last read
        self._meta: Dict[str, dict] = {}  # space key -> problem/arch features
        self._meta_loaded = False
        self._meta_dirty = False
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.disk_loaded = 0  # entries brought in from disk
        self.corrupt = 0  # unreadable or version-mismatched files skipped
        self.evicted = 0  # entries dropped by the per-space LRU cap
        self.stale_tmps = 0  # crashed writers' scratch files cleaned at flush
        self.reloads = 0  # read-refresh reloads of an mtime-bumped space

    # -------------------------------------------------------------- #
    def space_key(
        self, cost_model: CostModel, problem: Problem, arch: Architecture
    ) -> str:
        return space_key(cost_model, problem, arch)

    def _trim(self, d: "OrderedDict[object, Cost]") -> None:
        cap = self.max_entries_per_space
        if cap is not None:
            while len(d) > cap:
                d.popitem(last=False)  # least recently used first
                self.evicted += 1

    def _read_disk_tier(self, skey: str, d: "OrderedDict[object, Cost]") -> None:
        """Read ``{skey}.json`` and union its entries into ``d`` (existing
        signatures keep their in-memory Cost -- identical by construction).
        Records the file's mtime so the read-refresh probe can tell when
        another process's flush has replaced it."""
        f = self.path / f"{skey}.json"
        try:
            self._space_mtime[skey] = f.stat().st_mtime
        except OSError:
            self._space_mtime[skey] = 0.0  # absent: any future flush is news
        try:
            payload = json.loads(f.read_text())
            if (
                isinstance(payload, dict)
                and payload.get("version") == STORE_VERSION
            ):
                for key, rec in payload["costs"].items():
                    sig = _sig_from_key(key)
                    if sig not in d:
                        d[sig] = _cost_from_record(rec)
                        self.disk_loaded += 1
                self._trim(d)
            else:
                self.corrupt += 1  # stale version: discard, rewrite later
        except FileNotFoundError:
            pass
        except Exception:
            self.corrupt += 1  # truncated/garbled file: start fresh

    def _space(self, skey: str) -> "OrderedDict[object, Cost]":
        d = self._spaces.get(skey)
        if d is None:
            d = self._spaces[skey] = OrderedDict()
        if self.path is not None and skey not in self._loaded:
            self._loaded.add(skey)
            self._read_disk_tier(skey, d)
        return d

    def _maybe_reload(self, skey: str, d: "OrderedDict[object, Cost]") -> bool:
        """Read-refresh probe: re-stat the space file and reload when its
        mtime moved past our last read (another process flushed). Returns
        True when a reload actually happened."""
        if self.path is None or skey not in self._loaded:
            return False
        try:
            mtime = (self.path / f"{skey}.json").stat().st_mtime
        except OSError:
            return False
        if mtime <= self._space_mtime.get(skey, 0.0):
            return False
        self.reloads += 1
        self._read_disk_tier(skey, d)
        return True

    def get(self, skey: str, sig) -> Optional[Cost]:
        d = self._space(skey)
        c = d.get(sig)
        if c is None and self.refresh and self._maybe_reload(skey, d):
            c = d.get(sig)
        if c is None:
            self.misses += 1
        else:
            d.move_to_end(sig)  # LRU touch
            self.hits += 1
        return c

    def put(self, skey: str, sig, cost: Cost) -> None:
        d = self._space(skey)
        if sig not in d:
            d[sig] = cost
            self.puts += 1
            self._dirty.add(skey)
            self._trim(d)

    # -------------------------------------------------------------- #
    # Space metadata: nearest-neighbor warm start
    # -------------------------------------------------------------- #
    def _load_meta(self) -> None:
        if self._meta_loaded:
            return
        self._meta_loaded = True
        if self.path is None:
            return
        try:
            payload = json.loads((self.path / "_meta.json").read_text())
            if (
                isinstance(payload, dict)
                and payload.get("version") == STORE_VERSION
            ):
                for skey, rec in payload.get("spaces", {}).items():
                    self._meta.setdefault(skey, rec)
            else:
                self.corrupt += 1
        except FileNotFoundError:
            pass
        except Exception:
            self.corrupt += 1  # tolerated like a garbled space file

    def register_space_meta(
        self, skey: str, cost_model: CostModel, problem: Problem, arch: Architecture
    ) -> None:
        """Record the content features of a space so later queries can find
        it as a nearest neighbor. Idempotent; persisted by :meth:`flush`."""
        self._load_meta()
        if skey in self._meta:
            return
        rec = dict(_problem_features(problem))
        rec["model"] = _model_digest(cost_model)
        rec["arch"] = _arch_digest(arch)
        self._meta[skey] = rec
        self._meta_dirty = True

    def nearest_space(
        self,
        cost_model: CostModel,
        problem: Problem,
        arch: Architecture,
        exclude: Optional[str] = None,
    ) -> Optional[tuple]:
        """Nearest registered space to ``problem`` under the SAME cost model
        and architecture (costs from a different model or machine are not
        comparable, so they never seed an incumbent). Returns
        ``(skey, distance)`` or None; ties break on skey for determinism.
        """
        self._load_meta()
        model, ad = _model_digest(cost_model), _arch_digest(arch)
        q = _problem_features(problem)
        best = None
        for skey in sorted(self._meta):
            if skey == exclude:
                continue
            rec = self._meta[skey]
            if rec.get("model") != model or rec.get("arch") != ad:
                continue
            try:
                dist = _feature_distance(q, rec)
            except Exception:
                continue  # malformed record from a foreign writer
            if best is None or dist < best[1]:
                best = (skey, dist)
        return best

    def space_meta(self, skey: str) -> Optional[dict]:
        self._load_meta()
        rec = self._meta.get(skey)
        return dict(rec) if rec is not None else None

    def best_in_space(self, skey: str, metric: str) -> Optional[float]:
        """Minimum stored ``Cost.metric(metric)`` over a space (loads the
        disk tier), or None when the space is empty/unknown."""
        d = self._space(skey)
        best = None
        for c in d.values():
            try:
                v = float(c.metric(metric))
            except Exception:
                continue
            if math.isfinite(v) and (best is None or v < best):
                best = v
        return best

    # -------------------------------------------------------------- #
    @contextlib.contextmanager
    def _store_lock(self):
        """Advisory exclusive lock serializing read-merge-replace across
        processes (POSIX flock; no-op where unavailable). One lock file
        per DIRECTORY, deliberately never unlinked: unlink-and-recreate
        races would break flock's mutual exclusion, and a single constant
        file cannot litter a long-lived shared store."""
        if fcntl is None:
            yield
            return
        with open(self.path / ".store.lock", "w") as lf:
            fcntl.flock(lf, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf, fcntl.LOCK_UN)

    def _clean_stale_tmps(self) -> int:
        """Remove scratch ``.tmp`` files a crashed writer left behind.

        Every tmp is created and renamed away UNDER the directory lock, so
        any tmp visible at lock acquisition belongs to a writer that died
        between write and rename -- a crash window that must not
        accumulate litter in a long-lived shared store. Where flock is
        unavailable (non-POSIX, so writers are not serialized) only tmps
        older than 60s are removed, keeping a live writer's in-flight
        scratch file safe. Returns the number of files removed (also
        accumulated in ``stale_tmps``)."""
        removed = 0
        try:
            candidates = list(self.path.glob(".*.tmp"))
        except OSError:
            return 0
        now = time.time()
        for tmp in candidates:
            try:
                if fcntl is None and now - tmp.stat().st_mtime < 60.0:
                    continue
                tmp.unlink()
                removed += 1
            except OSError:
                pass  # already gone (or unreadable): someone else cleaned it
        if removed:
            self.stale_tmps += removed
            log.warning("result store %s: cleaned %d stale tmp file(s) left "
                        "by crashed writer(s)", self.path, removed)
        return removed

    def flush(self) -> int:
        """Write dirty spaces to the disk tier as ONE atomic write pass:
        the directory lock is acquired once and every dirty space is
        merged and atomically replaced under it -- a figure sweep touching
        many (problem, arch, model) spaces pays one lock round-trip
        instead of one per space, and no interleaving writer can observe
        (or race into) a half-flushed set of spaces. Returns the number of
        entries persisted. No-op without a path.

        Concurrent writers sharing a directory are lossless: under the
        lock, each space's on-disk file is re-read and UNIONED with the
        in-memory view right before its atomic replace, so entries another
        process flushed since our lazy load are preserved (identical keys
        are identical Costs by construction, so merge order is
        immaterial) -- including writers whose dirty sets cover DIFFERENT
        spaces (disjoint files never collide; shared ones union).

        With ``max_entries_per_space`` set, the merged union is LRU-
        compacted to the cap before the replace: prior-file entries not
        in memory rank least recent (in their file order, i.e. the other
        writer's LRU order), this store's entries follow in local LRU
        order, and only the newest ``cap`` survive -- so eviction composes
        with the union guarantee instead of clobbering it."""
        if self.path is None:
            self._dirty.clear()
            self._meta_dirty = False
            return 0
        dirty = sorted(self._dirty)
        if not dirty and not self._meta_dirty:
            return 0
        self.path.mkdir(parents=True, exist_ok=True)
        cap = self.max_entries_per_space
        written = 0
        with self._store_lock():
            self._clean_stale_tmps()
            if self._meta_dirty:
                self._flush_meta_locked()
            for skey in dirty:
                d = self._spaces[skey]
                mem = {_sig_to_key(sig): _cost_to_record(c) for sig, c in d.items()}
                merged: "OrderedDict[str, object]" = OrderedDict()
                try:
                    prior = json.loads((self.path / f"{skey}.json").read_text())
                    if (
                        isinstance(prior, dict)
                        and prior.get("version") == STORE_VERSION
                    ):
                        for key, rec in prior["costs"].items():
                            if key not in mem:
                                merged[key] = rec
                except Exception:
                    pass  # absent/corrupt prior file: nothing to merge
                merged.update(mem)  # in-memory LRU order, most recent last
                if cap is not None and len(merged) > cap:
                    drop = len(merged) - cap
                    for key in list(merged)[:drop]:
                        del merged[key]
                        self.evicted += 1
                payload = {"version": STORE_VERSION, "costs": dict(merged)}
                # writer-unique tmp name: scratch files are never shared
                # even if a non-POSIX platform skipped the lock
                tmp = self.path / f".{skey}.{os.getpid()}.{uuid.uuid4().hex[:8]}.tmp"
                tmp.write_text(json.dumps(payload, separators=(",", ":")))
                target = self.path / f"{skey}.json"
                tmp.replace(target)
                written += len(merged)
                # our own replace bumped the mtime; record it so the
                # read-refresh probe doesn't reload what we just wrote
                try:
                    self._space_mtime[skey] = target.stat().st_mtime
                except OSError:
                    pass
        self._dirty.clear()
        return written

    def _flush_meta_locked(self) -> None:
        """Merge + atomically replace ``_meta.json``; caller holds the
        directory lock. Prior records from other writers are preserved
        (identical skeys describe identical spaces, so merge order is
        immaterial)."""
        merged: Dict[str, dict] = {}
        try:
            prior = json.loads((self.path / "_meta.json").read_text())
            if isinstance(prior, dict) and prior.get("version") == STORE_VERSION:
                merged.update(prior.get("spaces", {}))
        except Exception:
            pass  # absent/corrupt prior meta: rewrite from memory
        merged.update(self._meta)
        payload = {"version": STORE_VERSION, "spaces": merged}
        tmp = self.path / f"._meta.{os.getpid()}.{uuid.uuid4().hex[:8]}.tmp"
        tmp.write_text(json.dumps(payload, sort_keys=True, separators=(",", ":")))
        tmp.replace(self.path / "_meta.json")
        self._meta = merged
        self._meta_dirty = False

    def stats_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "puts": self.puts,
            "disk_loaded": self.disk_loaded,
            "corrupt": self.corrupt,
            "evicted": self.evicted,
            "stale_tmps": self.stale_tmps,
            "reloads": self.reloads,
            "spaces": len(self._spaces),
            "entries": sum(len(d) for d in self._spaces.values()),
        }

    def __enter__(self) -> "ResultStore":
        return self

    def __exit__(self, *exc) -> None:
        self.flush()


# --------------------------------------------------------------------- #
# Sweep journal (crash-safe resume)
# --------------------------------------------------------------------- #
class SweepJournal:
    """Crash-safe progress journal for one named sweep.

    The concurrent sweep executor (``repro.core.sweep_exec``) records every
    completed task's SOLUTION RECORD (mapping dict + Cost record + search
    stats -- the exact data a solution is rebuilt from) keyed by a stable
    task fingerprint, plus per-group attempt counts. A sweep killed
    mid-flight and restarted with ``resume=True`` replays the journaled
    records verbatim -- completed groups are skipped entirely, in-flight
    groups re-run warm against the shared :class:`ResultStore` -- so the
    restarted sweep's outputs match an uninterrupted run's.

    File layout (single JSON file, usually next to the store's space
    files)::

        {"version": 1,
         "groups": {group_key: {"attempts": int, "done": bool}},
         "tasks":  {fingerprint: <opaque solution record>}}

    Flush discipline matches :meth:`ResultStore.flush`: writer-unique tmp
    + atomic rename under an advisory flock (``<journal>.lock``), stale
    ``.jtmp`` scratch files cleaned under the lock. The journal is
    flushed at every group START (attempts survive a crash, so "fail
    group N on attempt K" fault specs stay deterministic across restarts)
    and at every group COMPLETION -- a SIGKILL can lose at most the
    in-flight group's work, never corrupt the file.

    A journal opened without ``resume`` IGNORES any existing file and
    starts fresh (first flush replaces it): attempts and done flags from
    an unrelated earlier sweep must not leak into a new cold run.
    Corrupt or version-mismatched files are discarded (counted in
    ``corrupt``), mirroring the store's tolerance.
    """

    def __init__(self, path, resume: bool = False) -> None:
        self.path = Path(path)
        self.groups: Dict[str, dict] = {}
        self.tasks: Dict[str, object] = {}
        self.corrupt = 0
        self.resumed = False  # a prior journal was actually loaded
        if resume:
            try:
                payload = json.loads(self.path.read_text())
                if (
                    isinstance(payload, dict)
                    and payload.get("version") == JOURNAL_VERSION
                ):
                    self.groups = dict(payload.get("groups", {}))
                    self.tasks = dict(payload.get("tasks", {}))
                    self.resumed = True
                else:
                    self.corrupt += 1
            except FileNotFoundError:
                pass  # nothing to resume: behaves like a fresh journal
            except Exception:
                self.corrupt += 1

    # -------------------------------------------------------------- #
    def group_attempts(self, gkey: str) -> int:
        return int(self.groups.get(gkey, {}).get("attempts", 0))

    def group_done(self, gkey: str) -> bool:
        return bool(self.groups.get(gkey, {}).get("done", False))

    def note_group_start(self, gkey: str) -> None:
        g = self.groups.setdefault(gkey, {"attempts": 0, "done": False})
        g["attempts"] = int(g["attempts"]) + 1
        self.flush()

    def record_group(self, gkey: str, records: Dict[str, object]) -> None:
        """Mark ``gkey`` complete with its tasks' solution records."""
        self.tasks.update(records)
        g = self.groups.setdefault(gkey, {"attempts": 0, "done": False})
        g["done"] = True
        self.flush()

    def get_task(self, fingerprint: str):
        return self.tasks.get(fingerprint)

    # -------------------------------------------------------------- #
    @contextlib.contextmanager
    def _lock(self):
        """Advisory flock on ``<journal>.lock`` (constant file, never
        unlinked -- same rationale as the store's directory lock)."""
        if fcntl is None:
            yield
            return
        with open(self.path.with_name(self.path.name + ".lock"), "w") as lf:
            fcntl.flock(lf, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf, fcntl.LOCK_UN)

    def flush(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "version": JOURNAL_VERSION,
            "groups": self.groups,
            "tasks": self.tasks,
        }
        with self._lock():
            now = time.time()
            for tmp in self.path.parent.glob(f".{self.path.name}.*.jtmp"):
                try:
                    if fcntl is None and now - tmp.stat().st_mtime < 60.0:
                        continue
                    tmp.unlink()  # crashed writer's scratch: clean it
                except OSError:
                    pass
            tmp = self.path.with_name(
                f".{self.path.name}.{os.getpid()}.{uuid.uuid4().hex[:8]}.jtmp"
            )
            tmp.write_text(json.dumps(payload, separators=(",", ":")))
            tmp.replace(self.path)

    def stats_dict(self) -> dict:
        return {
            "groups": len(self.groups),
            "groups_done": sum(1 for g in self.groups.values() if g.get("done")),
            "tasks": len(self.tasks),
            "corrupt": self.corrupt,
            "resumed": self.resumed,
        }
