"""Fault-tolerant concurrent executor for ``union_opt_sweep``.

``union_opt_sweep`` groups its tasks by persistent-store space key; since
the array-native engine rework the groups are INDEPENDENT by construction
(each owns one :class:`EvaluationEngine`, shares nothing but the
concurrent-writer-safe :class:`ResultStore`). This module turns that
independence into a service-grade execution tier:

* **Concurrent dispatch** -- groups run on a worker pool. ``pool="thread"``
  keeps every engine in-process (shared memo/ctx, but GIL-bound on the
  numpy path); ``pool="process"`` (the default for ``workers > 1``) spawns
  fresh interpreters per group dispatch -- imports stay jax-free on the
  numpy path (see ``repro.runtime``'s lazy exports), each child opens its
  own ResultStore handle on the shared directory, and the store's
  union-on-flush merges results losslessly.

* **Failure handling** -- every group dispatch is wrapped in
  :func:`repro.runtime.fault_tolerance.retry_call`: a per-attempt
  ``group_timeout_s`` watchdog (hung trace/dispatch -> the attempt is
  abandoned and re-run), bounded retries with exponential backoff and
  deterministic jitter, and a straggler meter over group wall-clocks.
  A failed attempt may already have flushed fresh Costs to the store;
  re-running is safe because scoring is deterministic and the store is
  idempotent.

* **Graceful backend degradation** -- a jax failure inside a group
  (import, trace, compile, or dispatch) does NOT consume a retry: the
  engine itself degrades to the numpy batch path mid-search
  (:meth:`EvaluationEngine._check_backend_degraded`), bit-identical by
  the backend contract, counted in ``backend_fallbacks``.

* **Crash-safe resume** -- with a :class:`SweepJournal`, every completed
  group's solution records (mapping + cost + search counters) are flushed
  atomically; a SIGKILL'd sweep restarted with ``resume=True`` replays
  finished groups from the journal and re-runs only the rest, warm
  against the store. ALL solutions -- fresh or replayed -- round-trip
  through the same JSON record form, so a resumed sweep's outputs are
  identical to an uninterrupted run's by construction.

* **Deterministic fault injection** -- ``UNION_FAULT_SPEC`` (or the
  ``fault_spec=`` argument) drives every failure path on CPU in CI::

      fail:G@K          raise on group G (first-occurrence order),
                        attempt K (0-based)
      hang:G@K[:SECS]   group G attempt K sleeps SECS (default 5.0)
                        inside the watchdogged region, BEFORE any work --
                        models a wedged dispatch (nothing completes)
      slow:G@K[:SECS]   group G attempt K takes SECS (default 1.0) of
                        EXTRA latency spread evenly across its tasks --
                        work completes, just slowly, so deadline-with-
                        partial-result paths (the mapping service's
                        ``budget_exhausted`` answers) are testable
                        deterministically
      jaxfail:G         group G's analysis context reports a jax failure
                        -> engine degrades to numpy
      kill-after:N      SIGKILL this process right after the Nth
                        completed group's Costs are flushed to the store
                        but BEFORE its journal record -- the worst crash
                        ordering; a resumed sweep replays N-1 groups and
                        re-runs the Nth warm against the store

Clauses are ``;``-separated, e.g. ``"fail:1@0;hang:2@0:3;kill-after:2"``.
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import hashlib
import json
import logging
import os
import pickle
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost.engine import EvaluationEngine
from repro.core.cost.store import (
    ResultStore,
    SweepJournal,
    _cost_from_record,
    _cost_to_record,
    space_key,
)
from repro.core.mappers import MAPPER_REGISTRY
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace
from repro.runtime.fault_tolerance import (
    CallTimeoutError,
    RetryPolicy,
    RetryStats,
    StragglerMeter,
    call_with_deadline,
    retry_call,
)

log = logging.getLogger("repro.sweep")


# --------------------------------------------------------------------- #
# Fault-injection spec
# --------------------------------------------------------------------- #
@dataclass
class FaultSpec:
    """Parsed ``UNION_FAULT_SPEC`` (see module docstring for grammar)."""

    fails: Dict[Tuple[int, int], bool] = field(default_factory=dict)
    hangs: Dict[Tuple[int, int], float] = field(default_factory=dict)
    slows: Dict[Tuple[int, int], float] = field(default_factory=dict)
    jaxfail: frozenset = frozenset()
    kill_after: Optional[int] = None

    @classmethod
    def parse(cls, spec: Optional[str]) -> "FaultSpec":
        fs = cls()
        if not spec:
            return fs
        jax_groups = set()
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            kind, _, rest = clause.partition(":")
            try:
                if kind == "fail":
                    g, _, k = rest.partition("@")
                    fs.fails[(int(g), int(k))] = True
                elif kind == "hang":
                    g, _, tail = rest.partition("@")
                    k, _, secs = tail.partition(":")
                    fs.hangs[(int(g), int(k))] = float(secs) if secs else 5.0
                elif kind == "slow":
                    g, _, tail = rest.partition("@")
                    k, _, secs = tail.partition(":")
                    fs.slows[(int(g), int(k))] = float(secs) if secs else 1.0
                elif kind == "jaxfail":
                    jax_groups.add(int(rest))
                elif kind == "kill-after":
                    fs.kill_after = int(rest)
                else:
                    raise ValueError(f"unknown clause kind {kind!r}")
            except (ValueError, TypeError) as e:
                raise ValueError(
                    f"bad UNION_FAULT_SPEC clause {clause!r}: {e}"
                ) from None
        fs.jaxfail = frozenset(jax_groups)
        return fs

    def check_fail(self, group: int, attempt: int) -> None:
        if self.fails.get((group, attempt)):
            raise RuntimeError(
                f"injected failure (group {group}, attempt {attempt})"
            )

    def hang_s(self, group: int, attempt: int) -> float:
        return self.hangs.get((group, attempt), 0.0)

    def slow_s(self, group: int, attempt: int) -> float:
        return self.slows.get((group, attempt), 0.0)


# --------------------------------------------------------------------- #
# Canonical fingerprints
# --------------------------------------------------------------------- #
def _canon(obj):
    """JSON-safe canonical form: sets become sorted lists, dicts sort by
    key, dataclasses flatten to dicts -- the pieces whose ``repr`` is
    process-dependent (set iteration order under hash randomization)
    must never leak into a fingerprint."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _canon(
            {f.name: getattr(obj, f.name) for f in dataclasses.fields(obj)}
        )
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in sorted(obj.items(), key=lambda kv: str(kv[0]))}
    if isinstance(obj, (set, frozenset)):
        return sorted((_canon(v) for v in obj), key=repr)
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def task_fingerprint(gkey: str, problem, arch, mapper_spec, constraints,
                     tag, ordinal: int) -> str:
    """Stable cross-process fingerprint of one sweep task.

    ``ordinal`` disambiguates tasks that are otherwise identical within
    one sweep (the journal must keep one record per task slot). Problem
    and arch NAMES are included even though the space key excludes them:
    a resumed sweep must hand each record back to the task slot with the
    matching identity.
    """
    desc = json.dumps(
        {
            "gkey": gkey,
            "problem": getattr(problem, "name", ""),
            "arch": getattr(arch, "name", ""),
            "mapper": _canon(mapper_spec),
            "constraints": _canon(constraints),
            "tag": _canon(tag),
            "ordinal": ordinal,
        },
        sort_keys=True,
    )
    return hashlib.sha256(desc.encode()).hexdigest()[:24]


# --------------------------------------------------------------------- #
# Solution records (the single form every sweep result passes through)
# --------------------------------------------------------------------- #
def result_to_record(res: SearchResult) -> dict:
    """SearchResult -> JSON-clean record. ``json`` round-trip applied
    eagerly so a record served live is type-identical (lists, not tuples)
    to one reloaded from the journal -- resumed sweeps must be
    indistinguishable from uninterrupted ones."""
    rec = {
        "mapping": res.best_mapping.to_dict(),
        "cost": _cost_to_record(res.best_cost),
        "metric": res.metric,
        "trajectory": [[int(i), float(v)] for i, v in res.trajectory],
        "counters": {
            "evaluated": res.evaluated,
            "elapsed_s": res.elapsed_s,
            "cache_hits": res.cache_hits,
            "pruned": res.pruned,
            "analyzed": res.analyzed,
            "store_hits": res.store_hits,
            "considered": res.considered,
            "fused_dispatches": res.fused_dispatches,
            "backend_fallbacks": res.backend_fallbacks,
            "n_traces": res.n_traces,
            "device_syncs": res.device_syncs,
            "admit_s": res.admit_s,
            "score_s": res.score_s,
        },
    }
    return json.loads(json.dumps(rec))


def result_from_record(rec: dict) -> SearchResult:
    c = rec["counters"]
    return SearchResult(
        best_mapping=Mapping.from_dict(rec["mapping"]),
        best_cost=_cost_from_record(rec["cost"]),
        metric=rec["metric"],
        evaluated=int(c["evaluated"]),
        elapsed_s=float(c["elapsed_s"]),
        trajectory=[(int(i), float(v)) for i, v in rec["trajectory"]],
        cache_hits=int(c["cache_hits"]),
        pruned=int(c["pruned"]),
        analyzed=int(c["analyzed"]),
        store_hits=int(c["store_hits"]),
        considered=int(c["considered"]),
        fused_dispatches=int(c["fused_dispatches"]),
        backend_fallbacks=int(c.get("backend_fallbacks", 0)),
        n_traces=int(c.get("n_traces", 0)),
        device_syncs=int(c.get("device_syncs", 0)),
        admit_s=float(c["admit_s"]),
        score_s=float(c["score_s"]),
    )


# --------------------------------------------------------------------- #
# Group payloads + the group runner (runs in-process OR in a spawned
# worker -- module-level so it pickles)
# --------------------------------------------------------------------- #
def _resolve_mapper(spec) -> Mapper:
    """``("name", kw)`` -> a FRESH mapper instance (so a retried group
    replays the exact seeded candidate stream); an already-built Mapper
    object passes through (caller-owned state, reuse documented)."""
    if isinstance(spec, Mapper):
        return spec
    name, kw = spec
    return MAPPER_REGISTRY[name](**dict(kw))


def run_group(payload: dict) -> dict:
    """Execute one engine group: build the engine, run each task's
    search, return ``{"records": {fingerprint: record}, ...}``.

    The payload is a plain dict so the same function serves the serial
    path, thread workers, and spawned processes (where it arrives
    pickled). ``store`` is a live ResultStore in-process; ``store_path``
    + ``store_cap`` instead in a child, which opens its own handle on the
    shared directory (lossless union-on-flush).
    """
    hang_s = payload.get("hang_s", 0.0)
    if hang_s > 0:
        time.sleep(hang_s)  # injected hang, inside the watchdogged region
    # injected slowness: spread across tasks so the group makes progress
    # (tasks complete, just late) instead of stalling up front like hang
    slow_per_task = payload.get("slow_s", 0.0) / max(1, len(payload["tasks"]))

    store = payload.get("store")
    own_store = False
    if store is None and payload.get("store_path"):
        store = ResultStore(
            payload["store_path"],
            max_entries_per_space=payload.get("store_cap"),
        )
        own_store = True

    problem = payload["problem"]
    arch = payload["arch"]
    cm = payload["cost_model"]
    engine = EvaluationEngine(
        cm,
        problem,
        arch,
        metric=payload["metric"],
        cache_size=payload["engine_cache"],
        prune=payload["engine_prune"],
        workers=payload["engine_workers"],
        backend=payload["engine_backend"],
        store=store,
    )
    ctx = engine._ctx
    prior_jax_flag = ctx._jax_failed
    if payload.get("inject_jax_fail"):
        # simulate a trace/compile failure at the shared choke point every
        # jax path funnels through; restored below so the process-global
        # context cache is not poisoned for later (non-injected) sweeps
        ctx._jax_failed = True
    warmed = 0
    records: Dict[str, dict] = {}
    try:
        for tsk in payload["tasks"]:
            if slow_per_task > 0:
                time.sleep(slow_per_task)
            mp = _resolve_mapper(tsk["mapper"])
            if payload.get("warmup", True):
                warmed += engine.warmup(mp.batch_hints())
            space = MapSpace(problem, arch, tsk["constraints"])
            res = mp.search(space, engine.cost_model, payload["metric"], engine=engine)
            if res.best_mapping is None:
                raise RuntimeError(
                    f"mapper {mp.name} found no legal mapping for {problem.name}"
                )
            records[tsk["fingerprint"]] = result_to_record(res)
    finally:
        engine.close()
        if payload.get("inject_jax_fail"):
            ctx._jax_failed = prior_jax_flag
        if own_store and store is not None:
            store.flush()
    return {
        "records": records,
        "warmed": warmed,
        "backend_fallbacks": engine.stats.backend_fallbacks,
        "engine_backend": engine.backend,
        # a child's store traffic would vanish with its handle; ship the
        # counters home so the parent store's stats cover the whole sweep
        "store_stats": store.stats_dict() if own_store else None,
    }


def _process_group_main(blob: bytes) -> bytes:
    """Spawned-worker entry: payloads cross the boundary pre-pickled so a
    non-picklable group fails in the PARENT (where it can fall back to
    in-process execution) instead of poisoning the pool."""
    return pickle.dumps(run_group(pickle.loads(blob)))


# --------------------------------------------------------------------- #
# The executor
# --------------------------------------------------------------------- #
@dataclass(eq=False)  # identity equality: membership tests, not content
class _Group:
    index: int                      # first-occurrence order (fault-spec id)
    gkey: str                       # journal key
    problem: object                 # canonical group objects (content-equal
    arch: object                    # across the group's tasks)
    cost_model: object
    metric: str
    tasks: List[dict] = field(default_factory=list)  # {fingerprint, mapper, constraints}
    task_slots: List[int] = field(default_factory=list)  # sweep task indices


class SweepExecutor:
    """Dispatch independent engine groups with retries, deadlines,
    straggler accounting, crash-safe journaling, and optional
    thread/process concurrency. See the module docstring for the model.
    """

    def __init__(
        self,
        *,
        engine_backend: Optional[str] = "numpy",
        engine_workers: int = 0,
        engine_cache: int = 1 << 16,
        engine_prune: bool = True,
        result_store: Optional[ResultStore] = None,
        warmup: bool = True,
        workers: int = 0,
        pool: str = "auto",
        group_timeout_s: Optional[float] = None,
        max_group_retries: int = 2,
        group_backoff_s: float = 0.05,
        journal=None,
        resume: bool = False,
        fault_spec: Optional[str] = None,
    ) -> None:
        self.engine_backend = engine_backend
        self.engine_workers = engine_workers
        self.engine_cache = engine_cache
        self.engine_prune = engine_prune
        self.store = result_store
        self.warmup = warmup
        self.workers = max(0, int(workers))
        if pool not in ("auto", "thread", "process", "serial"):
            raise ValueError(f"unknown pool kind {pool!r}")
        self.pool_kind = pool
        self.group_timeout_s = group_timeout_s
        self.max_group_retries = max_group_retries
        self.group_backoff_s = group_backoff_s
        if journal is not None and not isinstance(journal, SweepJournal):
            journal = SweepJournal(journal, resume=resume)
        self.journal: Optional[SweepJournal] = journal
        self.fault = FaultSpec.parse(
            fault_spec if fault_spec is not None
            else os.environ.get("UNION_FAULT_SPEC")
        )
        self.retry_stats = RetryStats()
        self.meter = StragglerMeter()
        self._lock = threading.Lock()
        self._completed = 0
        self._flush_store_per_group = False  # set per-mode in run()
        self.group_wall: List[dict] = []

    # -------------------------------------------------------------- #
    def _mode(self) -> str:
        if self.workers <= 1 or self.pool_kind == "serial":
            return "serial"
        if self.pool_kind == "auto":
            # measured: the numpy engine path is GIL-bound (threads give
            # ~1.0x), so processes are the load-bearing concurrency path
            return "process"
        return self.pool_kind

    @staticmethod
    def build_groups(resolved: Sequence[tuple], *, engine_backend,
                     engine_prune) -> List[_Group]:
        """Group resolved tasks ``(task, problem, cm, mapper_spec)`` by
        space key + metric + backend + prune -- the same sharing rule the
        serial sweep used, now with a stable string key for the journal
        and a first-occurrence index for fault specs."""
        groups: Dict[str, _Group] = {}
        dup_counts: Dict[str, int] = {}
        for slot, (t, problem, cm, mapper_spec) in enumerate(resolved):
            skey = space_key(cm, problem, t.arch)
            gkey = f"{skey}:{t.metric}:{engine_backend}:{engine_prune}"
            g = groups.get(gkey)
            if g is None:
                g = groups[gkey] = _Group(
                    index=len(groups), gkey=gkey, problem=problem,
                    arch=t.arch, cost_model=cm, metric=t.metric,
                )
            base_fp = task_fingerprint(
                gkey, problem, t.arch, mapper_spec, t.constraints,
                t.tag, 0,
            )
            ordinal = dup_counts.get(base_fp, 0)
            dup_counts[base_fp] = ordinal + 1
            fp = base_fp if ordinal == 0 else task_fingerprint(
                gkey, problem, t.arch, mapper_spec, t.constraints,
                t.tag, ordinal,
            )
            g.tasks.append(
                {"fingerprint": fp, "mapper": mapper_spec,
                 "constraints": t.constraints}
            )
            g.task_slots.append(slot)
        return list(groups.values())

    def _payload(self, g: _Group, attempt: int, for_process: bool) -> dict:
        p = {
            "problem": g.problem,
            "arch": g.arch,
            "cost_model": g.cost_model,
            "metric": g.metric,
            "engine_backend": self.engine_backend,
            "engine_workers": self.engine_workers,
            "engine_cache": self.engine_cache,
            "engine_prune": self.engine_prune,
            "warmup": self.warmup,
            "tasks": g.tasks,
            "hang_s": self.fault.hang_s(g.index, attempt),
            "slow_s": self.fault.slow_s(g.index, attempt),
            "inject_jax_fail": g.index in self.fault.jaxfail,
        }
        if for_process:
            if self.store is not None and self.store.path is not None:
                p["store_path"] = str(self.store.path)
                p["store_cap"] = self.store.max_entries_per_space
        else:
            p["store"] = self.store
        return p

    # -------------------------------------------------------------- #
    def _attempt(self, g: _Group, attempt: int, pool) -> dict:
        """One group dispatch attempt under the deadline."""
        if pool is None:
            return call_with_deadline(
                lambda: run_group(self._payload(g, attempt, False)),
                self.group_timeout_s,
                label=f"group{g.index}",
            )
        # process pool: the deadline is enforced parent-side on the
        # future (a hung child cannot be trusted to watchdog itself); a
        # timed-out dispatch is abandoned like the thread watchdog's --
        # the worker slot frees when the child's work returns
        from concurrent.futures.process import BrokenProcessPool

        blob = pickle.dumps(self._payload(g, attempt, True))
        try:
            fut = pool.submit(_process_group_main, blob)
        except BrokenProcessPool:
            # the pool died (OOM-killed child, broken spawn) and cannot
            # recover; retrying through it would burn the whole budget, so
            # this and subsequent attempts degrade to in-process execution
            log.warning(
                "process pool broken; running group%d in-process", g.index
            )
            return call_with_deadline(
                lambda: run_group(self._payload(g, attempt, False)),
                self.group_timeout_s,
                label=f"group{g.index}",
            )
        try:
            return pickle.loads(fut.result(timeout=self.group_timeout_s))
        except cf.TimeoutError:
            fut.cancel()
            raise CallTimeoutError(
                f"group{g.index} exceeded {self.group_timeout_s}s deadline"
            ) from None

    def _dispatch(self, g: _Group, pool) -> dict:
        """Retry loop for one group; returns the group output dict."""
        label = f"group{g.index}"

        def attempt_hook(attempt: int) -> None:
            with self._lock:
                if self.journal is not None:
                    self.journal.note_group_start(g.gkey)
            self.fault.check_fail(g.index, attempt)

        t0 = time.time()
        out, _st = retry_call(
            lambda attempt: self._attempt(g, attempt, pool),
            RetryPolicy(
                max_retries=self.max_group_retries,
                deadline_s=None,  # enforced inside _attempt (pool-aware)
                backoff_s=self.group_backoff_s,
            ),
            label=label,
            attempt_hook=attempt_hook,
            stats=self.retry_stats,
        )
        wall = time.time() - t0
        with self._lock:
            child_store = out.get("store_stats")
            if child_store and self.store is not None:
                # fold a process child's store traffic into the live
                # handle so stats_dict() covers the whole sweep
                for k in ("hits", "misses", "puts", "disk_loaded",
                          "corrupt", "evicted", "stale_tmps"):
                    setattr(self.store, k,
                            getattr(self.store, k) + child_store.get(k, 0))
            straggler = self.meter.note(wall)
            if straggler:
                log.warning("%s straggled: %.2fs (avg %.2fs)",
                            label, wall, self.meter.avg())
            self.group_wall.append({
                "group": g.index,
                "tasks": len(g.tasks),
                "wall_s": round(wall, 4),
                "straggler": straggler,
                "replayed": False,
            })
            if self._flush_store_per_group and self.store is not None:
                # serial mode: persist this group's Costs before its
                # journal record, so a crash loses at most bookkeeping,
                # never scored work (thread mode defers to the end-of-
                # sweep flush -- other groups are mutating the shared
                # store concurrently; process children flush their own
                # handles at group end)
                self.store.flush()
            self._completed += 1
            if (
                self.fault.kill_after is not None
                and self._completed >= self.fault.kill_after
            ):
                # resume smoke: die in the WORST crash window -- the Nth
                # group's Costs are on disk but its journal record is
                # not, so a resumed sweep replays N-1 groups and re-runs
                # this one warm against the store
                log.warning("kill-after:%d reached -- SIGKILL",
                            self.fault.kill_after)
                os.kill(os.getpid(), signal.SIGKILL)
            if self.journal is not None:
                self.journal.record_group(g.gkey, out["records"])
        return out

    # -------------------------------------------------------------- #
    def run(self, resolved: Sequence[tuple]) -> Tuple[List[SearchResult], dict]:
        """Execute the sweep over ``resolved`` tasks (see
        :func:`build_groups` for the tuple shape). Returns per-task
        :class:`SearchResult`s in task order plus the aggregate stats
        dict ``union_opt_sweep`` reports."""
        groups = self.build_groups(
            resolved,
            engine_backend=self.engine_backend,
            engine_prune=self.engine_prune,
        )

        replayed: List[_Group] = []
        pending: List[_Group] = []
        for g in groups:
            if (
                self.journal is not None
                and self.journal.group_done(g.gkey)
                and all(
                    self.journal.get_task(t["fingerprint"]) is not None
                    for t in g.tasks
                )
            ):
                replayed.append(g)
            else:
                pending.append(g)
        if replayed:
            log.warning(
                "resume: replaying %d/%d journaled group(s), re-running %d",
                len(replayed), len(groups), len(pending),
            )

        mode = self._mode()
        self._flush_store_per_group = mode == "serial"
        outputs: Dict[int, dict] = {}
        pool = None
        driver = None
        try:
            if mode == "process" and pending:
                import multiprocessing as mp_mod

                pool = cf.ProcessPoolExecutor(
                    max_workers=self.workers,
                    mp_context=mp_mod.get_context("spawn"),
                )
                # a non-picklable group (caller-built mapper holding a
                # lambda, say) falls back to in-process execution rather
                # than failing the sweep
                inproc = []
                for g in pending:
                    try:
                        pickle.dumps(self._payload(g, 0, True))
                    except Exception as e:  # noqa: BLE001
                        log.warning(
                            "group%d payload not picklable (%s); running "
                            "in-process", g.index, type(e).__name__)
                        inproc.append(g)
                procable = [g for g in pending if g not in inproc]
                driver = cf.ThreadPoolExecutor(
                    max_workers=self.workers, thread_name_prefix="sweepdrv"
                )
                futs = {
                    driver.submit(self._dispatch, g, pool): g for g in procable
                }
                for g in inproc:
                    outputs[g.index] = self._dispatch(g, None)
                for f, g in futs.items():
                    outputs[g.index] = f.result()
            elif mode == "thread" and pending:
                driver = cf.ThreadPoolExecutor(
                    max_workers=self.workers, thread_name_prefix="sweepdrv"
                )
                futs = {
                    driver.submit(self._dispatch, g, None): g for g in pending
                }
                for f, g in futs.items():
                    outputs[g.index] = f.result()
            else:
                for g in pending:
                    outputs[g.index] = self._dispatch(g, None)
        finally:
            if driver is not None:
                driver.shutdown(wait=False, cancel_futures=True)
            if pool is not None:
                pool.shutdown(wait=False, cancel_futures=True)
            if self.store is not None:
                # flush even when a group ultimately fails: completed
                # groups' fresh Costs persist (flushing is not destructive)
                self.store.flush()

        # ---- assemble per-task results (everything via the record form)
        n_tasks = sum(len(g.tasks) for g in groups)
        results: List[Optional[SearchResult]] = [None] * n_tasks
        warmed = 0
        backend_fallbacks = 0
        for g in groups:
            if g in replayed:
                with self._lock:
                    self.group_wall.append({
                        "group": g.index, "tasks": len(g.tasks),
                        "wall_s": 0.0, "straggler": False, "replayed": True,
                    })
                recs = {
                    t["fingerprint"]: self.journal.get_task(t["fingerprint"])
                    for t in g.tasks
                }
            else:
                out = outputs[g.index]
                warmed += out["warmed"]
                backend_fallbacks += out["backend_fallbacks"]
                recs = out["records"]
            for slot, t in zip(g.task_slots, g.tasks):
                results[slot] = result_from_record(recs[t["fingerprint"]])

        agg = self._aggregate(results, groups, replayed, warmed,
                              backend_fallbacks, mode)
        return results, agg  # type: ignore[return-value]

    # -------------------------------------------------------------- #
    def _aggregate(self, results, groups, replayed, warmed,
                   backend_fallbacks, mode) -> dict:
        self.group_wall.sort(key=lambda r: r["group"])
        if os.environ.get("UNION_DETERMINISTIC_STATS"):
            # warm/cold-invariant subset only (see SearchResult.stats_dict)
            return {
                "tasks": len(results),
                "engines": len(groups),
                "engine_backend": self.engine_backend,
                "considered": sum(r.considered for r in results),
                "backend_fallbacks": backend_fallbacks,
                "elapsed_s": 0.0,
                "evals_per_s": 0.0,
            }
        agg = {
            "tasks": len(results),
            "engines": len(groups),
            "engine_backend": self.engine_backend,
            "warmed_buckets": warmed,
            "considered": sum(r.considered for r in results),
            "analyzed": sum(r.analyzed for r in results),
            "cache_hits": sum(r.cache_hits for r in results),
            "store_hits": sum(r.store_hits for r in results),
            "pruned": sum(r.pruned for r in results),
            "fused_dispatches": sum(r.fused_dispatches for r in results),
            "n_traces": sum(r.n_traces for r in results),
            "device_syncs": sum(r.device_syncs for r in results),
            "elapsed_s": round(sum(r.elapsed_s for r in results), 4),
            # robustness ledger
            "workers": self.workers,
            "pool": mode,
            "attempts": self.retry_stats.attempts,
            "retries": self.retry_stats.retries,
            "timeouts": self.retry_stats.timeouts,
            "backend_fallbacks": backend_fallbacks,
            "stragglers": self.meter.flagged,
            "replayed_groups": len(replayed),
            "group_wall": list(self.group_wall),
        }
        if self.journal is not None:
            agg["journal"] = self.journal.stats_dict()
        scored = sum(r.scored for r in results)
        agg["evals_per_s"] = (
            round(scored / agg["elapsed_s"], 1) if agg["elapsed_s"] > 0 else 0.0
        )
        return agg
