"""Union-opt (paper Sec. III-B): the end-to-end mapping optimizer.

Given a problem (or a LayerOp to be lowered), a target architecture, a
constraint file, a mapper choice and a cost-model choice, Union-opt:

  1. runs the conformability pass for the chosen cost model,
  2. builds the map-space,
  3. searches it with the chosen mapper,
  4. returns the best Union mapping + cost (+ the loop-nest rendering,
     Fig. 5(e)/Fig. 9 style).

This is the single entry point used by the case-study benchmarks AND by
the sharding auto-tuner (repro/sharding/auto.py) that turns mappings into
PartitionSpecs/BlockSpecs -- the co-design loop closure.

:func:`union_opt_sweep` is the MULTI-SEARCH form figure runs go through:
a list of :class:`SweepTask` points shares one
:class:`~repro.core.cost.engine.EvaluationEngine` per distinct
(cost model, problem, arch, metric) space -- memo cache, compiled array
programs and fused jitted runners included -- plus one optional
:class:`ResultStore` and a bucketed jax warmup pass, so retraces and
repeated scoring amortize across the whole sweep instead of per call.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union as TUnion

from repro.core.architecture import Architecture
from repro.core.constraints import Constraints
from repro.core.cost import MaestroLikeModel, TimeloopLikeModel, TPURooflineModel
from repro.core.cost.base import Cost, CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.cost.store import ResultStore
from repro.core.ir.conformability import conformable_models
from repro.core.ir.dialects import LayerOp
from repro.core.ir.lowering import lower_layer_to_problem
from repro.core.mappers import MAPPER_REGISTRY, Mapper
from repro.core.mappers.base import SearchResult
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace
from repro.core.problem import Problem

COST_MODEL_REGISTRY = {
    "timeloop": TimeloopLikeModel,
    "maestro": MaestroLikeModel,
    "tpu_roofline": TPURooflineModel,
}


@dataclass
class UnionSolution:
    problem: Problem
    mapping: Mapping
    cost: Cost
    search: SearchResult
    mapper: str
    cost_model: str
    metric: str

    def loop_nest(self) -> str:
        return self.mapping.loop_nest_str(self.problem)


def union_opt(
    workload: TUnion[Problem, LayerOp],
    arch: Architecture,
    mapper: TUnion[str, Mapper] = "heuristic",
    cost_model: TUnion[str, CostModel] = "timeloop",
    metric: str = "edp",
    constraints: Optional[Constraints] = None,
    engine_workers: int = 0,
    engine_cache: int = 1 << 16,
    engine_prune: bool = True,
    engine_backend: Optional[str] = "numpy",
    result_store: Optional[ResultStore] = None,
    **mapper_kw,
) -> UnionSolution:
    """Run one end-to-end mapping search.

    ``engine_workers`` / ``engine_cache`` / ``engine_prune`` /
    ``engine_backend`` configure the shared :class:`EvaluationEngine` all
    mappers score candidates through (process-pool fan-out, memo-cache
    capacity, lower-bound admission, and the vectorized miss-batch
    backend: "numpy" default, anything else for the per-candidate scalar
    path). ``engine_backend="jax"`` runs the SINGLE-DISPATCH fused
    pipeline: one jitted program per miss-batch covers stack ->
    lower-bound -> admit mask -> traffic -> energy on device, returning
    only per-candidate ``(cycles, energy_pj, util)`` scalars (plus small
    breakdown arrays) to host, with Cost objects materialized for
    admitted rows only -- costs, decisions, and counters bit-identical to
    the numpy and scalar paths. The compiled program is cached on the
    (problem, arch) analysis context, so repeated ``union_opt`` calls
    over the same space reuse it. ``result_store`` is an optional
    persistent cross-search cache shared between calls (see
    ``repro.core.cost.store.ResultStore``; construct it with
    ``max_entries_per_space=`` for LRU-capped tiers): benchmark sweeps
    pass one store so identical signatures are scored once across runs;
    callers own ``flush()``.
    """
    problem = (
        lower_layer_to_problem(workload) if isinstance(workload, LayerOp) else workload
    )
    cm = (
        COST_MODEL_REGISTRY[cost_model]() if isinstance(cost_model, str) else cost_model
    )
    rep = conformable_models(problem, [cm])
    ok, why = rep.results.get(cm.name, (cm.conformable(problem), "model check"))
    if not ok:
        raise ValueError(
            f"problem {problem.name!r} is not conformable to cost model "
            f"{cm.name!r}: {why}"
        )
    mp = MAPPER_REGISTRY[mapper](**mapper_kw) if isinstance(mapper, str) else mapper
    space = MapSpace(problem, arch, constraints)
    engine = EvaluationEngine(
        cm,
        problem,
        arch,
        metric=metric,
        cache_size=engine_cache,
        prune=engine_prune,
        workers=engine_workers,
        backend=engine_backend,
        store=result_store,
    )
    try:
        res = mp.search(space, cm, metric, engine=engine)
    finally:
        engine.close()
    if res.best_mapping is None:
        raise RuntimeError(f"mapper {mp.name} found no legal mapping for {problem.name}")
    return UnionSolution(
        problem=problem,
        mapping=res.best_mapping,
        cost=res.best_cost,
        search=res,
        mapper=mp.name,
        cost_model=cm.name,
        metric=metric,
    )


# --------------------------------------------------------------------- #
# Multi-problem fused sweeps
# --------------------------------------------------------------------- #
@dataclass
class SweepTask:
    """One point of a :func:`union_opt_sweep`: the same knobs one
    ``union_opt`` call takes, as data. ``tag`` is an opaque caller label:
    solutions come back in task order, so callers recover it by zipping
    tasks with the result (``zip(tasks, sweep)`` -- how the figure
    benchmarks key their tables)."""

    workload: "TUnion[Problem, LayerOp]"
    arch: Architecture
    mapper: "TUnion[str, Mapper]" = "heuristic"
    cost_model: "TUnion[str, CostModel]" = "timeloop"
    metric: str = "edp"
    constraints: Optional[Constraints] = None
    mapper_kw: dict = field(default_factory=dict)
    tag: Optional[object] = None


@dataclass
class SweepResult:
    """Solutions (in task order) + sweep-level sharing/throughput stats."""

    solutions: List[UnionSolution]
    stats: dict

    def __iter__(self):
        return iter(self.solutions)

    def __getitem__(self, i):
        return self.solutions[i]

    def __len__(self):
        return len(self.solutions)


def union_opt_sweep(
    tasks: Sequence["TUnion[SweepTask, dict]"],
    *,
    engine_backend: Optional[str] = "numpy",
    engine_workers: int = 0,
    engine_cache: int = 1 << 16,
    engine_prune: bool = True,
    result_store: Optional[ResultStore] = None,
    warmup: bool = True,
) -> SweepResult:
    """Run a whole figure sweep through SHARED evaluation machinery.

    Tasks are grouped by their persistent-store space key -- the digest of
    (cost model config, problem content, arch content) -- plus metric and
    backend, and each group shares ONE :class:`EvaluationEngine`: its memo
    cache carries results between that group's searches (e.g. fig8 scores
    each problem with a heuristic AND a random mapper -- the second search
    starts warm), and its compiled array programs / fused jitted runners
    are built once. Content-equal problems and archs from different
    constructor calls alias the same analysis context (see
    ``get_context``), so even cross-group tasks reuse traced programs
    where shapes and constants agree. Per-task ``SearchResult`` counters
    stay per-search (the tracker diffs engine snapshots).

    ``warmup=True`` pre-traces each group's fused jax runner at the pow2
    buckets its mappers' ``batch_hints`` pad to (no-op on numpy/scalar
    backends), so first-batch retrace stalls disappear from the timed
    searches' ``admit_s``/``score_s``.

    ``result_store`` is shared by every task and flushed ONCE at the end
    (one atomic multi-space write pass; see ``ResultStore.flush``) --
    callers that keep the store open may flush again later, flushing here
    is not destructive.
    """
    from repro.core.cost.store import space_key as _space_key

    resolved = []
    for t in tasks:
        if isinstance(t, dict):
            t = SweepTask(**t)
        problem = (
            lower_layer_to_problem(t.workload)
            if isinstance(t.workload, LayerOp)
            else t.workload
        )
        cm = (
            COST_MODEL_REGISTRY[t.cost_model]()
            if isinstance(t.cost_model, str)
            else t.cost_model
        )
        rep = conformable_models(problem, [cm])
        ok, why = rep.results.get(cm.name, (cm.conformable(problem), "model check"))
        if not ok:
            raise ValueError(
                f"problem {problem.name!r} is not conformable to cost model "
                f"{cm.name!r}: {why}"
            )
        mp = (
            MAPPER_REGISTRY[t.mapper](**t.mapper_kw)
            if isinstance(t.mapper, str)
            else t.mapper
        )
        resolved.append((t, problem, cm, mp))

    engines: Dict[object, tuple] = {}
    solutions: List[UnionSolution] = []
    warmed = 0
    try:
        for t, problem, cm, mp in resolved:
            gkey = (
                _space_key(cm, problem, t.arch),
                t.metric,
                engine_backend,
                engine_prune,
            )
            ent = engines.get(gkey)
            if ent is None:
                engine = EvaluationEngine(
                    cm,
                    problem,
                    t.arch,
                    metric=t.metric,
                    cache_size=engine_cache,
                    prune=engine_prune,
                    workers=engine_workers,
                    backend=engine_backend,
                    store=result_store,
                )
                engines[gkey] = ent = (engine, problem, t.arch)
            engine, gproblem, garch = ent
            if warmup:
                # idempotent per bucket: already-traced sizes re-dispatch
                # in microseconds
                warmed += engine.warmup(mp.batch_hints())
            # the search runs over the group's canonical objects (their
            # content is identical by the space key), but the solution
            # keeps the TASK's own problem identity -- space_key excludes
            # names, so content-equal workloads with different names must
            # not swap identities
            space = MapSpace(gproblem, garch, t.constraints)
            res = mp.search(space, engine.cost_model, t.metric, engine=engine)
            if res.best_mapping is None:
                raise RuntimeError(
                    f"mapper {mp.name} found no legal mapping for {problem.name}"
                )
            solutions.append(
                UnionSolution(
                    problem=problem,
                    mapping=res.best_mapping,
                    cost=res.best_cost,
                    search=res,
                    mapper=mp.name,
                    cost_model=engine.cost_model.name,
                    metric=t.metric,
                )
            )
    finally:
        for engine, _p, _a in engines.values():
            engine.close()
        if result_store is not None:
            # flush even when a task raises: every completed task's fresh
            # Costs persist (flushing is never destructive)
            result_store.flush()
    agg = {
        "tasks": len(solutions),
        "engines": len(engines),
        "engine_backend": engine_backend,
        "warmed_buckets": warmed,
        "considered": sum(s.search.considered for s in solutions),
        "analyzed": sum(s.search.analyzed for s in solutions),
        "cache_hits": sum(s.search.cache_hits for s in solutions),
        "store_hits": sum(s.search.store_hits for s in solutions),
        "pruned": sum(s.search.pruned for s in solutions),
        "fused_dispatches": sum(s.search.fused_dispatches for s in solutions),
        "elapsed_s": round(sum(s.search.elapsed_s for s in solutions), 4),
    }
    scored = sum(s.search.scored for s in solutions)
    agg["evals_per_s"] = (
        round(scored / agg["elapsed_s"], 1) if agg["elapsed_s"] > 0 else 0.0
    )
    return SweepResult(solutions, agg)
