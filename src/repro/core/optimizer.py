"""Union-opt (paper Sec. III-B): the end-to-end mapping optimizer.

Given a problem (or a LayerOp to be lowered), a target architecture, a
constraint file, a mapper choice and a cost-model choice, Union-opt:

  1. runs the conformability pass for the chosen cost model,
  2. builds the map-space,
  3. searches it with the chosen mapper,
  4. returns the best Union mapping + cost (+ the loop-nest rendering,
     Fig. 5(e)/Fig. 9 style).

This is the single entry point used by the case-study benchmarks AND by
the sharding auto-tuner (repro/sharding/auto.py) that turns mappings into
PartitionSpecs/BlockSpecs -- the co-design loop closure.

:func:`union_opt_sweep` is the MULTI-SEARCH form figure runs go through:
a list of :class:`SweepTask` points shares one
:class:`~repro.core.cost.engine.EvaluationEngine` per distinct
(cost model, problem, arch, metric) space -- memo cache, compiled array
programs and fused jitted runners included -- plus one optional
:class:`ResultStore` and a bucketed jax warmup pass, so retraces and
repeated scoring amortize across the whole sweep instead of per call.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union as TUnion

from repro.core.architecture import Architecture
from repro.core.constraints import Constraints
from repro.core.cost import MaestroLikeModel, TimeloopLikeModel, TPURooflineModel
from repro.core.cost.base import Cost, CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.cost.store import ResultStore
from repro.core.ir.conformability import conformable_models
from repro.core.ir.dialects import LayerOp
from repro.core.ir.lowering import lower_layer_to_problem
from repro.core.mappers import MAPPER_REGISTRY, Mapper
from repro.core.mappers.base import SearchResult
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace
from repro.core.problem import Problem

COST_MODEL_REGISTRY = {
    "timeloop": TimeloopLikeModel,
    "maestro": MaestroLikeModel,
    "tpu_roofline": TPURooflineModel,
}


@dataclass
class UnionSolution:
    problem: Problem
    mapping: Mapping
    cost: Cost
    search: SearchResult
    mapper: str
    cost_model: str
    metric: str

    def loop_nest(self) -> str:
        return self.mapping.loop_nest_str(self.problem)


def union_opt(
    workload: TUnion[Problem, LayerOp],
    arch: Architecture,
    mapper: TUnion[str, Mapper] = "heuristic",
    cost_model: TUnion[str, CostModel] = "timeloop",
    metric: str = "edp",
    constraints: Optional[Constraints] = None,
    engine_workers: int = 0,
    engine_cache: int = 1 << 16,
    engine_prune: bool = True,
    engine_backend: Optional[str] = "numpy",
    result_store: Optional[ResultStore] = None,
    **mapper_kw,
) -> UnionSolution:
    """Run one end-to-end mapping search.

    ``engine_workers`` / ``engine_cache`` / ``engine_prune`` /
    ``engine_backend`` configure the shared :class:`EvaluationEngine` all
    mappers score candidates through (process-pool fan-out, memo-cache
    capacity, lower-bound admission, and the vectorized miss-batch
    backend: "numpy" default, anything else for the per-candidate scalar
    path). ``engine_backend="jax"`` runs the SINGLE-DISPATCH fused
    pipeline: one jitted program per miss-batch covers stack ->
    lower-bound -> admit mask -> traffic -> energy on device, returning
    only per-candidate ``(cycles, energy_pj, util)`` scalars (plus small
    breakdown arrays) to host, with Cost objects materialized for
    admitted rows only -- costs, decisions, and counters bit-identical to
    the numpy and scalar paths. The compiled program is cached on the
    (problem, arch) analysis context, so repeated ``union_opt`` calls
    over the same space reuse it. ``result_store`` is an optional
    persistent cross-search cache shared between calls (see
    ``repro.core.cost.store.ResultStore``; construct it with
    ``max_entries_per_space=`` for LRU-capped tiers): benchmark sweeps
    pass one store so identical signatures are scored once across runs;
    callers own ``flush()``.
    """
    problem = (
        lower_layer_to_problem(workload) if isinstance(workload, LayerOp) else workload
    )
    cm = (
        COST_MODEL_REGISTRY[cost_model]() if isinstance(cost_model, str) else cost_model
    )
    rep = conformable_models(problem, [cm])
    ok, why = rep.results.get(cm.name, (cm.conformable(problem), "model check"))
    if not ok:
        raise ValueError(
            f"problem {problem.name!r} is not conformable to cost model "
            f"{cm.name!r}: {why}"
        )
    mp = MAPPER_REGISTRY[mapper](**mapper_kw) if isinstance(mapper, str) else mapper
    space = MapSpace(problem, arch, constraints)
    engine = EvaluationEngine(
        cm,
        problem,
        arch,
        metric=metric,
        cache_size=engine_cache,
        prune=engine_prune,
        workers=engine_workers,
        backend=engine_backend,
        store=result_store,
    )
    try:
        res = mp.search(space, cm, metric, engine=engine)
    finally:
        engine.close()
    if res.best_mapping is None:
        raise RuntimeError(f"mapper {mp.name} found no legal mapping for {problem.name}")
    return UnionSolution(
        problem=problem,
        mapping=res.best_mapping,
        cost=res.best_cost,
        search=res,
        mapper=mp.name,
        cost_model=cm.name,
        metric=metric,
    )


# --------------------------------------------------------------------- #
# Multi-problem fused sweeps
# --------------------------------------------------------------------- #
@dataclass
class SweepTask:
    """One point of a :func:`union_opt_sweep`: the same knobs one
    ``union_opt`` call takes, as data. ``tag`` is an opaque caller label:
    solutions come back in task order, so callers recover it by zipping
    tasks with the result (``zip(tasks, sweep)`` -- how the figure
    benchmarks key their tables)."""

    workload: "TUnion[Problem, LayerOp]"
    arch: Architecture
    mapper: "TUnion[str, Mapper]" = "heuristic"
    cost_model: "TUnion[str, CostModel]" = "timeloop"
    metric: str = "edp"
    constraints: Optional[Constraints] = None
    mapper_kw: dict = field(default_factory=dict)
    tag: Optional[object] = None


@dataclass
class SweepResult:
    """Solutions (in task order) + sweep-level sharing/throughput stats."""

    solutions: List[UnionSolution]
    stats: dict

    def __iter__(self):
        return iter(self.solutions)

    def __getitem__(self, i):
        return self.solutions[i]

    def __len__(self):
        return len(self.solutions)


def union_opt_sweep(
    tasks: Sequence["TUnion[SweepTask, dict]"],
    *,
    engine_backend: Optional[str] = "numpy",
    engine_workers: int = 0,
    engine_cache: int = 1 << 16,
    engine_prune: bool = True,
    result_store: Optional[ResultStore] = None,
    warmup: bool = True,
    workers: int = 0,
    pool: str = "auto",
    group_timeout_s: Optional[float] = None,
    max_group_retries: int = 2,
    group_backoff_s: float = 0.05,
    journal=None,
    resume: bool = False,
    fault_spec: Optional[str] = None,
) -> SweepResult:
    """Run a whole figure sweep through SHARED evaluation machinery.

    Tasks are grouped by their persistent-store space key -- the digest of
    (cost model config, problem content, arch content) -- plus metric and
    backend, and each group shares ONE :class:`EvaluationEngine`: its memo
    cache carries results between that group's searches (e.g. fig8 scores
    each problem with a heuristic AND a random mapper -- the second search
    starts warm), and its compiled array programs / fused jitted runners
    are built once. Content-equal problems and archs from different
    constructor calls alias the same analysis context (see
    ``get_context``), so even cross-group tasks reuse traced programs
    where shapes and constants agree. Per-task ``SearchResult`` counters
    stay per-search (the tracker diffs engine snapshots).

    ``warmup=True`` pre-traces each group's fused jax runner at the pow2
    buckets its mappers' ``batch_hints`` pad to (no-op on numpy/scalar
    backends), so first-batch retrace stalls disappear from the timed
    searches' ``admit_s``/``score_s``.

    ``result_store`` is shared by every task and flushed ONCE at the end
    (one atomic multi-space write pass; see ``ResultStore.flush``) --
    callers that keep the store open may flush again later, flushing here
    is not destructive.

    Execution is delegated to the fault-tolerant
    :class:`~repro.core.sweep_exec.SweepExecutor` (see that module for
    the failure taxonomy and ``docs/sweep_service.md`` for the service
    model):

    ``workers``/``pool``
        ``workers > 1`` dispatches independent groups concurrently --
        ``pool="process"`` (the ``"auto"`` default; spawned interpreters,
        the load-bearing path since the numpy engine is GIL-bound) or
        ``pool="thread"``.
    ``group_timeout_s``/``max_group_retries``/``group_backoff_s``
        per-group watchdog deadline and bounded retries with exponential
        backoff + deterministic jitter; a hung or failed group attempt is
        abandoned and re-run instead of killing the sweep.
    ``journal``/``resume``
        a :class:`~repro.core.cost.store.SweepJournal` (or a path) makes
        the sweep crash-safe: completed groups' solution records are
        flushed atomically, and ``resume=True`` replays them instead of
        re-searching. All solutions round-trip through the journal's
        record form either way, so resumed and uninterrupted sweeps are
        identical by construction.
    ``fault_spec``
        deterministic fault injection (defaults to ``UNION_FAULT_SPEC``
        from the environment), e.g. ``"fail:1@0;hang:2@0:3"``.
    """
    from repro.core.sweep_exec import SweepExecutor

    resolved = []
    for t in tasks:
        if isinstance(t, dict):
            t = SweepTask(**t)
        problem = (
            lower_layer_to_problem(t.workload)
            if isinstance(t.workload, LayerOp)
            else t.workload
        )
        cm = (
            COST_MODEL_REGISTRY[t.cost_model]()
            if isinstance(t.cost_model, str)
            else t.cost_model
        )
        rep = conformable_models(problem, [cm])
        ok, why = rep.results.get(cm.name, (cm.conformable(problem), "model check"))
        if not ok:
            raise ValueError(
                f"problem {problem.name!r} is not conformable to cost model "
                f"{cm.name!r}: {why}"
            )
        if isinstance(t.mapper, str):
            # fail fast on unknown mappers / bad kwargs, then ship the SPEC:
            # the executor builds a FRESH instance per group attempt so a
            # retried group replays the exact seeded candidate stream
            mp_name = MAPPER_REGISTRY[t.mapper](**t.mapper_kw).name
            mapper_spec = (t.mapper, dict(t.mapper_kw))
        else:
            mp_name = t.mapper.name
            mapper_spec = t.mapper
        resolved.append((t, problem, cm, mapper_spec))
        t.__dict__["_mapper_name"] = mp_name  # for solution labeling below

    executor = SweepExecutor(
        engine_backend=engine_backend,
        engine_workers=engine_workers,
        engine_cache=engine_cache,
        engine_prune=engine_prune,
        result_store=result_store,
        warmup=warmup,
        workers=workers,
        pool=pool,
        group_timeout_s=group_timeout_s,
        max_group_retries=max_group_retries,
        group_backoff_s=group_backoff_s,
        journal=journal,
        resume=resume,
        fault_spec=fault_spec,
    )
    results, agg = executor.run(resolved)

    solutions = [
        UnionSolution(
            problem=problem,
            mapping=res.best_mapping,
            cost=res.best_cost,
            search=res,
            mapper=t.__dict__["_mapper_name"],
            cost_model=cm.name,
            metric=t.metric,
        )
        for (t, problem, cm, _spec), res in zip(resolved, results)
    ]
    return SweepResult(solutions, agg)
