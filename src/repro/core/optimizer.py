"""Union-opt (paper Sec. III-B): the end-to-end mapping optimizer.

Given a problem (or a LayerOp to be lowered), a target architecture, a
constraint file, a mapper choice and a cost-model choice, Union-opt:

  1. runs the conformability pass for the chosen cost model,
  2. builds the map-space,
  3. searches it with the chosen mapper,
  4. returns the best Union mapping + cost (+ the loop-nest rendering,
     Fig. 5(e)/Fig. 9 style).

This is the single entry point used by the case-study benchmarks AND by
the sharding auto-tuner (repro/sharding/auto.py) that turns mappings into
PartitionSpecs/BlockSpecs -- the co-design loop closure.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union as TUnion

from repro.core.architecture import Architecture
from repro.core.constraints import Constraints
from repro.core.cost import MaestroLikeModel, TimeloopLikeModel, TPURooflineModel
from repro.core.cost.base import Cost, CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.cost.store import ResultStore
from repro.core.ir.conformability import conformable_models
from repro.core.ir.dialects import LayerOp
from repro.core.ir.lowering import lower_layer_to_problem
from repro.core.mappers import MAPPER_REGISTRY, Mapper
from repro.core.mappers.base import SearchResult
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace
from repro.core.problem import Problem

COST_MODEL_REGISTRY = {
    "timeloop": TimeloopLikeModel,
    "maestro": MaestroLikeModel,
    "tpu_roofline": TPURooflineModel,
}


@dataclass
class UnionSolution:
    problem: Problem
    mapping: Mapping
    cost: Cost
    search: SearchResult
    mapper: str
    cost_model: str
    metric: str

    def loop_nest(self) -> str:
        return self.mapping.loop_nest_str(self.problem)


def union_opt(
    workload: TUnion[Problem, LayerOp],
    arch: Architecture,
    mapper: TUnion[str, Mapper] = "heuristic",
    cost_model: TUnion[str, CostModel] = "timeloop",
    metric: str = "edp",
    constraints: Optional[Constraints] = None,
    engine_workers: int = 0,
    engine_cache: int = 1 << 16,
    engine_prune: bool = True,
    engine_backend: Optional[str] = "numpy",
    result_store: Optional[ResultStore] = None,
    **mapper_kw,
) -> UnionSolution:
    """Run one end-to-end mapping search.

    ``engine_workers`` / ``engine_cache`` / ``engine_prune`` /
    ``engine_backend`` configure the shared :class:`EvaluationEngine` all
    mappers score candidates through (process-pool fan-out, memo-cache
    capacity, lower-bound admission, and the vectorized miss-batch
    backend: "numpy" default, anything else for the per-candidate scalar
    path). ``engine_backend="jax"`` runs the SINGLE-DISPATCH fused
    pipeline: one jitted program per miss-batch covers stack ->
    lower-bound -> admit mask -> traffic -> energy on device, returning
    only per-candidate ``(cycles, energy_pj, util)`` scalars (plus small
    breakdown arrays) to host, with Cost objects materialized for
    admitted rows only -- costs, decisions, and counters bit-identical to
    the numpy and scalar paths. The compiled program is cached on the
    (problem, arch) analysis context, so repeated ``union_opt`` calls
    over the same space reuse it. ``result_store`` is an optional
    persistent cross-search cache shared between calls (see
    ``repro.core.cost.store.ResultStore``; construct it with
    ``max_entries_per_space=`` for LRU-capped tiers): benchmark sweeps
    pass one store so identical signatures are scored once across runs;
    callers own ``flush()``.
    """
    problem = (
        lower_layer_to_problem(workload) if isinstance(workload, LayerOp) else workload
    )
    cm = (
        COST_MODEL_REGISTRY[cost_model]() if isinstance(cost_model, str) else cost_model
    )
    rep = conformable_models(problem, [cm])
    ok, why = rep.results.get(cm.name, (cm.conformable(problem), "model check"))
    if not ok:
        raise ValueError(
            f"problem {problem.name!r} is not conformable to cost model "
            f"{cm.name!r}: {why}"
        )
    mp = MAPPER_REGISTRY[mapper](**mapper_kw) if isinstance(mapper, str) else mapper
    space = MapSpace(problem, arch, constraints)
    engine = EvaluationEngine(
        cm,
        problem,
        arch,
        metric=metric,
        cache_size=engine_cache,
        prune=engine_prune,
        workers=engine_workers,
        backend=engine_backend,
        store=result_store,
    )
    try:
        res = mp.search(space, cm, metric, engine=engine)
    finally:
        engine.close()
    if res.best_mapping is None:
        raise RuntimeError(f"mapper {mp.name} found no legal mapping for {problem.name}")
    return UnionSolution(
        problem=problem,
        mapping=res.best_mapping,
        cost=res.best_cost,
        search=res,
        mapper=mp.name,
        cost_model=cm.name,
        metric=metric,
    )
