"""Union architecture abstraction (paper Sec. IV-C).

A *logical cluster-target* hierarchical description: the architecture is a
chain of cluster levels ``C_n (outermost) ... C_1 (innermost)``.  Each level
has:

  * ``memory_bytes``     -- local memory capacity (None when ``virtual``),
  * ``virtual``          -- paper's Virtual attribute: no dedicated physical
                            memory at this level (an "imaginary" buffer used
                            only to express intermediate tiling),
  * ``fanout``           -- number of sub-cluster instances,
  * ``dimension``        -- paper's Dimension attribute: physical axis along
                            which the sub-clusters are laid out ('X', 'Y',
                            or a mesh-axis name like 'pod'/'data'/'model'),
  * ``fill_bandwidth``   -- bytes/s from the parent level into this level,
  * ``read_energy/write_energy`` -- pJ per byte (Accelergy-style),
  * leaf compute: ``macs_per_cycle`` + ``mac_energy``.

The same abstraction describes the paper's edge/cloud/chiplet accelerators
AND a multi-pod TPU system (pods -> chips -> Pallas grid -> VMEM/MXU); see
``tpu_v5e_pod`` below, which is what closes the co-design loop in this repo.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Cluster:
    """One cluster level. Levels are indexed outermost=highest."""

    name: str
    fanout: int = 1
    dimension: str = "X"  # physical layout axis of the sub-clusters
    memory_bytes: Optional[int] = None  # None => virtual level
    fill_bandwidth: float = float("inf")  # bytes/sec from parent into this level
    read_energy: float = 0.0  # pJ / byte
    write_energy: float = 0.0  # pJ / byte
    # leaf compute (only meaningful for the innermost cluster)
    macs_per_cycle: int = 0
    mac_energy: float = 0.0  # pJ / MAC

    @property
    def virtual(self) -> bool:
        return self.memory_bytes is None

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        mem = "virtual" if self.virtual else f"{self.memory_bytes}B"
        return f"Cluster({self.name}, fanout={self.fanout}@{self.dimension}, {mem})"


@dataclass
class Architecture:
    """A chain of cluster levels, outermost first.

    ``clusters[0]`` is C_n (e.g. DRAM/host), ``clusters[-1]`` is C_1 (the PE
    with its L1 + MAC). The physical PE count is the product of fanouts.
    """

    name: str
    clusters: List[Cluster]
    frequency_hz: float = 1e9
    attrs: Dict[str, object] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.clusters:
            raise ValueError("architecture needs at least one cluster level")
        if self.clusters[-1].macs_per_cycle <= 0:
            raise ValueError("innermost cluster must have compute (macs_per_cycle>0)")

    # ---------------------------------------------------------------- #
    @property
    def n_levels(self) -> int:
        return len(self.clusters)

    def level(self, i: int) -> Cluster:
        """Paper-style index: C_n ... C_1 with n = n_levels. level(1) is innermost."""
        return self.clusters[self.n_levels - i]

    @property
    def num_pes(self) -> int:
        return math.prod(c.fanout for c in self.clusters)

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.num_pes * self.clusters[-1].macs_per_cycle

    def fanout_below(self, idx: int) -> int:
        """Product of fanouts strictly inside clusters[idx]."""
        return math.prod(c.fanout for c in self.clusters[idx + 1 :]) if idx + 1 < self.n_levels else 1

    def with_aspect_ratio(self, shape: Sequence[int], names: Optional[Sequence[str]] = None) -> "Architecture":
        """Re-cluster the spatial fanout into the given aspect ratio.

        Used by the paper's Fig. 10 case study: a flexible accelerator
        (MAERI/Eyeriss_v2-like) reconfigures its PE array into e.g. 1x2048,
        32x64, ... We rebuild the sub-PE cluster levels accordingly,
        inserting virtual levels for each spatial axis.
        """
        total = math.prod(shape)
        if total != self.num_pes:
            raise ValueError(f"aspect ratio {shape} != {self.num_pes} PEs")
        outer = [c for c in self.clusters if c.fanout == 1 and c.memory_bytes is not None]
        if not outer:
            raise ValueError("expected at least one non-spatial outer level")
        pe = self.clusters[-1]
        new: List[Cluster] = list(outer[:-1])
        shared = outer[-1]
        new.append(shared)
        names = names or [("Y" if i % 2 == 0 else "X") for i in range(len(shape))]
        for i, (f, ax) in enumerate(zip(shape[:-1], names[:-1])):
            new.append(Cluster(f"V{len(shape)-1-i}", fanout=int(f), dimension=ax, memory_bytes=None))
        new.append(replace(pe, fanout=int(shape[-1]), dimension=names[-1]))
        return Architecture(f"{self.name}_ar{'x'.join(map(str, shape))}", new, self.frequency_hz, dict(self.attrs))

    def describe(self) -> str:
        lines = [f"Architecture {self.name} ({self.num_pes} PEs @ {self.frequency_hz/1e9:g} GHz)"]
        for i, c in enumerate(self.clusters):
            lvl = self.n_levels - i
            mem = "virtual" if c.virtual else f"{c.memory_bytes:,} B"
            bw = "" if math.isinf(c.fill_bandwidth) else f", fill {c.fill_bandwidth/1e9:g} GB/s"
            comp = f", {c.macs_per_cycle} MAC/cyc" if c.macs_per_cycle else ""
            lines.append(f"  C{lvl} {c.name}: fanout {c.fanout} along {c.dimension}, {mem}{bw}{comp}")
        return "\n".join(lines)


# ---------------------------------------------------------------------- #
# Presets: the paper's accelerators (Table V) and the TPU target
# ---------------------------------------------------------------------- #

# Accelergy-style energy constants (pJ/byte; relative magnitudes follow the
# usual 45nm tables used by Timeloop+Accelergy and Eyeriss).
_E_DRAM = 64.0
_E_L2 = 4.0
_E_L1 = 0.5
_E_MAC_UINT8 = 0.2  # pJ per uint8 MAC (paper case studies use uint8 units)


def edge_accelerator(aspect: Tuple[int, int] = (16, 16), word_bytes: int = 1) -> Architecture:
    """Paper Table V 'Edge': 256 PEs, 0.5KB L1, 100KB L2, 32 GB/s NoC."""
    y, x = aspect
    assert y * x == 256, "edge accelerator has 256 PEs"
    return Architecture(
        "edge",
        [
            Cluster("DRAM", 1, "X", memory_bytes=1 << 40, fill_bandwidth=float("inf"),
                    read_energy=_E_DRAM, write_energy=_E_DRAM),
            Cluster("L2", 1, "X", memory_bytes=100 * 1024, fill_bandwidth=32e9,
                    read_energy=_E_L2, write_energy=_E_L2),
            Cluster("V2", y, "Y", memory_bytes=None),
            Cluster("PE", x, "X", memory_bytes=512, fill_bandwidth=32e9 / 256,
                    read_energy=_E_L1, write_energy=_E_L1,
                    macs_per_cycle=1, mac_energy=_E_MAC_UINT8),
        ],
        frequency_hz=1e9,
        attrs={"word_bytes": word_bytes},
    )


def cloud_accelerator(aspect: Tuple[int, int] = (32, 64), word_bytes: int = 1) -> Architecture:
    """Paper Table V 'Cloud': 2048 PEs, 0.5KB L1, 800KB L2, 256 GB/s NoC."""
    y, x = aspect
    assert y * x == 2048, "cloud accelerator has 2048 PEs"
    return Architecture(
        "cloud",
        [
            Cluster("DRAM", 1, "X", memory_bytes=1 << 40, fill_bandwidth=float("inf"),
                    read_energy=_E_DRAM, write_energy=_E_DRAM),
            Cluster("L2", 1, "X", memory_bytes=800 * 1024, fill_bandwidth=256e9,
                    read_energy=_E_L2, write_energy=_E_L2),
            Cluster("V2", y, "Y", memory_bytes=None),
            Cluster("PE", x, "X", memory_bytes=512, fill_bandwidth=256e9 / 2048,
                    read_energy=_E_L1, write_energy=_E_L1,
                    macs_per_cycle=1, mac_energy=_E_MAC_UINT8),
        ],
        frequency_hz=1e9,
        attrs={"word_bytes": word_bytes},
    )


def chiplet_accelerator(n_chiplets: int = 16, fill_bandwidth: float = 8e9) -> Architecture:
    """Paper Fig. 11 (Simba-like): 16 chiplets x edge config = 4096 PEs.

    ``fill_bandwidth`` is the DRAM -> per-chiplet global-buffer bandwidth;
    the case study sweeps it. Package-level traffic pays a higher energy.
    """
    return Architecture(
        f"chiplet{n_chiplets}",
        [
            Cluster("DRAM", 1, "X", memory_bytes=1 << 40,
                    read_energy=_E_DRAM, write_energy=_E_DRAM),
            Cluster("Package", n_chiplets, "Y", memory_bytes=None),
            Cluster("ChipletGB", 1, "X", memory_bytes=100 * 1024,
                    fill_bandwidth=fill_bandwidth,
                    read_energy=_E_L2 * 2.5, write_energy=_E_L2 * 2.5),
            Cluster("V2", 16, "Y", memory_bytes=None),
            Cluster("PE", 16, "X", memory_bytes=512, fill_bandwidth=32e9 / 256,
                    read_energy=_E_L1, write_energy=_E_L1,
                    macs_per_cycle=1, mac_energy=_E_MAC_UINT8),
        ],
        frequency_hz=1e9,
        attrs={"inter_chiplet": True},
    )


# TPU v5e constants (per chip)
TPU_V5E = {
    "peak_bf16_flops": 197e12,
    "hbm_bytes": 16 * (1 << 30),
    "hbm_bw": 819e9,
    "ici_link_bw": 50e9,  # ~50 GB/s per link
    "vmem_bytes": 64 * (1 << 20),  # budgeted usable VMEM for one kernel pipeline
    "mxu": 128,  # systolic array dim
}


def tpu_chip(vmem_tile_budget: int = 16 * (1 << 20)) -> Architecture:
    """A single TPU v5e chip as a 3-level Union cluster hierarchy:
    C3 HBM -> C2 virtual grid-step (the Pallas grid) -> C1 VMEM+MXU.

    This is the architecture the kernel tile-planner maps Problems onto;
    legality rule R3 at C1 guarantees the chosen temporal tile fits the
    VMEM budget, so every legal mapping is a valid BlockSpec.
    """
    mxu = TPU_V5E["mxu"]
    macs_per_cycle = mxu * mxu * 4  # 4 MXUs per chip
    freq = TPU_V5E["peak_bf16_flops"] / (2 * macs_per_cycle)
    return Architecture(
        "tpu_chip",
        [
            Cluster("HBM", 1, "X", memory_bytes=TPU_V5E["hbm_bytes"],
                    fill_bandwidth=TPU_V5E["ici_link_bw"],
                    read_energy=7.0, write_energy=7.0),
            Cluster("GridStep", 1, "X", memory_bytes=None),
            Cluster("VMEM", 1, "X", memory_bytes=vmem_tile_budget,
                    fill_bandwidth=TPU_V5E["hbm_bw"],
                    read_energy=0.15, write_energy=0.15,
                    macs_per_cycle=macs_per_cycle, mac_energy=0.4),
        ],
        frequency_hz=freq,
        attrs=dict(TPU_V5E),
    )


def tpu_v5e_pod(
    pods: int = 1,
    data: int = 16,
    model: int = 16,
    vmem_tile_budget: int = 16 * (1 << 20),
) -> Architecture:
    """A multi-pod TPU v5e system in Union's cluster abstraction.

    C6 Host/DCN -> C5 pods (DCN links) -> C4 'data' chips -> C3 'model'
    chips (HBM lives here: a chip) -> C2 virtual Pallas grid step -> C1
    VMEM+MXU. Spatial tiling at C5/C4/C3 == GSPMD sharding over mesh axes
    (pod, data, model); tiling at C2/C1 == Pallas grid/BlockSpec.

    Energy numbers are pJ/byte estimates for 7nm-class HBM/SRAM, only used
    for relative EDP comparisons, exactly like the paper's case studies.
    """
    mxu = TPU_V5E["mxu"]
    macs_per_cycle = mxu * mxu  # one MXU pass per cycle (bf16)
    # derive clock so that peak FLOPs match 197 TF: 2*macs/cycle*f = 197e12
    freq = TPU_V5E["peak_bf16_flops"] / (2 * macs_per_cycle * 4)  # 4 MXUs/chip
    levels = [
        Cluster("DCN", 1, "X", memory_bytes=1 << 50, fill_bandwidth=25e9,
                read_energy=400.0, write_energy=400.0),
        Cluster("Pods", pods, "pod", memory_bytes=None),
        Cluster("DataRing", data, "data", memory_bytes=None),
        Cluster("HBM", model, "model", memory_bytes=TPU_V5E["hbm_bytes"],
                fill_bandwidth=TPU_V5E["ici_link_bw"],
                read_energy=7.0, write_energy=7.0),
        Cluster("GridStep", 1, "X", memory_bytes=None),
        Cluster("VMEM", 1, "X", memory_bytes=vmem_tile_budget,
                fill_bandwidth=TPU_V5E["hbm_bw"],
                read_energy=0.15, write_energy=0.15,
                macs_per_cycle=macs_per_cycle * 4, mac_energy=0.4),
    ]
    return Architecture(
        f"tpu_v5e_{pods}x{data}x{model}",
        levels,
        frequency_hz=freq,
        attrs={"chip_count": pods * data * model, **TPU_V5E},
    )
