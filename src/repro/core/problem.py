"""Union problem abstraction (paper Sec. IV-B).

A tensor operation is described by:
  * a set of named problem *dimensions* with integer sizes (the iteration
    space is their Cartesian product),
  * a set of *data spaces* (tensors), each with an affine *projection*
    from the iteration space onto the tensor's coordinate space,
  * an optional high-level ``operation`` tag (GEMM / CONV2D / TC / ...)
    so operation-level cost models (MAESTRO) and loop-level cost models
    (Timeloop) can both consume the same instance.

The abstraction is intentionally richer than plain einsum: a projection
axis is a list of (coefficient, dim) terms so strided convolution windows
(``x*stride + r``) are first-class, as in Timeloop's problem spec.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping as TMapping, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Term:
    """One affine term ``coeff * dim``."""

    coeff: int
    dim: str

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return self.dim if self.coeff == 1 else f"{self.coeff}*{self.dim}"


@dataclass(frozen=True)
class AffineExpr:
    """An affine combination of problem dimensions: ``sum_i coeff_i * dim_i``.

    One AffineExpr describes ONE coordinate axis of a data space.
    """

    terms: Tuple[Term, ...]

    @staticmethod
    def of(*terms: Tuple[int, str] | str) -> "AffineExpr":
        out = []
        for t in terms:
            if isinstance(t, str):
                out.append(Term(1, t))
            else:
                out.append(Term(int(t[0]), str(t[1])))
        return AffineExpr(tuple(out))

    @property
    def dims(self) -> Tuple[str, ...]:
        return tuple(t.dim for t in self.terms)

    def extent(self, tile: TMapping[str, int]) -> int:
        """Number of distinct coordinate values touched when each dim ``d``
        ranges over ``tile[d]`` contiguous values.

        For a single term ``c*d`` with tile t: extent = (t-1)*|c| + 1 when the
        axis is sampled at stride |c| -- but data footprint counts *addresses
        spanned*, so for compound expressions (conv sliding window
        ``stride*x + r``) the footprint is ``sum_i |c_i|*(t_i - 1) + 1``.
        This matches Timeloop's working-set computation for strided CONV.
        """
        span = 1
        for t in self.terms:
            span += abs(t.coeff) * (max(1, int(tile.get(t.dim, 1))) - 1)
        return span

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return " + ".join(repr(t) for t in self.terms)


@dataclass(frozen=True)
class DataSpace:
    """A tensor operand/result of the problem.

    ``projection`` has one AffineExpr per tensor axis. ``is_output`` marks
    read-modify-write data spaces (partial-sum traffic is modeled for them).
    """

    name: str
    projection: Tuple[AffineExpr, ...]
    is_output: bool = False
    word_bytes: int = 2  # bf16 default on TPU; paper case studies use 1 (uint8)

    @property
    def dims(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for expr in self.projection:
            for d in expr.dims:
                if d not in seen:
                    seen.append(d)
        return tuple(seen)

    def footprint(self, tile: TMapping[str, int]) -> int:
        """Number of elements touched for the given per-dim tile sizes."""
        n = 1
        for expr in self.projection:
            n *= expr.extent(tile)
        return n

    def footprint_bytes(self, tile: TMapping[str, int]) -> int:
        return self.footprint(tile) * self.word_bytes


@dataclass
class Problem:
    """A Union problem instance.

    ``dims`` maps dimension name -> size (ordered; the order is the default
    loop order). ``operation`` is the optional high-level tag used by
    operation-level cost models and conformability passes.
    """

    name: str
    dims: Dict[str, int]
    data_spaces: Tuple[DataSpace, ...]
    operation: Optional[str] = None  # e.g. "GEMM", "CONV2D", "TC", "MTTKRP"
    unit_op: str = "mac2"  # two-operand multiply-accumulate (paper Sec. III-B2)
    attrs: Dict[str, object] = field(default_factory=dict)

    # ------------------------------------------------------------------ #
    # Derived quantities
    # ------------------------------------------------------------------ #
    @property
    def dim_names(self) -> Tuple[str, ...]:
        return tuple(self.dims.keys())

    @property
    def iteration_space(self) -> int:
        return math.prod(self.dims.values())

    @property
    def macs(self) -> int:
        """One unit-op per iteration-space point."""
        return self.iteration_space

    @property
    def flops(self) -> int:
        return 2 * self.macs  # one multiply + one add

    def outputs(self) -> Tuple[DataSpace, ...]:
        return tuple(ds for ds in self.data_spaces if ds.is_output)

    def inputs(self) -> Tuple[DataSpace, ...]:
        return tuple(ds for ds in self.data_spaces if not ds.is_output)

    def data_space(self, name: str) -> DataSpace:
        for ds in self.data_spaces:
            if ds.name == name:
                return ds
        raise KeyError(name)

    def reduction_dims(self) -> Tuple[str, ...]:
        """Dims that do not project into any output data space."""
        out_dims = set()
        for ds in self.outputs():
            out_dims.update(ds.dims)
        return tuple(d for d in self.dims if d not in out_dims)

    def total_tensor_bytes(self) -> int:
        return sum(ds.footprint_bytes(self.dims) for ds in self.data_spaces)

    def validate(self) -> None:
        for ds in self.data_spaces:
            for expr in ds.projection:
                for t in expr.terms:
                    if t.dim not in self.dims:
                        raise ValueError(
                            f"data space {ds.name!r} references unknown dim {t.dim!r}"
                        )
        if not self.outputs():
            raise ValueError(f"problem {self.name!r} has no output data space")

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        d = ", ".join(f"{k}={v}" for k, v in self.dims.items())
        return f"Problem({self.name}: {d}; op={self.operation})"

    # ------------------------------------------------------------------ #
    # Constructors for the tensor operations in the paper (Sec. II-A)
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_einsum(
        name: str,
        spec: str,
        sizes: TMapping[str, int],
        operation: Optional[str] = None,
        word_bytes: int = 2,
    ) -> "Problem":
        """Build a Problem from an einsum spec, e.g. ``"dfgb,geac->abcdef"``.

        Every index letter is a problem dimension; inputs/outputs get
        identity projections. This covers GEMM, TC, MTTKRP, batched matmul,
        attention score/context products, SSD chunk contractions, ...
        """
        lhs, rhs = spec.replace(" ", "").split("->")
        operands = lhs.split(",")
        letters: List[str] = []
        for token in operands + [rhs]:
            for ch in token:
                if ch not in letters:
                    letters.append(ch)
        dims = {ch: int(sizes[ch]) for ch in letters}
        spaces: List[DataSpace] = []
        for i, token in enumerate(operands):
            proj = tuple(AffineExpr.of(ch) for ch in token)
            spaces.append(DataSpace(f"In{i}", proj, False, word_bytes))
        out_proj = tuple(AffineExpr.of(ch) for ch in rhs)
        spaces.append(DataSpace("Out", out_proj, True, word_bytes))
        p = Problem(name, dims, tuple(spaces), operation=operation)
        p.attrs["einsum"] = spec
        p.validate()
        return p

    @staticmethod
    def gemm(M: int, N: int, K: int, name: str = "gemm", word_bytes: int = 2) -> "Problem":
        p = Problem.from_einsum(name, "mk,kn->mn", {"m": M, "k": K, "n": N}, "GEMM", word_bytes)
        return p

    @staticmethod
    def conv2d(
        N: int,
        K: int,
        C: int,
        X: int,
        Y: int,
        R: int,
        S: int,
        stride: int = 1,
        name: str = "conv2d",
        word_bytes: int = 2,
    ) -> "Problem":
        """CONV2D loop nest of paper Algorithm 1. X, Y are OUTPUT sizes."""
        dims = {"n": N, "k": K, "x": X, "y": Y, "c": C, "r": R, "s": S}
        ia = DataSpace(
            "Inputs",
            (
                AffineExpr.of("n"),
                AffineExpr.of("c"),
                AffineExpr.of((stride, "x"), (1, "r")),
                AffineExpr.of((stride, "y"), (1, "s")),
            ),
            False,
            word_bytes,
        )
        w = DataSpace(
            "Weights",
            (AffineExpr.of("k"), AffineExpr.of("c"), AffineExpr.of("r"), AffineExpr.of("s")),
            False,
            word_bytes,
        )
        oa = DataSpace(
            "Outputs",
            (AffineExpr.of("n"), AffineExpr.of("k"), AffineExpr.of("x"), AffineExpr.of("y")),
            True,
            word_bytes,
        )
        p = Problem(name, dims, (ia, w, oa), operation="CONV2D")
        p.attrs["stride"] = stride
        p.validate()
        return p

    @staticmethod
    def depthwise_conv2d(
        N: int, C: int, X: int, Y: int, R: int, S: int, stride: int = 1,
        name: str = "dwconv", word_bytes: int = 2,
    ) -> "Problem":
        dims = {"n": N, "c": C, "x": X, "y": Y, "r": R, "s": S}
        ia = DataSpace(
            "Inputs",
            (
                AffineExpr.of("n"),
                AffineExpr.of("c"),
                AffineExpr.of((stride, "x"), (1, "r")),
                AffineExpr.of((stride, "y"), (1, "s")),
            ),
            False,
            word_bytes,
        )
        w = DataSpace(
            "Weights",
            (AffineExpr.of("c"), AffineExpr.of("r"), AffineExpr.of("s")),
            False,
            word_bytes,
        )
        oa = DataSpace(
            "Outputs",
            (AffineExpr.of("n"), AffineExpr.of("c"), AffineExpr.of("x"), AffineExpr.of("y")),
            True,
            word_bytes,
        )
        p = Problem(name, dims, (ia, w, oa), operation="DWCONV")
        p.attrs["stride"] = stride
        p.validate()
        return p

    @staticmethod
    def mttkrp(I: int, J: int, K: int, L: int, name: str = "mttkrp", word_bytes: int = 2) -> "Problem":
        """A(i,j) += X(i,k,l) * B(k,j) * C(l,j): three-operand unit op.

        Used by the paper (Sec. III-B2) as the example of a problem whose
        unit operation is NOT a two-operand MAC -- conformability passes
        must reject it for cost models configured with mac2.
        """
        dims = {"i": I, "j": J, "k": K, "l": L}
        x = DataSpace("X", (AffineExpr.of("i"), AffineExpr.of("k"), AffineExpr.of("l")), False, word_bytes)
        b = DataSpace("B", (AffineExpr.of("k"), AffineExpr.of("j")), False, word_bytes)
        c = DataSpace("C", (AffineExpr.of("l"), AffineExpr.of("j")), False, word_bytes)
        a = DataSpace("A", (AffineExpr.of("i"), AffineExpr.of("j")), True, word_bytes)
        p = Problem(name, dims, (x, b, c, a), operation="MTTKRP", unit_op="mac3")
        p.validate()
        return p

    # Paper Table III tensor contractions (TCCG suite) ------------------- #
    @staticmethod
    def tc_intensli2(tds: int, word_bytes: int = 2) -> "Problem":
        # C[a,b,c,d] = A[d,b,e,a] * B[e,c]
        return Problem.from_einsum(
            f"intensli2_tds{tds}", "dbea,ec->abcd",
            {k: tds for k in "abcde"}, "TC", word_bytes,
        )

    @staticmethod
    def tc_ccsd7(tds: int, word_bytes: int = 2) -> "Problem":
        # C[a,b,c] = A[a,d,e,c] * B[e,b,d]
        return Problem.from_einsum(
            f"ccsd7_tds{tds}", "adec,ebd->abc",
            {k: tds for k in "abcde"}, "TC", word_bytes,
        )

    @staticmethod
    def tc_ccsd_t4(tds: int, word_bytes: int = 2) -> "Problem":
        # C[a,b,c,d,e,f] = A[d,f,g,b] * B[g,e,a,c]  (paper Algorithm 2)
        return Problem.from_einsum(
            f"ccsd-t4_tds{tds}", "dfgb,geac->abcdef",
            {k: tds for k in "abcdefg"}, "TC", word_bytes,
        )
