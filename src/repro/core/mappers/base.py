"""Mapper interface and search bookkeeping.

All mappers score candidates through one :class:`EvaluationEngine`
(``repro.core.cost.engine``): a signature-keyed memo cache, a lower-bound
admission filter, and a batch API. ``SearchResult`` surfaces the engine's
cache-hit / pruned counters next to the classic evaluated count so search
throughput stays observable.
"""

from __future__ import annotations

import abc
import math
import os
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.architecture import Architecture
from repro.core.cost.base import Cost, CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace
from repro.core.problem import Problem


@dataclass
class SearchResult:
    best_mapping: Optional[Mapping]
    best_cost: Optional[Cost]
    metric: str
    evaluated: int
    elapsed_s: float
    trajectory: List[Tuple[int, float]] = field(default_factory=list)  # (eval#, best metric)
    # engine counters (0 when a mapper bypasses the engine)
    cache_hits: int = 0
    pruned: int = 0
    analyzed: int = 0  # full cost-model analyses (cache misses)
    store_hits: int = 0  # served by the cross-search ResultStore
    # candidate instances the mapper submitted to the engine, before dedup
    # and regardless of how they were served (analysis / memo / store /
    # bound rejection). A store hit turns a would-be pruned or analyzed
    # candidate into a served one -- the evaluated/pruned SPLIT shifts
    # between warm and cold runs -- but the submitted stream is identical,
    # so this total is warm/cold INVARIANT.
    considered: int = 0
    fused_dispatches: int = 0  # miss-batches served by one jitted dispatch
    # engine degraded jax -> numpy mid-search (counted warning; results
    # unchanged by the backend bit-identity contract)
    backend_fallbacks: int = 0
    # compiled programs traced on behalf of this search (0 when the
    # shape-generic process cache already held every program -- the
    # one-trace-per-shape-class property this counter makes observable)
    n_traces: int = 0
    # host<->device sync points of the device-resident search loops (one
    # per mega-batch precompute / K-generation flush; 0 on host loops)
    device_syncs: int = 0
    admit_s: float = 0.0  # engine wall-clock in the admission (bound) stage
    score_s: float = 0.0  # engine wall-clock scoring admitted misses

    @property
    def best_metric(self) -> float:
        return self.best_cost.metric(self.metric) if self.best_cost else float("inf")

    @property
    def candidates(self) -> int:
        """Candidates the search considered: scored + bound-pruned."""
        return self.evaluated + self.pruned

    @property
    def scored(self) -> int:
        """Throughput numerator: the warm/cold-invariant ``considered``
        total MINUS store-served candidates (a store hit costs a dict
        probe, not an evaluation -- counting it would inflate warm-run
        rows against cold baselines). Falls back to the classic
        scored+pruned count for mappers that bypass the engine
        (``considered == 0``). The single definition both
        :attr:`evals_per_s` and ``benchmarks/mappers_bench.py`` use."""
        return (
            self.considered - self.store_hits if self.considered else self.candidates
        )

    @property
    def evals_per_s(self) -> float:
        return self.scored / self.elapsed_s if self.elapsed_s > 0 else 0.0

    def stats_dict(self) -> dict:
        """JSON-ready engine-counter summary (figure benchmarks attach this
        next to their metrics so cache-hit / pruned / throughput stay
        observable per experiment).

        With ``UNION_DETERMINISTIC_STATS`` set, only warm/cold-INVARIANT
        fields are emitted (the mapper's submitted candidate stream and
        the search outcome) and every timing is zeroed: the crash/resume
        byte-identity check compares figure JSONs from a killed+resumed
        sweep against an uninterrupted run, and the evaluated/pruned/
        store-hit split plus wall-clocks legitimately differ with store
        warmth while ``considered`` and the best mapping/cost do not.
        """
        if os.environ.get("UNION_DETERMINISTIC_STATS"):
            # NOT ``evaluated``: a store-served candidate is offered to the
            # tracker where a cold run would have bound-pruned it, so the
            # offer count shifts with warmth even though the best
            # mapping/cost cannot.
            return {
                "considered": self.considered,
                "backend_fallbacks": self.backend_fallbacks,
                "elapsed_s": 0.0,
                "evals_per_s": 0.0,
            }
        return {
            "evaluated": self.evaluated,
            "analyzed": self.analyzed,
            "cache_hits": self.cache_hits,
            "store_hits": self.store_hits,
            "pruned": self.pruned,
            "candidates": self.candidates,
            "considered": self.considered,
            "fused_dispatches": self.fused_dispatches,
            "backend_fallbacks": self.backend_fallbacks,
            "n_traces": self.n_traces,
            "device_syncs": self.device_syncs,
            "elapsed_s": round(self.elapsed_s, 4),
            "evals_per_s": round(self.evals_per_s, 1),
            "admit_s": round(self.admit_s, 4),
            "score_s": round(self.score_s, 4),
        }


class Mapper(abc.ABC):
    name: str = "base"

    @abc.abstractmethod
    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
        engine: Optional[EvaluationEngine] = None,
    ) -> SearchResult:
        ...

    def _mk_engine(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str,
        engine: Optional[EvaluationEngine],
    ) -> EvaluationEngine:
        if engine is not None:
            return engine
        return EvaluationEngine(cost_model, space.problem, space.arch, metric=metric)

    def _mk_result(
        self, metric: str, engine: Optional[EvaluationEngine] = None
    ) -> "_Tracker":
        return _Tracker(metric, engine)

    def batch_hints(self) -> List[int]:
        """Miss-batch sizes this mapper's searches are likely to dispatch
        -- consumed by ``EvaluationEngine.warmup`` (bucketed pre-tracing
        of the fused jax program) before a sweep's timed searches. Purely
        advisory: an empty list just skips warmup."""
        return []


class _Tracker:
    """Shared incumbent tracking for all mappers.

    The engine's counters are snapshotted at construction and reported as
    DIFFS, so a shared engine (``union_opt_sweep`` reuses one engine --
    memo cache, compiled runners and all -- across every search over the
    same space) still yields correct per-search stats. For the classic
    one-engine-per-search flow the snapshot is all zeros and nothing
    changes."""

    def __init__(self, metric: str, engine: Optional[EvaluationEngine] = None) -> None:
        self.metric = metric
        self.engine = engine
        self._stats_base = engine.stats.snapshot() if engine is not None else None
        self.best_mapping: Optional[Mapping] = None
        self.best_cost: Optional[Cost] = None
        self.best_metric_value: float = math.inf
        self.evaluated = 0
        self.t0 = time.time()
        self.trajectory: List[Tuple[int, float]] = []

    def offer(self, mapping: Mapping, cost: Cost) -> bool:
        self.evaluated += 1
        score = cost.metric(self.metric)
        if self.best_cost is None or score < self.best_metric_value:
            self.best_mapping = mapping
            self.best_cost = cost
            self.best_metric_value = score
            self.trajectory.append((self.evaluated, score))
            return True
        return False

    def offer_lazy(self, make, cost: Cost, score: Optional[float] = None) -> bool:
        """:meth:`offer` for array-native batches: ``make()`` materializes
        the candidate (a GenomeBatch row -> Genome) ONLY when it improves
        the incumbent, so scanning a batch's costs touches no per-row
        Python objects for the non-improving majority. ``score`` passes an
        already-computed metric value (callers that also need the fitness
        avoid scoring twice)."""
        self.evaluated += 1
        if score is None:
            score = cost.metric(self.metric)
        if self.best_cost is None or score < self.best_metric_value:
            self.best_mapping = make()
            self.best_cost = cost
            self.best_metric_value = score
            self.trajectory.append((self.evaluated, score))
            return True
        return False

    def result(self) -> SearchResult:
        stats = self.engine.stats if self.engine is not None else None
        base = self._stats_base

        def delta(attr, zero=0):
            if stats is None:
                return zero
            return getattr(stats, attr) - getattr(base, attr)

        best = self.best_mapping
        if best is not None and not isinstance(best, Mapping):
            best = best.to_mapping()  # chain-level genome -> Mapping
        return SearchResult(
            best_mapping=best,
            best_cost=self.best_cost,
            metric=self.metric,
            evaluated=self.evaluated,
            elapsed_s=time.time() - self.t0,
            trajectory=self.trajectory,
            cache_hits=delta("cache_hits"),
            pruned=delta("pruned"),
            analyzed=delta("evaluated"),
            store_hits=delta("store_hits"),
            considered=delta("considered"),
            fused_dispatches=delta("fused_dispatches"),
            backend_fallbacks=delta("backend_fallbacks"),
            n_traces=delta("n_traces"),
            device_syncs=delta("device_syncs"),
            admit_s=delta("admit_s", 0.0),
            score_s=delta("score_s", 0.0),
        )
