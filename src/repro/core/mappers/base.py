"""Mapper interface and search bookkeeping."""

from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.architecture import Architecture
from repro.core.cost.base import Cost, CostModel
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace
from repro.core.problem import Problem


@dataclass
class SearchResult:
    best_mapping: Optional[Mapping]
    best_cost: Optional[Cost]
    metric: str
    evaluated: int
    elapsed_s: float
    trajectory: List[Tuple[int, float]] = field(default_factory=list)  # (eval#, best metric)

    @property
    def best_metric(self) -> float:
        return self.best_cost.metric(self.metric) if self.best_cost else float("inf")


class Mapper(abc.ABC):
    name: str = "base"

    @abc.abstractmethod
    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
    ) -> SearchResult:
        ...

    def _mk_result(self, metric: str) -> "_Tracker":
        return _Tracker(metric)


class _Tracker:
    """Shared incumbent tracking for all mappers."""

    def __init__(self, metric: str) -> None:
        self.metric = metric
        self.best_mapping: Optional[Mapping] = None
        self.best_cost: Optional[Cost] = None
        self.evaluated = 0
        self.t0 = time.time()
        self.trajectory: List[Tuple[int, float]] = []

    def offer(self, mapping: Mapping, cost: Cost) -> bool:
        self.evaluated += 1
        if self.best_cost is None or cost.metric(self.metric) < self.best_cost.metric(self.metric):
            self.best_mapping = mapping
            self.best_cost = cost
            self.trajectory.append((self.evaluated, cost.metric(self.metric)))
            return True
        return False

    def result(self) -> SearchResult:
        return SearchResult(
            best_mapping=self.best_mapping,
            best_cost=self.best_cost,
            metric=self.metric,
            evaluated=self.evaluated,
            elapsed_s=time.time() - self.t0,
            trajectory=self.trajectory,
        )
