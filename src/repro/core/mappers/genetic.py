"""GAMMA-style genetic-algorithm mapper (paper [15]).

Standard GA over the unified mapping genome (per-dim divisor chains +
per-level loop orders): tournament selection, chain crossover, tile/order
mutation, elitism. Works with ANY cost model -- in the paper's framing
this is the previously-impossible "GAMMA driving Timeloop" combination.

``seed_version=2`` (default) runs the GA ARRAY-NATIVE: the population
lives as dense :class:`~repro.core.genome_batch.GenomeBatch` matrices and
every generation's selection (tournament index draws), crossover
(per-dim/per-level parent masks), mutation (masked order-swap / chain
re-sample) and legality checks run as masked array programs over the
whole population with a counter-based (Philox) RNG -- one draw sequence
per generation instead of thousands of per-candidate ``random.Random``
calls. Generation is all-numpy, so for a fixed seed the search is
bit-identical across scalar/numpy/jax engine backends.
``seed_version=1`` preserves the historical per-candidate stream exactly.

Fitness is computed through the evaluation engine: each generation's
children are generated first (only the RNG advances) and then scored as
one batch, so the signature cache absorbs the heavy candidate re-visiting
of mutate/crossover and pool fan-out applies when enabled. Selection
needs a true fitness for every member, so the lower-bound filter is NOT
applied here -- population dynamics, and therefore results for fixed
seeds, are identical to serial evaluation.
"""

from __future__ import annotations

import random
from operator import itemgetter
from typing import List, Optional, Tuple

import numpy as np

from repro.core import genome_batch as gbm
from repro.core.cost.base import CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.device_loop import DeviceGAScorer, device_loop_enabled
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapspace import MapSpace, fast_sample


class GeneticMapper(Mapper):
    name = "genetic"

    def __init__(
        self,
        population: int = 40,
        generations: int = 20,
        elite: int = 4,
        tournament: int = 3,
        mutation_rate: float = 0.35,
        seed: int = 0,
        seed_version: int = 2,
    ) -> None:
        self.population = population
        self.generations = generations
        self.elite = elite
        self.tournament = tournament
        self.mutation_rate = mutation_rate
        self.seed = seed
        self.seed_version = seed_version

    def batch_hints(self) -> List[int]:
        return [self.population, self.population - self.elite]

    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
        engine: Optional[EvaluationEngine] = None,
    ) -> SearchResult:
        if self.seed_version < 2:
            return self._search_v1(space, cost_model, metric, engine)
        return self._search_v2(space, cost_model, metric, engine)

    # ------------------------------------------------------------------ #
    def _search_v2(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str,
        engine: Optional[EvaluationEngine],
    ) -> SearchResult:
        engine = self._mk_engine(space, cost_model, metric, engine)
        tr = self._mk_result(metric, engine)
        rng = gbm.philox_rng(self.seed)
        P = self.population
        n, D = space.n_levels, len(space.dims)

        # Device-resident scoring: each generation's fitness comes off one
        # fused dispatch with results left ON DEVICE; the buffered results
        # replay through the engine (and the tracker, in generation order)
        # every sync_cadence() generations. Selection reads only the
        # fitness vector and the GA never consults the tracker mid-loop,
        # so deferring the offers is observationally equivalent -- best,
        # trajectory, memo and store contents equal the host loop's.
        def on_costs(g, cs):
            for i, c in enumerate(cs):
                tr.offer_lazy(
                    lambda b=i, gg=g: gg.genome(b), c, score=c.metric(metric)
                )

        scorer = DeviceGAScorer(engine, on_costs) if device_loop_enabled(engine) else None

        def score_batch(g):
            """Per-row fitness; offers immediate (host) or deferred
            (device, replayed in order at the K-generation sync)."""
            if scorer is not None and scorer.active:
                f = scorer.score(g)
                if f is not None:
                    return f
            cs = engine.evaluate_batch(g)
            out = np.empty(len(g), dtype=np.float64)
            for i, c in enumerate(cs):
                s = c.metric(metric)
                tr.offer_lazy(lambda b=i, gg=g: gg.genome(b), c, score=s)
                out[i] = s
            return out

        tt, st, perm = gbm.random_rows_batch(space, rng, P)
        gb = gbm.GenomeBatch(space, tt, st, perm)
        fitness = score_batch(gb)

        T = min(self.tournament, P)
        elite = min(self.elite, P)
        C = P - elite
        for _gen in range(self.generations):
            order = np.argsort(fitness, kind="stable")
            tt, st, perm, fitness = tt[order], st[order], perm[order], fitness[order]
            if C <= 0:
                break
            # tournament selection: per (child, parent), T distinct
            # population indices via the smallest-keys trick, winner by
            # fitness
            keys = rng.random((C, 2, P))
            contenders = np.argpartition(keys, T - 1, axis=2)[:, :, :T]
            cfit = fitness[contenders]
            winner = np.take_along_axis(
                contenders, np.argmin(cfit, axis=2)[:, :, None], axis=2
            )[:, :, 0]
            pa, pb = winner[:, 0], winner[:, 1]
            # FUSED child construction: per-dim uniform chain crossover +
            # per-level order choice, mutation applied in the same round
            # (mutated children: one order swap or one chain re-sample),
            # ONE legality program per round over all still-illegal
            # children, which redraw their masks/moves against the same
            # parents
            ctt = np.empty((C, n, D), dtype=np.int64)
            cst = np.empty_like(ctt)
            cperm = np.empty_like(ctt)
            mut = rng.random(C) < self.mutation_rate
            todo = np.arange(C)
            for _try in range(3):
                V = todo.size
                sa, sb = pa[todo], pb[todo]
                md = (rng.random((V, D)) < 0.5)[:, None, :]
                mo = (rng.random((V, n)) < 0.5)[:, :, None]
                t2 = np.where(md, tt[sa], tt[sb])
                s2 = np.where(md, st[sa], st[sb])
                p2 = np.where(mo, perm[sa], perm[sb])
                mrows = np.flatnonzero(mut[todo])
                if mrows.size:
                    move = rng.random(mrows.size) < 0.3
                    om = mrows[move]
                    if om.size and D >= 2:
                        lvl = rng.integers(0, n, om.size)
                        a = rng.integers(0, D, om.size)
                        b = rng.integers(0, D - 1, om.size)
                        b = b + (b >= a)
                        swp = p2[om, lvl, a].copy()
                        p2[om, lvl, a] = p2[om, lvl, b]
                        p2[om, lvl, b] = swp
                    cmr = mrows[~move]
                    if cmr.size:
                        dsel = rng.integers(0, D, cmr.size)
                        for j in range(D):
                            rr = cmr[dsel == j]
                            if rr.size == 0:
                                continue
                            tcol, scol = gbm.sample_chain_cols(
                                space, rng, j, rr.size
                            )
                            t2[rr, :, j] = tcol
                            s2[rr, :, j] = scol
                # two-phase legality: pass the (majority) already-legal
                # children untouched -- duplicate children stay exact
                # duplicates and keep hitting the engine memo -- then
                # repair ONLY the failures' fanout (the dominant failure
                # mode of cross-dim mixing) and re-check that small subset
                ok = gbm.legal_batch(space, t2, s2, p2, structured=True)
                bad = np.flatnonzero(~ok)
                if bad.size:
                    bt, bs, bp = t2[bad], s2[bad], p2[bad]
                    gbm.repair_fanout_batch(space, rng, bt, bs)
                    ok2 = gbm.legal_batch(space, bt, bs, bp, structured=True)
                    fixed = np.flatnonzero(ok2)
                    t2[bad[fixed]] = bt[fixed]
                    s2[bad[fixed]] = bs[fixed]
                    ok[bad[fixed]] = True
                ctt[todo], cst[todo], cperm[todo] = t2, s2, p2
                todo = todo[~ok]
                if todo.size == 0:
                    break
            # Fallback after the bounded retry rounds: parent a wholesale.
            # Deliberately a DUPLICATE of an already-scored candidate --
            # it shows up as a memo hit, costing a dict probe instead of
            # an array-program evaluation (the scalar GA converged to the
            # same behavior through its per-candidate fallbacks).
            if todo.size:
                ctt[todo], cst[todo], cperm[todo] = (
                    tt[pa[todo]],
                    st[pa[todo]],
                    perm[pa[todo]],
                )
            cgb = gbm.GenomeBatch(space, ctt, cst, cperm)
            cfit2 = score_batch(cgb)
            tt = np.concatenate([tt[:elite], ctt])
            st = np.concatenate([st[:elite], cst])
            perm = np.concatenate([perm[:elite], cperm])
            fitness = np.concatenate([fitness[:elite], cfit2])
        if scorer is not None:
            scorer.flush()  # replay any still-buffered generations
        return tr.result()

    # ------------------------------------------------------------------ #
    def _search_v1(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str,
        engine: Optional[EvaluationEngine],
    ) -> SearchResult:
        """The historical per-candidate stream (``seed_version=1``),
        bit-exact with pre-batch releases for fixed seeds."""
        engine = self._mk_engine(space, cost_model, metric, engine)
        rng = random.Random(self.seed)
        tr = self._mk_result(metric, engine)

        seeds = [space.random_genome(rng) for _ in range(self.population)]
        costs = engine.evaluate_batch(seeds)
        pop: List[Tuple[float, object]] = []
        for m, c in zip(seeds, costs):
            tr.offer(m, c)
            pop.append((c.metric(metric), m))

        fitness = itemgetter(0)
        tournament = min(self.tournament, self.population)
        for _gen in range(self.generations):
            pop.sort(key=fitness)
            nxt: List[Tuple[float, object]] = pop[: self.elite]

            def pick():
                contenders = fast_sample(rng, pop, min(tournament, len(pop)))
                return min(contenders, key=fitness)[1]

            children = []
            while len(nxt) + len(children) < self.population:
                child = space.crossover_genome(pick(), pick(), rng)
                if rng.random() < self.mutation_rate:
                    child = space.mutate_genome(child, rng)
                children.append(child)
            ccosts = engine.evaluate_batch(children)
            for m, c in zip(children, ccosts):
                tr.offer(m, c)
                nxt.append((c.metric(metric), m))
            pop = nxt
        return tr.result()
