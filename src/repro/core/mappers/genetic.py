"""GAMMA-style genetic-algorithm mapper (paper [15]).

Standard GA over the unified mapping genome (per-dim divisor chains +
per-level loop orders): tournament selection, chain crossover, tile/order
mutation, elitism. Works with ANY cost model -- in the paper's framing
this is the previously-impossible "GAMMA driving Timeloop" combination.
"""

from __future__ import annotations

import random
from typing import List, Tuple

from repro.core.cost.base import Cost, CostModel
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace


class GeneticMapper(Mapper):
    name = "genetic"

    def __init__(
        self,
        population: int = 40,
        generations: int = 20,
        elite: int = 4,
        tournament: int = 3,
        mutation_rate: float = 0.35,
        seed: int = 0,
    ) -> None:
        self.population = population
        self.generations = generations
        self.elite = elite
        self.tournament = tournament
        self.mutation_rate = mutation_rate
        self.seed = seed

    def search(self, space: MapSpace, cost_model: CostModel, metric: str = "edp") -> SearchResult:
        rng = random.Random(self.seed)
        tr = self._mk_result(metric)

        def score(m: Mapping) -> Cost:
            c = cost_model.evaluate(space.problem, m, space.arch)
            tr.offer(m, c)
            return c

        pop: List[Tuple[float, Mapping]] = []
        for _ in range(self.population):
            m = space.random_mapping(rng)
            pop.append((score(m).metric(metric), m))

        for _gen in range(self.generations):
            pop.sort(key=lambda t: t[0])
            nxt: List[Tuple[float, Mapping]] = pop[: self.elite]
            while len(nxt) < self.population:
                # tournament selection
                def pick() -> Mapping:
                    contenders = rng.sample(pop, min(self.tournament, len(pop)))
                    return min(contenders, key=lambda t: t[0])[1]

                child = space.crossover(pick(), pick(), rng)
                if rng.random() < self.mutation_rate:
                    child = space.mutate(child, rng)
                nxt.append((score(child).metric(metric), child))
            pop = nxt
        return tr.result()
