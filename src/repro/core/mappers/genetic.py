"""GAMMA-style genetic-algorithm mapper (paper [15]).

Standard GA over the unified mapping genome (per-dim divisor chains +
per-level loop orders): tournament selection, chain crossover, tile/order
mutation, elitism. Works with ANY cost model -- in the paper's framing
this is the previously-impossible "GAMMA driving Timeloop" combination.

Fitness is computed through the evaluation engine: each generation's
children are generated first (only the RNG advances) and then scored as
one batch, so the signature cache absorbs the heavy candidate re-visiting
of mutate/crossover (typically ~half of all evaluations) and pool fan-out
applies when enabled. Selection needs a true fitness for every member, so
the lower-bound filter is NOT applied here -- population dynamics, and
therefore results for fixed seeds, are identical to serial evaluation.
"""

from __future__ import annotations

import random
from operator import itemgetter
from typing import List, Optional, Tuple

from repro.core.cost.base import CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapspace import MapSpace, fast_sample


class GeneticMapper(Mapper):
    name = "genetic"

    def __init__(
        self,
        population: int = 40,
        generations: int = 20,
        elite: int = 4,
        tournament: int = 3,
        mutation_rate: float = 0.35,
        seed: int = 0,
    ) -> None:
        self.population = population
        self.generations = generations
        self.elite = elite
        self.tournament = tournament
        self.mutation_rate = mutation_rate
        self.seed = seed

    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
        engine: Optional[EvaluationEngine] = None,
    ) -> SearchResult:
        engine = self._mk_engine(space, cost_model, metric, engine)
        rng = random.Random(self.seed)
        tr = self._mk_result(metric, engine)

        seeds = [space.random_genome(rng) for _ in range(self.population)]
        costs = engine.evaluate_batch(seeds)
        pop: List[Tuple[float, object]] = []
        for m, c in zip(seeds, costs):
            tr.offer(m, c)
            pop.append((c.metric(metric), m))

        fitness = itemgetter(0)
        tournament = min(self.tournament, self.population)
        for _gen in range(self.generations):
            pop.sort(key=fitness)
            nxt: List[Tuple[float, object]] = pop[: self.elite]

            def pick():
                contenders = fast_sample(rng, pop, min(tournament, len(pop)))
                return min(contenders, key=fitness)[1]

            children = []
            while len(nxt) + len(children) < self.population:
                child = space.crossover_genome(pick(), pick(), rng)
                if rng.random() < self.mutation_rate:
                    child = space.mutate_genome(child, rng)
                children.append(child)
            ccosts = engine.evaluate_batch(children)
            for m, c in zip(children, ccosts):
                tr.offer(m, c)
                nxt.append((c.metric(metric), m))
            pop = nxt
        return tr.result()
