"""Plug-and-play mappers (paper Sec. III-B1).

Every mapper searches the SAME MapSpace and scores candidates with ANY
CostModel -- the unified mapping abstraction is what makes e.g. a
GAMMA-style genetic mapper usable with a Timeloop-like cost model, which
the paper highlights as impossible in the tightly-coupled status quo.
"""

from repro.core.mappers.base import Mapper, SearchResult  # noqa: F401
from repro.core.mappers.exhaustive import ExhaustiveMapper  # noqa: F401
from repro.core.mappers.random_search import RandomMapper  # noqa: F401
from repro.core.mappers.decoupled import DecoupledMapper  # noqa: F401
from repro.core.mappers.genetic import GeneticMapper  # noqa: F401
from repro.core.mappers.heuristic import HeuristicMapper  # noqa: F401

MAPPER_REGISTRY = {
    "exhaustive": ExhaustiveMapper,
    "random": RandomMapper,
    "decoupled": DecoupledMapper,
    "genetic": GeneticMapper,
    "heuristic": HeuristicMapper,
}


def get_mapper(name: str, **kw) -> Mapper:
    return MAPPER_REGISTRY[name](**kw)
