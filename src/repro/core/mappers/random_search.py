"""Random-sampling mapper (Timeloop's default search [11])."""

from __future__ import annotations

import random

from repro.core.cost.base import CostModel
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapspace import MapSpace


class RandomMapper(Mapper):
    name = "random"

    def __init__(self, samples: int = 2000, seed: int = 0, patience: int = 0) -> None:
        """``patience``: stop after this many consecutive non-improving
        samples (0 = never early-stop), mirroring Timeloop's victory
        condition."""
        self.samples = samples
        self.seed = seed
        self.patience = patience

    def search(self, space: MapSpace, cost_model: CostModel, metric: str = "edp") -> SearchResult:
        rng = random.Random(self.seed)
        tr = self._mk_result(metric)
        stale = 0
        for _ in range(self.samples):
            m = space.random_mapping(rng)
            cost = cost_model.evaluate(space.problem, m, space.arch)
            if tr.offer(m, cost):
                stale = 0
            else:
                stale += 1
                if self.patience and stale >= self.patience:
                    break
        return tr.result()
