"""Random-sampling mapper (Timeloop's default search [11]).

Samples are drawn in chunks and scored through the evaluation engine:
bound-dominated candidates are pruned before the reuse analysis, the rest
are batch-evaluated (pool fan-out when the engine has workers). Candidate
generation touches only the RNG, so chunking preserves the exact sample
stream -- and a pruned candidate provably cannot improve the incumbent --
which keeps results identical to one-at-a-time evaluation for fixed seeds.
"""

from __future__ import annotations

import random
from typing import Optional

from repro.core.cost.base import CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapspace import MapSpace


class RandomMapper(Mapper):
    name = "random"

    def __init__(
        self,
        samples: int = 2000,
        seed: int = 0,
        patience: int = 0,
        batch_size: int = 128,
        probe: int = 8,
    ) -> None:
        """``patience``: stop after this many consecutive non-improving
        samples (0 = never early-stop), mirroring Timeloop's victory
        condition. ``probe``: the engine-level warm start (see
        ``EvaluationEngine.evaluate_batch``) -- while no incumbent exists,
        the first ``probe`` candidates of a batch are scored unpruned and
        their best seeds the bound filter for the rest (0 disables). The
        sample stream is independent of chunking and pruning is exact, so
        results are identical for any ``probe``."""
        self.samples = samples
        self.seed = seed
        self.patience = patience
        self.batch_size = batch_size
        self.probe = probe

    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
        engine: Optional[EvaluationEngine] = None,
    ) -> SearchResult:
        engine = self._mk_engine(space, cost_model, metric, engine)
        rng = random.Random(self.seed)
        tr = self._mk_result(metric, engine)
        stale = 0
        remaining = self.samples
        while remaining > 0:
            k = min(self.batch_size, remaining)
            remaining -= k
            batch = [space.random_genome(rng) for _ in range(k)]
            costs = engine.evaluate_batch(
                batch, incumbent=tr.best_metric_value, probe=self.probe
            )
            stop = False
            for m, c in zip(batch, costs):
                if c is not None and tr.offer(m, c):
                    stale = 0
                else:
                    # pruned candidates are provably non-improving
                    stale += 1
                    if self.patience and stale >= self.patience:
                        stop = True
                        break
            if stop:
                break
        return tr.result()
