"""Random-sampling mapper (Timeloop's default search [11]).

Samples are drawn in chunks and scored through the evaluation engine:
bound-dominated candidates are pruned before the reuse analysis, the rest
are batch-evaluated (pool fan-out when the engine has workers).

``seed_version`` selects the candidate generator:

  * ``2`` (default) -- ARRAY-NATIVE: each chunk is one
    :class:`~repro.core.genome_batch.GenomeBatch` drawn by the vectorized
    counter-based (Philox) sampler -- chain choices, fanout repair,
    order shuffles and legality run as array programs over the whole
    chunk, and the engine consumes the dense rows directly (row-hash
    dedup, sliced StackedBatch). Candidates depend only on
    ``(seed, chunk sequence)``; generation never touches the engine
    backend, so results are bit-identical across scalar/numpy/jax.
  * ``1`` -- the historical per-candidate ``random.Random`` stream
    (bit-exact with every pre-batch release for fixed seeds).

Within a version, chunking preserves the exact sample stream -- and a
pruned candidate provably cannot improve the incumbent -- so results are
identical to one-at-a-time evaluation for fixed seeds.
"""

from __future__ import annotations

import random
from typing import List, Optional

from repro.core.cost.base import CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.device_loop import (
    device_loop_enabled,
    device_precompute,
    sync_cadence,
)
from repro.core.genome_batch import philox_rng, random_genome_batch
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapspace import MapSpace


class RandomMapper(Mapper):
    name = "random"

    def __init__(
        self,
        samples: int = 2000,
        seed: int = 0,
        patience: int = 0,
        batch_size: int = 128,
        probe: int = 8,
        seed_version: int = 2,
    ) -> None:
        """``patience``: stop after this many consecutive non-improving
        samples (0 = never early-stop), mirroring Timeloop's victory
        condition. ``probe``: the engine-level warm start (see
        ``EvaluationEngine.evaluate_batch``) -- while no incumbent exists,
        the first ``probe`` candidates of a batch are scored unpruned and
        their best seeds the bound filter for the rest (0 disables). The
        sample stream is independent of chunking and pruning is exact, so
        results are identical for any ``probe``. ``seed_version``: 2 for
        the vectorized batch sampler (default), 1 for the historical
        scalar stream."""
        self.samples = samples
        self.seed = seed
        self.patience = patience
        self.batch_size = batch_size
        self.probe = probe
        self.seed_version = seed_version

    def batch_hints(self) -> List[int]:
        first = min(self.batch_size, self.samples)
        tail = self.samples % self.batch_size
        return [self.probe, first - self.probe, first, tail]

    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
        engine: Optional[EvaluationEngine] = None,
    ) -> SearchResult:
        engine = self._mk_engine(space, cost_model, metric, engine)
        tr = self._mk_result(metric, engine)
        v2 = self.seed_version >= 2
        rng = philox_rng(self.seed) if v2 else random.Random(self.seed)
        # device-resident window: pre-draw up to K chunks (the sample
        # stream is generation-independent, so the draws are the exact
        # chunks the host loop would draw) and score them as ONE fused
        # device dispatch; each chunk then replays through the engine with
        # its precomputed rows -- admission against the then-current
        # incumbent, memo/store and counters identical to the host loop.
        # A patience stop mid-window discards the unconsumed chunks.
        window = sync_cadence() if (v2 and device_loop_enabled(engine)) else 1
        stale = 0
        remaining = self.samples
        stop = False
        while remaining > 0 and not stop:
            sizes = []
            rem2 = remaining
            while rem2 > 0 and len(sizes) < window:
                k = min(self.batch_size, rem2)
                rem2 -= k
                sizes.append(k)
            remaining = rem2
            if v2:
                batches = [random_genome_batch(space, rng, k) for k in sizes]
            else:
                batches = [
                    [space.random_genome(rng) for _ in range(k)] for k in sizes
                ]
            pres = device_precompute(engine, batches) if window > 1 else None
            if pres is None:
                pres = [None] * len(batches)
            for batch, pre in zip(batches, pres):
                costs = engine.evaluate_batch(
                    batch,
                    incumbent=tr.best_metric_value,
                    probe=self.probe,
                    precomputed=pre,
                )
                for i, c in enumerate(costs):
                    if c is not None and (
                        tr.offer_lazy(lambda b=i, g=batch: g.genome(b), c)
                        if v2
                        else tr.offer(batch[i], c)
                    ):
                        stale = 0
                    else:
                        # pruned candidates are provably non-improving
                        stale += 1
                        if self.patience and stale >= self.patience:
                            stop = True
                            break
                if stop:
                    break
        return tr.result()
