"""Heuristic mapper: utilization-first greedy seed + local hill-climb.

The greedy seed spreads the largest problem dims spatially across the
spatial-capable levels (maximizing PE utilization, which Fig. 10 of the
paper shows dominates EDP), then temporal tiles are chosen to saturate
each level's memory. Hill-climbing refines with the shared mutation
operator, accepting only improvements.

The climb is chunked through ``EvaluationEngine.evaluate_batch`` so it
hits the batched admission bound, the shared StackedBatch, and (under
``engine_backend="jax"``) the single-dispatch fused admit+score program
-- previously each step went through scalar ``evaluate_admit``. Chunks
are SPECULATIVE: all ``chunk`` candidates are mutations of the current
incumbent, results are scanned in order, and the tail past the first
accepted move is discarded while the RNG is rewound to the state the
serial walk would have -- so the ACCEPTED-MOVE SEQUENCE (every accepted
mapping and score, in order) and the final best mapping are identical to
the one-at-a-time climb for any fixed seed (A/B-asserted in
``tests/test_mappers.py``). Work counters are NOT part of that contract:
speculated candidates past an accepted move were evaluated and cached,
so a later re-draw the serial walk would bound-prune can instead be
served from cache and offered -- ``SearchResult.evaluated`` and
trajectory step indices may differ slightly from ``chunk=1``.

The neighbor batches themselves are ARRAY-NATIVE: mutations are drawn at
the genome level (``mutate_genome`` -- the identical RNG stream
``space.mutate`` consumes, so the serial-equivalence contract is
untouched) and each chunk is submitted as one dense
:class:`~repro.core.genome_batch.GenomeBatch`, so the engine dedups by
row hash and slices the admission/scoring StackedBatch straight out of
the chunk matrices instead of building per-candidate signature tuples.
No seed-versioning applies here: the climb's stream is pinned by the
accepted-move contract.
"""

from __future__ import annotations

import math
import random
from typing import Dict, List, Optional

from repro.core.cost.base import CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.genome_batch import GenomeBatch
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapping import LevelMapping, Mapping
from repro.core.mapspace import MapSpace


class HeuristicMapper(Mapper):
    name = "heuristic"

    def __init__(
        self,
        climb_steps: int = 300,
        restarts: int = 3,
        seed: int = 0,
        chunk: int = 8,
        probe: int = 8,
    ) -> None:
        """``chunk``: climb steps speculated per ``evaluate_batch`` call
        (<=1 restores the serial scalar walk -- the A/B reference).
        ``probe``: the engine-level warm start passed through to
        ``evaluate_batch`` like random/exhaustive do; the climb always has
        a finite incumbent (the seed mapping), so it only engages if a
        cost model ever yields an infinite seed metric."""
        self.climb_steps = climb_steps
        self.restarts = restarts
        self.seed = seed
        self.chunk = chunk
        self.probe = probe

    def batch_hints(self):
        return [self.chunk, self.probe]

    # ------------------------------------------------------------------ #
    def _greedy_seed(self, space: MapSpace, rng: random.Random) -> Mapping:
        problem, arch = space.problem, space.arch
        dims = dict(problem.dims)
        n = space.n_levels
        # remaining sizes to tile, per dim
        chains: Dict[str, List[int]] = {d: [] for d in dims}
        cur = dict(dims)
        for i in range(n):
            fan = space.child_fanout[i]
            # choose spatial factors for this level greedily from big dims
            st_factors = {d: 1 for d in dims}
            if fan > 1 and i < n - 1:
                budget = fan
                # sort dims by remaining size, prefer non-reduction dims for
                # outputs-stationarity but allow all
                for d in sorted(dims, key=lambda d: -cur[d]):
                    if budget <= 1:
                        break
                    if space.constraints is not None and not space.constraints._spatial_ok(
                        arch.clusters[i].name, d
                    ):
                        continue
                    f = math.gcd(cur[d], budget)
                    # largest divisor of cur[d] that divides budget
                    best = 1
                    for v in space._divs(cur[d]):
                        if budget % v == 0 and v > best:
                            best = v
                    f = best
                    if f > 1:
                        st_factors[d] = f
                        budget //= f
            for d in dims:
                tt = cur[d]  # temporal tile = whole remaining (stream at this level)
                st = tt // st_factors[d]
                chains[d].extend((tt, st))
                cur[d] = st
        levels = []
        for i, cl in enumerate(arch.clusters):
            tt = {d: chains[d][2 * i] for d in dims}
            st = {d: chains[d][2 * i + 1] for d in dims}
            levels.append(LevelMapping(cl.name, tuple(dims), tt, st))
        m = Mapping(levels, problem.name)
        # repair memory violations: shrink temporal tiles at offending levels
        for i, cl in enumerate(arch.clusters):
            if cl.virtual or cl.memory_bytes is None or i == 0:
                continue
            guard = 0
            while True:
                tile = {d: m.levels[i].tt(d) for d in dims}
                need = sum(ds.footprint_bytes(tile) for ds in problem.data_spaces)
                if need <= cl.memory_bytes or guard > 64:
                    break
                guard += 1
                # halve the biggest temporal tile dim (keeping divisibility)
                d = max(dims, key=lambda d: m.levels[i].tt(d))
                tt = m.levels[i].tt(d)
                smaller = [v for v in space._divs(tt) if v < tt]
                if not smaller:
                    break
                new_tt = max(smaller)
                # keep inner chain nested
                m.levels[i].temporal_tile_sizes[d] = new_tt
                m.levels[i].spatial_tile_sizes[d] = min(m.levels[i].st(d), new_tt)
                for j in range(i + 1, space.n_levels):
                    m.levels[j].temporal_tile_sizes[d] = min(
                        m.levels[j].tt(d), m.levels[j - 1].st(d)
                    )
                    m.levels[j].spatial_tile_sizes[d] = min(
                        m.levels[j].st(d), m.levels[j].tt(d)
                    )
        if m.is_legal(problem, arch):
            return m
        return space.random_mapping(rng)

    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
        engine: Optional[EvaluationEngine] = None,
    ) -> SearchResult:
        engine = self._mk_engine(space, cost_model, metric, engine)
        rng = random.Random(self.seed)
        tr = self._mk_result(metric, engine)
        steps_per_restart = self.climb_steps // self.restarts
        for r in range(self.restarts):
            m = self._greedy_seed(space, rng) if r == 0 else space.random_mapping(rng)
            if space.constraints is not None and not space.constraints.ok(
                m, space.problem, space.arch
            ):
                m = space.random_mapping(rng)
            best = engine.evaluate(m)
            tr.offer(m, best)
            best_s = best.metric(metric)
            if self.chunk <= 1:
                # serial reference walk (exact historical behavior)
                for _ in range(steps_per_restart):
                    cand = space.mutate(m, rng)
                    # prune against the LOCAL incumbent: a candidate whose
                    # bound is >= the climb's best can neither be an
                    # accepted move nor improve the global best (global <=
                    # local), so the walk is unchanged vs. evaluating
                    # everything.
                    c = engine.evaluate_admit(cand, incumbent=best_s)
                    if c is None:
                        continue
                    tr.offer(cand, c)
                    s = c.metric(metric)
                    if s < best_s:
                        m, best, best_s = cand, c, s
                continue
            g = space._genome_of(m)
            steps = 0
            while steps < steps_per_restart:
                k = min(self.chunk, steps_per_restart - steps)
                # Speculate k mutations of the CURRENT incumbent. The RNG
                # state before each draw is recorded so an accepted move
                # can rewind to exactly where the serial walk would be
                # (mutate is deterministic in (genome, rng state), so the
                # replayed prefix is byte-identical). Genome-level draws
                # consume the identical stream ``space.mutate`` would.
                states = []
                cands = []
                for _ in range(k):
                    states.append(rng.getstate())
                    cands.append(space.mutate_genome(g, rng))
                costs = engine.evaluate_batch(
                    GenomeBatch.from_genomes(space, cands),
                    incumbent=best_s,
                    probe=self.probe,
                )
                accepted = None
                for j, (cand, c) in enumerate(zip(cands, costs)):
                    if c is None:
                        continue  # bound-pruned: provably not an accepted move
                    tr.offer(cand, c)
                    s = c.metric(metric)
                    if s < best_s:
                        accepted = j
                        g, best, best_s = cand, c, s
                        break
                if accepted is None:
                    steps += k
                else:
                    # the serial walk would now mutate the NEW incumbent:
                    # count only the steps up to the accepted move and
                    # rewind the RNG past it, discarding the speculated tail
                    steps += accepted + 1
                    if accepted + 1 < k:
                        rng.setstate(states[accepted + 1])
        return tr.result()
