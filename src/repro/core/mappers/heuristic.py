"""Heuristic mapper: utilization-first greedy seed + local hill-climb.

The greedy seed spreads the largest problem dims spatially across the
spatial-capable levels (maximizing PE utilization, which Fig. 10 of the
paper shows dominates EDP), then temporal tiles are chosen to saturate
each level's memory. Hill-climbing refines with the shared mutation
operator, accepting only improvements.
"""

from __future__ import annotations

import math
import random
from typing import Dict, List, Optional

from repro.core.cost.base import CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapping import LevelMapping, Mapping
from repro.core.mapspace import MapSpace


class HeuristicMapper(Mapper):
    name = "heuristic"

    def __init__(self, climb_steps: int = 300, restarts: int = 3, seed: int = 0) -> None:
        self.climb_steps = climb_steps
        self.restarts = restarts
        self.seed = seed

    # ------------------------------------------------------------------ #
    def _greedy_seed(self, space: MapSpace, rng: random.Random) -> Mapping:
        problem, arch = space.problem, space.arch
        dims = dict(problem.dims)
        n = space.n_levels
        # remaining sizes to tile, per dim
        chains: Dict[str, List[int]] = {d: [] for d in dims}
        cur = dict(dims)
        for i in range(n):
            fan = space.child_fanout[i]
            # choose spatial factors for this level greedily from big dims
            st_factors = {d: 1 for d in dims}
            if fan > 1 and i < n - 1:
                budget = fan
                # sort dims by remaining size, prefer non-reduction dims for
                # outputs-stationarity but allow all
                for d in sorted(dims, key=lambda d: -cur[d]):
                    if budget <= 1:
                        break
                    if space.constraints is not None and not space.constraints._spatial_ok(
                        arch.clusters[i].name, d
                    ):
                        continue
                    f = math.gcd(cur[d], budget)
                    # largest divisor of cur[d] that divides budget
                    best = 1
                    for v in space._divs(cur[d]):
                        if budget % v == 0 and v > best:
                            best = v
                    f = best
                    if f > 1:
                        st_factors[d] = f
                        budget //= f
            for d in dims:
                tt = cur[d]  # temporal tile = whole remaining (stream at this level)
                st = tt // st_factors[d]
                chains[d].extend((tt, st))
                cur[d] = st
        levels = []
        for i, cl in enumerate(arch.clusters):
            tt = {d: chains[d][2 * i] for d in dims}
            st = {d: chains[d][2 * i + 1] for d in dims}
            levels.append(LevelMapping(cl.name, tuple(dims), tt, st))
        m = Mapping(levels, problem.name)
        # repair memory violations: shrink temporal tiles at offending levels
        for i, cl in enumerate(arch.clusters):
            if cl.virtual or cl.memory_bytes is None or i == 0:
                continue
            guard = 0
            while True:
                tile = {d: m.levels[i].tt(d) for d in dims}
                need = sum(ds.footprint_bytes(tile) for ds in problem.data_spaces)
                if need <= cl.memory_bytes or guard > 64:
                    break
                guard += 1
                # halve the biggest temporal tile dim (keeping divisibility)
                d = max(dims, key=lambda d: m.levels[i].tt(d))
                tt = m.levels[i].tt(d)
                smaller = [v for v in space._divs(tt) if v < tt]
                if not smaller:
                    break
                new_tt = max(smaller)
                # keep inner chain nested
                m.levels[i].temporal_tile_sizes[d] = new_tt
                m.levels[i].spatial_tile_sizes[d] = min(m.levels[i].st(d), new_tt)
                for j in range(i + 1, space.n_levels):
                    m.levels[j].temporal_tile_sizes[d] = min(
                        m.levels[j].tt(d), m.levels[j - 1].st(d)
                    )
                    m.levels[j].spatial_tile_sizes[d] = min(
                        m.levels[j].st(d), m.levels[j].tt(d)
                    )
        if m.is_legal(problem, arch):
            return m
        return space.random_mapping(rng)

    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
        engine: Optional[EvaluationEngine] = None,
    ) -> SearchResult:
        engine = self._mk_engine(space, cost_model, metric, engine)
        rng = random.Random(self.seed)
        tr = self._mk_result(metric, engine)
        for r in range(self.restarts):
            m = self._greedy_seed(space, rng) if r == 0 else space.random_mapping(rng)
            if space.constraints is not None and not space.constraints.ok(
                m, space.problem, space.arch
            ):
                m = space.random_mapping(rng)
            best = engine.evaluate(m)
            tr.offer(m, best)
            best_s = best.metric(metric)
            for _ in range(self.climb_steps // self.restarts):
                cand = space.mutate(m, rng)
                # prune against the LOCAL incumbent: a candidate whose bound
                # is >= the climb's best can neither be an accepted move nor
                # improve the global best (global <= local), so the walk is
                # unchanged vs. evaluating everything.
                c = engine.evaluate_admit(cand, incumbent=best_s)
                if c is None:
                    continue
                tr.offer(cand, c)
                s = c.metric(metric)
                if s < best_s:
                    m, best, best_s = cand, c, s
        return tr.result()
