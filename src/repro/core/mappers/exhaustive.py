"""Exhaustive (capped) map-space search.

Tilings stream out of the map-space in chunks; each chunk is admitted
against the current incumbent (a bound-dominated tiling can never become
the running minimum) and the survivors are batch-evaluated. The argmin
over the stream -- and the reported best mapping -- is exactly the one
serial evaluation finds.

Candidate generation is ARRAY-NATIVE whenever the space allows it
(canonical orders, no constraint set): the per-dim legal chain lists are
combined by vectorized mixed-radix index decoding + one masked legality
program per block (``genome_batch.exhaustive_genome_batches``), which
reproduces the recursive enumerator's candidate stream AND chunk
boundaries bit-for-bit -- results and engine counters are identical, no
seed-versioning needed. Sampled orders or constraints fall back to the
scalar generator."""

from __future__ import annotations

import itertools
from typing import List, Optional

from repro.core.cost.base import CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.device_loop import (
    device_loop_enabled,
    device_precompute,
    sync_cadence,
)
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapspace import MapSpace


class ExhaustiveMapper(Mapper):
    name = "exhaustive"

    def __init__(
        self,
        max_mappings: Optional[int] = 50_000,
        orders: str = "canonical",
        batch_size: int = 256,
        probe: int = 8,
        vectorized: bool = True,
    ) -> None:
        """``probe``: the engine-level warm start (see
        ``EvaluationEngine.evaluate_batch``) -- while no incumbent exists,
        the first ``probe`` candidates of a chunk are scored unpruned and
        their best seeds the bound filter for the rest (0 disables). The
        enumeration stream and the argmin are unaffected. ``vectorized``:
        use the array-native enumerator where applicable (bit-identical
        stream; False forces the scalar generator, the A/B reference)."""
        self.max_mappings = max_mappings
        self.orders = orders
        self.batch_size = batch_size
        self.probe = probe
        self.vectorized = vectorized

    def batch_hints(self) -> List[int]:
        return [self.probe, self.batch_size - self.probe, self.batch_size]

    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
        engine: Optional[EvaluationEngine] = None,
    ) -> SearchResult:
        engine = self._mk_engine(space, cost_model, metric, engine)
        tr = self._mk_result(metric, engine)
        if self.vectorized and self.orders == "canonical" and space.constraints is None:
            # device-resident window: buffer up to K enumerated chunks and
            # score them as ONE fused dispatch; each chunk replays through
            # the engine with its precomputed rows (admission against the
            # then-current incumbent), so the argmin and every counter
            # equal the chunk-at-a-time host loop. The enumeration stream
            # and chunk boundaries are untouched.
            window = sync_cadence() if device_loop_enabled(engine) else 1
            stream = space.enumerate_genome_batches(
                max_mappings=self.max_mappings, batch_size=self.batch_size
            )
            while True:
                batches = list(itertools.islice(stream, window))
                if not batches:
                    break
                pres = device_precompute(engine, batches) if window > 1 else None
                if pres is None:
                    pres = [None] * len(batches)
                for gb, pre in zip(batches, pres):
                    costs = engine.evaluate_batch(
                        gb,
                        incumbent=tr.best_metric_value,
                        probe=self.probe,
                        precomputed=pre,
                    )
                    for i, c in enumerate(costs):
                        if c is not None:
                            tr.offer_lazy(lambda b=i, g=gb: g.genome(b), c)
            return tr.result()
        stream = space.enumerate_genomes(max_mappings=self.max_mappings, orders=self.orders)
        while True:
            chunk = list(itertools.islice(stream, self.batch_size))
            if not chunk:
                break
            costs = engine.evaluate_batch(
                chunk, incumbent=tr.best_metric_value, probe=self.probe
            )
            for m, c in zip(chunk, costs):
                if c is not None:
                    tr.offer(m, c)
        return tr.result()
