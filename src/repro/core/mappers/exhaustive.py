"""Exhaustive (capped) map-space search."""

from __future__ import annotations

from typing import Optional

from repro.core.cost.base import CostModel
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapspace import MapSpace


class ExhaustiveMapper(Mapper):
    name = "exhaustive"

    def __init__(self, max_mappings: Optional[int] = 50_000, orders: str = "canonical") -> None:
        self.max_mappings = max_mappings
        self.orders = orders

    def search(self, space: MapSpace, cost_model: CostModel, metric: str = "edp") -> SearchResult:
        tr = self._mk_result(metric)
        for m in space.enumerate_tilings(max_mappings=self.max_mappings, orders=self.orders):
            cost = cost_model.evaluate(space.problem, m, space.arch)
            tr.offer(m, cost)
        return tr.result()
