"""Marvel-style decoupled mapper (paper [13]).

Phase 1 decouples the OFF-CHIP map-space: choose the outer-level tiling
that minimizes DRAM (outermost-memory) traffic. Phase 2 searches the
ON-CHIP levels conditioned on each of the top-k off-chip prefixes.

``seed_version=2`` (default) runs both phases ARRAY-NATIVE: phase 1 draws
its sample population as one vectorized
:class:`~repro.core.genome_batch.GenomeBatch` and ranks DRAM traffic with
ONE ``signature_traffic_batch`` array program (previously each sample paid
a full per-candidate ``analyze``); phase 2 re-samples the on-chip levels
below each retained prefix as a conditional batch draw and submits the
legal rows as one GenomeBatch per prefix. Generation is all-numpy
(counter-based Philox draws), so fixed-seed searches are bit-identical
across scalar/numpy/jax engine backends. ``seed_version=1`` preserves the
historical per-candidate stream exactly.
"""

from __future__ import annotations

import random
from typing import List, Optional, Tuple

import numpy as np

from repro.core import genome_batch as gbm
from repro.core.cost.analysis import BATCH_EXACT_LIMIT, analyze, get_context
from repro.core.cost.base import CostModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.mappers.base import Mapper, SearchResult
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace


class DecoupledMapper(Mapper):
    name = "decoupled"

    def __init__(
        self,
        offchip_samples: int = 400,
        onchip_samples: int = 400,
        top_k: int = 4,
        seed: int = 0,
        probe: int = 8,
        seed_version: int = 2,
    ) -> None:
        """``probe``: the engine-level warm start (see
        ``EvaluationEngine.evaluate_batch``) -- while the incumbent is
        still infinite, the first ``probe`` candidates of a phase-2 batch
        are scored unpruned and their best seeds the bound filter for the
        rest (0 disables). Candidate order is unchanged and pruning is
        exact, so results are identical for any ``probe``.
        ``seed_version``: 2 for the vectorized batch pipeline (default),
        1 for the historical scalar stream."""
        self.offchip_samples = offchip_samples
        self.onchip_samples = onchip_samples
        self.top_k = top_k
        self.seed = seed
        self.probe = probe
        self.seed_version = seed_version

    def batch_hints(self) -> List[int]:
        per_prefix = max(1, self.onchip_samples // max(1, self.top_k))
        return [self.probe, per_prefix, per_prefix - self.probe]

    # ------------------------------------------------------------------ #
    def _split_level(self, space: MapSpace) -> int:
        """The off-chip boundary: everything above the first level with
        fanout > 1."""
        split = next(
            (i for i, f in enumerate(space.child_fanout) if f > 1),
            1,
        )
        return max(1, split)

    def _dram_traffic(self, space: MapSpace, m: Mapping) -> float:
        prof = analyze(space.problem, m, space.arch)
        total = 0.0
        # traffic served by the outermost (DRAM) level = parent_reads/writes
        # of the first non-virtual level below it
        for ds in space.problem.data_spaces:
            for i in range(1, space.arch.n_levels):
                lt = prof.traffic.get((ds.name, i))
                if lt is None:
                    continue
                total += (lt.parent_reads + lt.parent_writes) * ds.word_bytes
                break  # first real level below DRAM only
        return total

    def _dram_traffic_batch(self, space: MapSpace, gb) -> np.ndarray:
        """Phase-1 ranking criterion for a whole GenomeBatch as ONE array
        program: the stacked reuse analysis already exposes per-level
        parent reads/writes, so the per-candidate ``analyze`` walk
        disappears. Falls back per candidate when the batch program
        declines or any consumed value reaches the float64-exact limit
        (the same BATCH_EXACT_LIMIT guard every other batch consumer
        applies), so the ranking always equals the scalar walk's."""
        ctx = get_context(space.problem, space.arch)
        bt = ctx.signature_traffic_batch(stacked=gb.stacked())
        total = None
        if bt is not None:
            lvl = next((i for i in ctx.real_levels if i >= 1), None)
            if lvl is None:
                return np.zeros(len(gb))
            pos = ctx.real_levels.index(lvl)
            total = np.zeros(len(gb), dtype=np.float64)
            mx = 0.0
            for k, ds in enumerate(space.problem.data_spaces):
                r = bt.rows[k]
                term = (
                    r.parent_reads[:, pos] + r.parent_writes[:, pos]
                ) * ds.word_bytes
                mx = max(
                    mx,
                    float(r.parent_reads[:, pos].max(initial=0.0)),
                    float(r.parent_writes[:, pos].max(initial=0.0)),
                    float(term.max(initial=0.0)),
                )
                total += term
            if not (mx < BATCH_EXACT_LIMIT):
                total = None  # exactness not guaranteed: scalar walk
        if total is None:
            return np.asarray(
                [
                    self._dram_traffic(space, gb.genome(b).to_mapping())
                    for b in range(len(gb))
                ]
            )
        return total

    # ------------------------------------------------------------------ #
    def _search_v2(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str,
        engine: Optional[EvaluationEngine],
    ) -> SearchResult:
        engine = self._mk_engine(space, cost_model, metric, engine)
        tr = self._mk_result(metric, engine)
        rng = gbm.philox_rng(self.seed)
        split = self._split_level(space)
        # Phase 1: one vectorized sample batch, one traffic array program
        gb = gbm.random_genome_batch(space, rng, self.offchip_samples)
        traffic = self._dram_traffic_batch(space, gb)
        ranked = np.argsort(traffic, kind="stable")
        seen_prefix = set()
        prefix_rows: List[int] = []
        for b in ranked.tolist():
            key = gb.tt[b, :split].tobytes() + gb.st[b, :split].tobytes()
            if key not in seen_prefix:
                seen_prefix.add(key)
                prefix_rows.append(b)
            if len(prefix_rows) >= self.top_k:
                break
        # Phase 2: conditional on-chip batches per prefix
        per_prefix = max(1, self.onchip_samples // max(1, len(prefix_rows)))
        for b in prefix_rows:
            tt, st, perm = gbm.resample_inner_rows(
                space, rng, gb.tt[b], gb.st[b], gb.perm[b], split, per_prefix
            )
            ok = gbm.legal_batch(space, tt, st, perm, structured=True)
            keep = np.flatnonzero(ok)
            if keep.size == 0:
                continue
            sub = gbm.GenomeBatch(space, tt[keep], st[keep], perm[keep])
            costs = engine.evaluate_batch(
                sub, incumbent=tr.best_metric_value, probe=self.probe
            )
            for i, c in enumerate(costs):
                if c is not None:
                    tr.offer_lazy(lambda r=i, g=sub: g.genome(r), c)
        if tr.best_mapping is None:  # fall back to the best phase-1 candidate
            b = int(ranked[0])
            g = gb.genome(b)
            tr.offer(g, engine.evaluate(g))
        return tr.result()

    # ------------------------------------------------------------------ #
    def _resample_inner(
        self, space: MapSpace, base: Mapping, rng: random.Random, split_level: int
    ) -> Mapping:
        """Keep levels [0, split_level) of `base`, resample the rest."""
        m = base.clone()
        for d in space.dims:
            cur = m.levels[split_level - 1].st(d) if split_level > 0 else space.problem.dims[d]
            for i in range(split_level, space.n_levels):
                tt = rng.choice([v for v in space._divs(cur)])
                spatial_ok = (
                    space.child_fanout[i] > 1
                    and i < space.n_levels - 1
                    and (space.constraints is None
                         or space.constraints._spatial_ok(space.arch.clusters[i].name, d))
                )
                st = rng.choice([v for v in space._divs(tt)]) if spatial_ok else tt
                if i == space.n_levels - 1:
                    st = tt
                m.levels[i].temporal_tile_sizes[d] = tt
                m.levels[i].spatial_tile_sizes[d] = st
                cur = st
        for i in range(split_level, space.n_levels):
            order = list(space.dims)
            rng.shuffle(order)
            m.levels[i].temporal_order = tuple(order)
        return m

    def search(
        self,
        space: MapSpace,
        cost_model: CostModel,
        metric: str = "edp",
        engine: Optional[EvaluationEngine] = None,
    ) -> SearchResult:
        if self.seed_version >= 2:
            return self._search_v2(space, cost_model, metric, engine)
        engine = self._mk_engine(space, cost_model, metric, engine)
        rng = random.Random(self.seed)
        tr = self._mk_result(metric, engine)
        split = self._split_level(space)
        # Phase 1: rank off-chip prefixes by DRAM traffic
        cands: List[Tuple[float, Mapping]] = []
        for _ in range(self.offchip_samples):
            m = space.random_mapping(rng)
            cands.append((self._dram_traffic(space, m), m))
        cands.sort(key=lambda t: t[0])
        seen_prefix = set()
        prefixes: List[Mapping] = []
        for _, m in cands:
            key = tuple(
                (m.levels[i].tt(d), m.levels[i].st(d))
                for i in range(split)
                for d in space.dims
            )
            if key not in seen_prefix:
                seen_prefix.add(key)
                prefixes.append(m)
            if len(prefixes) >= self.top_k:
                break
        # Phase 2: on-chip search conditioned on each prefix. Candidates are
        # generated (RNG-only) and legality-filtered first, then the batch is
        # admitted against the incumbent and evaluated through the engine.
        per_prefix = max(1, self.onchip_samples // max(1, len(prefixes)))
        for base in prefixes:
            batch: List[Mapping] = []
            for _ in range(per_prefix):
                m = self._resample_inner(space, base, rng, split)
                if not m.is_legal(space.problem, space.arch):
                    continue
                if space.constraints is not None and not space.constraints.ok(
                    m, space.problem, space.arch
                ):
                    continue
                batch.append(m)
            costs = engine.evaluate_batch(
                batch, incumbent=tr.best_metric_value, probe=self.probe
            )
            for m, cost in zip(batch, costs):
                if cost is not None:
                    tr.offer(m, cost)
        if tr.best_mapping is None:  # fall back to the best phase-1 candidate
            m = cands[0][1]
            tr.offer(m, engine.evaluate(m))
        return tr.result()
