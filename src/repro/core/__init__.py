"""Union core: the paper's primary contribution.

Unified abstractions (paper Sec. IV):
  problem       -- tensor operation as dims + data-spaces + affine projections
  architecture  -- logical cluster-target hardware description
  mapping       -- cluster-target loop-centric mapping + legality rules
  mapspace      -- map-space enumeration with pruning
  constraints   -- user constraint files (paper Sec. IV-E)
  cost          -- plug-and-play cost models (Timeloop-like, MAESTRO-like, roofline)
  mappers       -- plug-and-play mappers (exhaustive/random/decoupled/genetic/heuristic)
  ir            -- mini-MLIR dialect stack + lowering + TTGT + conformability
"""

from repro.core.problem import Problem, DataSpace, AffineExpr, Term  # noqa: F401
from repro.core.architecture import Architecture, Cluster  # noqa: F401
from repro.core.mapping import Mapping, LevelMapping  # noqa: F401
