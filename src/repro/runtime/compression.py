"""Gradient compression for the cross-pod all-reduce (int8 + error feedback).

The pod axis crosses DCN (25 GB/s) instead of ICI (50 GB/s/link x ring),
so the per-step gradient all-reduce over 'pod' is the one collective worth
compressing 4x. Scheme: per-tensor symmetric int8 quantization with an
error-feedback residual (Seide et al. / 1-bit SGD lineage) so the
quantization bias does not accumulate:

    g_eff = g + residual
    q     = quantize(g_eff);  residual' = g_eff - dequantize(q)
    ĝ     = psum(dequantize(q)) / N      (wire: int8, 4x fewer bytes)

``make_compressed_allreduce`` returns a function usable inside shard_map
over the pod axis; tests verify the error-feedback contraction property
and end-to-end convergence parity on a toy model.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def error_feedback_update(
    g: jnp.ndarray, residual: Optional[jnp.ndarray]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q, scale, new_residual, dequantized_local)."""
    g_eff = g.astype(jnp.float32) + (residual if residual is not None else 0.0)
    q, scale = compress_int8(g_eff)
    deq = decompress_int8(q, scale)
    return q, scale, g_eff - deq, deq


def make_compressed_allreduce(axis_name: str):
    """psum of int8-compressed values over ``axis_name`` (inside shard_map).

    Wire traffic: the int8 payload + one f32 scale per tensor. The psum
    itself runs on the dequantized f32 (XLA has no int8 all-reduce with
    per-participant scales); on the real fabric the int8+scale pair is
    what crosses DCN -- we model the byte count, which is what the
    roofline collective term consumes.
    """

    def allreduce(g: jnp.ndarray, residual: jnp.ndarray):
        q, scale, new_res, deq = error_feedback_update(g, residual)
        n = jax.lax.psum(1, axis_name)
        avg = jax.lax.psum(deq, axis_name) / n
        return avg.astype(g.dtype), new_res

    return allreduce


def compressed_wire_bytes(tree) -> int:
    """Bytes crossing the link per participant with int8+scale encoding."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += leaf.size * 1 + 4  # int8 payload + f32 scale
    return total


def raw_wire_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))
