"""Fault tolerance: retry-on-failure, deadlines, straggler mitigation.

At 1000+-node scale the failure model is: (a) a unit of work raises
(device OOM, preempted host, interconnect error) -> retry from the last
good state, a bounded number of times, then restore from checkpoint;
(b) a unit of work hangs or straggles -> a watchdog detects a missed
deadline, the caller abandons the dispatch and re-runs (on a real cluster
this is where the workload manager would also re-slice the mesh -- see
elastic.plan_mesh).

The module is split into a GENERIC core and the train-step wrapper built
on it:

  * :func:`call_with_deadline` -- run any callable under a watchdog
    deadline (raises :class:`CallTimeoutError` on a miss);
  * :class:`RetryPolicy` / :func:`retry_call` -- bounded retries with
    exponential backoff and DETERMINISTIC jitter (hashed from the call
    label + attempt, so concurrent retry storms de-synchronize without
    randomness that would break reproducible tests);
  * :class:`StragglerMeter` -- moving-average straggler detection;
  * :class:`CircuitBreaker` -- closed/open/half-open breaker with a
    deterministic (count-based) probe schedule, the stateful recoverable
    form of the sweep executor's one-way backend degradation (used by
    ``repro.serve.mapping_service`` around the jax engine backend and
    optionally by ``EvaluationEngine._check_backend_degraded``);
  * :class:`FaultTolerantRunner` -- the training-loop shape (step_fn +
    checkpoint restore) expressed through the core above.

The same core drives the mapping-sweep executor
(``repro.core.sweep_exec``): group dispatches are wrapped in
``retry_call`` with a per-group deadline, which is why the core lives
here rather than inside the runner. This is the single-controller
analogue of what multi-controller JAX does with coordinator heartbeats;
the control flow is identical and exercised on CPU by the tests via
fault injection hooks.

This module deliberately does NOT import jax at module scope: sweep
worker processes import the retry core on the numpy-only path, and a
multi-second jax import per spawned worker would erase the concurrency
win.
"""

from __future__ import annotations

import hashlib
import logging
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

log = logging.getLogger("repro.runtime")


class CallTimeoutError(RuntimeError):
    """A watchdogged callable missed its deadline."""


class StepTimeoutError(CallTimeoutError):
    """Back-compat alias: a training step missed its deadline."""


# ------------------------------------------------------------------ #
# Generic watchdog / retry core
# ------------------------------------------------------------------ #
def call_with_deadline(fn: Callable[[], Any], deadline_s: Optional[float],
                       label: str = "call"):
    """Run ``fn()`` under a watchdog deadline.

    ``deadline_s=None`` calls inline (no thread). Otherwise the callable
    runs in a named daemon thread; a missed deadline raises
    :class:`CallTimeoutError` and the thread is ABANDONED (there is no
    portable way to cancel arbitrary Python work -- the thread keeps the
    GIL-yielding work alive until it returns, which is why hung work must
    itself be bounded, e.g. an injected hang sleeps past the deadline but
    not forever). On a completed call the thread is joined promptly, so
    an early exit never leaves a live watchdog behind.
    """
    if deadline_s is None:
        return fn()
    done = threading.Event()
    box: Dict[str, Any] = {}

    def work():
        try:
            box["out"] = fn()
        except BaseException as e:  # re-raised in the caller below
            box["err"] = e
        finally:
            done.set()

    th = threading.Thread(target=work, name=f"deadline:{label}", daemon=True)
    th.start()
    if not done.wait(deadline_s):
        raise CallTimeoutError(f"{label} exceeded {deadline_s}s deadline")
    th.join()  # finished: reap promptly, no lingering thread on early exit
    if "err" in box:
        raise box["err"]
    return box.get("out")


@dataclass
class RetryPolicy:
    """Bounded-retry + deadline + backoff policy for one unit of work."""

    max_retries: int = 2                 # re-runs after the first attempt
    deadline_s: Optional[float] = None   # per-attempt watchdog (None = off)
    backoff_s: float = 0.0               # base backoff; exponential per retry
    backoff_cap_s: float = 30.0
    jitter: float = 0.25                 # +/- fraction of the backoff


@dataclass
class RetryStats:
    """Counters accumulated by :func:`retry_call` (shareable across calls)."""

    attempts: int = 0
    retries: int = 0
    timeouts: int = 0
    backoff_total_s: float = 0.0
    errors: List[str] = field(default_factory=list)


def backoff_delay(policy: RetryPolicy, attempt: int, label: str) -> float:
    """Exponential backoff with deterministic jitter.

    The jitter is hashed from (label, attempt), NOT drawn from a global
    RNG: retrying groups of a sweep de-synchronize from each other (their
    labels differ) while every run of the same sweep behaves identically
    -- a requirement for the crash/resume byte-identity tests.
    """
    base = min(policy.backoff_cap_s, policy.backoff_s * (2 ** (attempt - 1)))
    if base <= 0:
        return 0.0
    h = hashlib.sha256(f"{label}:{attempt}".encode()).digest()
    u = int.from_bytes(h[:8], "big") / 2**64
    return base * (1.0 + policy.jitter * (2.0 * u - 1.0))


def retry_call(
    fn: Callable[[int], Any],
    policy: Optional[RetryPolicy] = None,
    *,
    label: str = "call",
    attempt_hook: Optional[Callable[[int], None]] = None,
    on_error: Optional[Callable[[int, BaseException], None]] = None,
    stats: Optional[RetryStats] = None,
    sleep: Callable[[float], None] = time.sleep,
):
    """Run ``fn(attempt)`` under ``policy``: per-attempt deadline, bounded
    retries, exponential backoff with deterministic jitter.

    ``attempt_hook(attempt)`` runs before each attempt and may raise --
    the fault-injection point the tests (and ``UNION_FAULT_SPEC``) use.
    ``on_error(attempt, exc)`` observes each failure before the retry
    decision. Returns ``(result, RetryStats)``; raises the last error
    once retries are exhausted. Pass ``stats`` to accumulate counters
    across several calls (e.g. one sweep-wide ledger).
    """
    policy = policy or RetryPolicy()
    st = stats if stats is not None else RetryStats()
    attempt = 0
    while True:
        st.attempts += 1
        try:
            if attempt_hook is not None:
                attempt_hook(attempt)
            out = call_with_deadline(
                lambda: fn(attempt), policy.deadline_s, label=f"{label}#{attempt}"
            )
            return out, st
        except Exception as e:  # noqa: BLE001 -- deliberate catch-all
            if isinstance(e, CallTimeoutError):
                st.timeouts += 1
            st.errors.append(f"{type(e).__name__}: {e}")
            if on_error is not None:
                on_error(attempt, e)
            log.warning("%s failed (%s: %s), attempt %d/%d", label,
                        type(e).__name__, e, attempt + 1,
                        policy.max_retries + 1)
            if attempt >= policy.max_retries:
                raise
            st.retries += 1
            attempt += 1
            d = backoff_delay(policy, attempt, label)
            if d > 0:
                st.backoff_total_s += d
                sleep(d)


class CircuitBreaker:
    """Closed/open/half-open circuit breaker with a DETERMINISTIC probe
    schedule.

    The sweep executor's backend degradation (PR 6) was one-way: a jax
    failure flipped the engine to numpy for the rest of its life. A
    long-lived process (the mapping-service daemon) needs the stateful,
    recoverable version: ``failure_threshold`` consecutive failures OPEN
    the circuit (callers take the fallback path without touching the
    protected backend), every ``probe_interval``-th denied call
    transitions to HALF-OPEN and admits exactly one probe, and the
    probe's outcome either CLOSES the circuit (recovery) or re-opens it
    (the probe counter restarts).

    The probe schedule counts *denied calls*, not wall-clock: tests (and
    the deterministic fault-injection drills) step the breaker through
    open -> half-open -> closed without sleeping, and two runs of the
    same request stream always probe at the same points. An optional
    ``cooldown_s`` adds a wall-clock floor between probes for production
    use (``clock`` is injectable for tests); by default it is 0 and the
    schedule is purely count-based.

    Thread-safe: the daemon's worker threads share one breaker. State
    transitions are recorded in ``transitions`` (capped) so services can
    export them as metrics.
    """

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"

    def __init__(
        self,
        failure_threshold: int = 3,
        probe_interval: int = 4,
        cooldown_s: float = 0.0,
        clock: Callable[[], float] = time.monotonic,
        label: str = "breaker",
    ) -> None:
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if probe_interval < 1:
            raise ValueError("probe_interval must be >= 1")
        self.failure_threshold = failure_threshold
        self.probe_interval = probe_interval
        self.cooldown_s = cooldown_s
        self.clock = clock
        self.label = label
        self.state = self.CLOSED
        self._lock = threading.Lock()
        self._consecutive_failures = 0
        self._denied_since_probe = 0
        self._opened_at = 0.0
        # counters / transition log (metrics surface)
        self.failures = 0
        self.successes = 0
        self.denied = 0
        self.probes = 0
        self.opened = 0
        self.recovered = 0
        self.transitions: List[str] = []

    # -------------------------------------------------------------- #
    def _transition(self, new_state: str) -> None:
        if new_state != self.state:
            self.transitions.append(f"{self.state}->{new_state}")
            del self.transitions[:-64]  # cap the log, keep the newest
            self.state = new_state

    def allow(self) -> bool:
        """May the protected backend be tried right now?

        CLOSED: always. OPEN: deny, but every ``probe_interval``-th
        denied call (past any ``cooldown_s``) flips to HALF-OPEN and
        admits that call as the single probe. HALF-OPEN: deny (one probe
        is already in flight; its record_success/record_failure decides).
        """
        with self._lock:
            if self.state == self.CLOSED:
                return True
            if self.state == self.HALF_OPEN:
                self.denied += 1
                return False
            # OPEN
            if self.cooldown_s and (
                self.clock() - self._opened_at < self.cooldown_s
            ):
                self.denied += 1
                return False
            self._denied_since_probe += 1
            if self._denied_since_probe >= self.probe_interval:
                self._denied_since_probe = 0
                self.probes += 1
                self._transition(self.HALF_OPEN)
                log.warning("%s: half-open probe admitted", self.label)
                return True
            self.denied += 1
            return False

    def record_success(self) -> None:
        """A protected call completed: close from half-open (recovery),
        reset the consecutive-failure count when already closed."""
        with self._lock:
            self.successes += 1
            self._consecutive_failures = 0
            if self.state == self.HALF_OPEN:
                self.recovered += 1
                self._transition(self.CLOSED)
                log.warning("%s: probe succeeded -- circuit CLOSED", self.label)

    def record_failure(self) -> None:
        """A protected call failed: re-open from half-open (the probe
        lost), or open once ``failure_threshold`` consecutive closed-state
        failures accumulate."""
        with self._lock:
            self.failures += 1
            if self.state == self.HALF_OPEN:
                self.opened += 1
                self._opened_at = self.clock()
                self._denied_since_probe = 0
                self._transition(self.OPEN)
                log.warning("%s: probe failed -- circuit re-OPENED", self.label)
                return
            self._consecutive_failures += 1
            if (
                self.state == self.CLOSED
                and self._consecutive_failures >= self.failure_threshold
            ):
                self.opened += 1
                self._opened_at = self.clock()
                self._denied_since_probe = 0
                self._transition(self.OPEN)
                log.warning(
                    "%s: %d consecutive failures -- circuit OPEN",
                    self.label, self._consecutive_failures,
                )

    def stats_dict(self) -> dict:
        with self._lock:
            return {
                "state": self.state,
                "failures": self.failures,
                "successes": self.successes,
                "denied": self.denied,
                "probes": self.probes,
                "opened": self.opened,
                "recovered": self.recovered,
                "transitions": list(self.transitions),
            }


class StragglerMeter:
    """Moving-average straggler detection: flags a duration slower than
    ``slack`` x the average of the last ``window`` durations."""

    def __init__(self, window: int = 20, slack: float = 3.0) -> None:
        self.window = window
        self.slack = slack
        self._durations: List[float] = []
        self.flagged = 0

    def note(self, dt: float) -> bool:
        w = self._durations[-self.window:]
        straggler = bool(w) and dt > self.slack * (sum(w) / len(w))
        self._durations.append(dt)
        if straggler:
            self.flagged += 1
        return straggler

    def avg(self) -> float:
        w = self._durations[-self.window:]
        return sum(w) / max(1, len(w))


# ------------------------------------------------------------------ #
# Train-step runner (the original shape, now on the shared core)
# ------------------------------------------------------------------ #
@dataclass
class RunnerConfig:
    max_retries_per_step: int = 2       # transient-failure retries
    max_restores: int = 3               # checkpoint restores before giving up
    step_timeout_s: Optional[float] = None  # straggler deadline (None = off)
    # moving-average straggler detection: flag steps slower than
    # slack * avg of the last window steps
    straggler_window: int = 20
    straggler_slack: float = 3.0


@dataclass
class StepStats:
    step: int
    seconds: float
    retried: int       # failed attempts before this success (CUMULATIVE
    #                    across checkpoint restores -- a step that burned
    #                    its retry budget, restored, then succeeded reports
    #                    every failed attempt, not the post-restore count)
    straggler: bool


class FaultTolerantRunner:
    """Wraps a compiled step function with retry/restore/straggler logic.

    ``step_fn(state, batch) -> (state, metrics)`` must be functional: on
    failure we simply re-invoke it with the same (state, batch). With
    donated buffers a failed dispatch may have invalidated ``state``, so
    the runner keeps ``state`` alive via a host-side keepalive policy:
    donation is only enabled when a checkpoint manager is provided.

    The watchdog/retry mechanics live in the module-level core
    (:func:`call_with_deadline`); this class adds the training-specific
    parts: checkpoint restore as the last line of defense, and per-step
    stats. The runner is reusable across steps: per-step retry budgets
    reset at every ``run_step`` call, and a completed (or failed) step
    leaves no live watchdog thread behind.
    """

    def __init__(
        self,
        step_fn: Callable,
        cfg: RunnerConfig = RunnerConfig(),
        *,
        checkpoint_manager=None,
        restore_fn: Optional[Callable] = None,  # () -> (state, step)
        fault_hook: Optional[Callable[[int], None]] = None,  # test injection
    ) -> None:
        self.step_fn = step_fn
        self.cfg = cfg
        self.ckpt = checkpoint_manager
        self.restore_fn = restore_fn
        self.fault_hook = fault_hook
        self._meter = StragglerMeter(cfg.straggler_window, cfg.straggler_slack)
        self._restores = 0
        self.stats: list[StepStats] = []

    # ---------------------------------------------------------------- #
    def _block(self, tree) -> None:
        import jax  # deferred: keeps the retry core importable without jax

        for leaf in jax.tree.leaves(tree):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()

    def _run_once(self, state, batch, step: int):
        """One dispatch with an optional watchdog deadline."""
        if self.fault_hook is not None:
            self.fault_hook(step)  # may raise (injected fault)

        def dispatch():
            out = self.step_fn(state, batch)
            self._block(out)
            return out

        try:
            return call_with_deadline(
                dispatch, self.cfg.step_timeout_s, label=f"step{step}"
            )
        except CallTimeoutError as e:
            raise StepTimeoutError(str(e)) from None

    # ---------------------------------------------------------------- #
    def run_step(self, state, batch, step: int):
        """Returns (new_state, metrics). Raises only after exhausting both
        retries and checkpoint restores."""
        budget_used = 0     # retries since the last restore (the budget)
        failed_attempts = 0  # cumulative, for stats
        while True:
            t0 = time.time()
            try:
                out = self._run_once(state, batch, step)
                dt = time.time() - t0
                straggler = self._meter.note(dt)
                if straggler:
                    log.warning("step %d straggled: %.2fs (avg %.2fs)",
                                step, dt, self._meter.avg())
                self.stats.append(StepStats(step, dt, failed_attempts, straggler))
                return out
            except Exception as e:  # noqa: BLE001 -- deliberate catch-all
                budget_used += 1
                failed_attempts += 1
                log.warning("step %d failed (%s: %s), retry %d/%d",
                            step, type(e).__name__, e, budget_used,
                            self.cfg.max_retries_per_step)
                if budget_used <= self.cfg.max_retries_per_step:
                    continue
                if self.restore_fn is not None and self._restores < self.cfg.max_restores:
                    self._restores += 1
                    log.warning("restoring from checkpoint (restore %d/%d)",
                                self._restores, self.cfg.max_restores)
                    state, _ = self.restore_fn()
                    budget_used = 0  # fresh budget; failed_attempts keeps history
                    continue
                raise
