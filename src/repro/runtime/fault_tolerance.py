"""Fault tolerance: retry-on-failure, heartbeats, straggler mitigation.

At 1000+-node scale the failure model is: (a) a step raises (device OOM,
preempted host, interconnect error) -> retry from the last good state, a
bounded number of times, then restore from checkpoint; (b) a step hangs or
straggles -> a watchdog thread detects a missed deadline, the runner
cancels/abandons the dispatch and re-runs (on a real cluster this is where
the workload manager would also re-slice the mesh -- see elastic.plan_mesh).

This is the single-controller analogue of what multi-controller JAX does
with coordinator heartbeats; the control flow is identical and exercised
on CPU by the tests via fault injection hooks.
"""

from __future__ import annotations

import dataclasses
import logging
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax

log = logging.getLogger("repro.runtime")


class StepTimeoutError(RuntimeError):
    pass


@dataclass
class RunnerConfig:
    max_retries_per_step: int = 2       # transient-failure retries
    max_restores: int = 3               # checkpoint restores before giving up
    step_timeout_s: Optional[float] = None  # straggler deadline (None = off)
    # moving-average straggler detection: flag steps slower than
    # slack * avg of the last window steps
    straggler_window: int = 20
    straggler_slack: float = 3.0


@dataclass
class StepStats:
    step: int
    seconds: float
    retried: int
    straggler: bool


class FaultTolerantRunner:
    """Wraps a compiled step function with retry/restore/straggler logic.

    ``step_fn(state, batch) -> (state, metrics)`` must be functional: on
    failure we simply re-invoke it with the same (state, batch). With
    donated buffers a failed dispatch may have invalidated ``state``, so
    the runner keeps ``state`` alive via a host-side keepalive policy:
    donation is only enabled when a checkpoint manager is provided.
    """

    def __init__(
        self,
        step_fn: Callable,
        cfg: RunnerConfig = RunnerConfig(),
        *,
        checkpoint_manager=None,
        restore_fn: Optional[Callable] = None,  # () -> (state, step)
        fault_hook: Optional[Callable[[int], None]] = None,  # test injection
    ) -> None:
        self.step_fn = step_fn
        self.cfg = cfg
        self.ckpt = checkpoint_manager
        self.restore_fn = restore_fn
        self.fault_hook = fault_hook
        self._durations: list[float] = []
        self._restores = 0
        self.stats: list[StepStats] = []

    # ---------------------------------------------------------------- #
    def _block(self, tree) -> None:
        for leaf in jax.tree.leaves(tree):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()

    def _run_once(self, state, batch, step: int):
        """One dispatch with an optional watchdog deadline."""
        if self.fault_hook is not None:
            self.fault_hook(step)  # may raise (injected fault)
        timeout = self.cfg.step_timeout_s
        if timeout is None:
            out = self.step_fn(state, batch)
            self._block(out)
            return out
        result: Dict[str, Any] = {}
        err: Dict[str, BaseException] = {}

        def work():
            try:
                out = self.step_fn(state, batch)
                self._block(out)
                result["out"] = out
            except BaseException as e:  # propagated below
                err["e"] = e

        th = threading.Thread(target=work, daemon=True)
        th.start()
        th.join(timeout)
        if th.is_alive():
            raise StepTimeoutError(f"step {step} exceeded {timeout}s deadline")
        if "e" in err:
            raise err["e"]
        return result["out"]

    # ---------------------------------------------------------------- #
    def run_step(self, state, batch, step: int):
        """Returns (new_state, metrics). Raises only after exhausting both
        retries and checkpoint restores."""
        retries = 0
        while True:
            t0 = time.time()
            try:
                out = self._run_once(state, batch, step)
                dt = time.time() - t0
                straggler = self._note_duration(dt)
                if straggler:
                    log.warning("step %d straggled: %.2fs (avg %.2fs)",
                                step, dt, self._avg())
                self.stats.append(StepStats(step, dt, retries, straggler))
                return out
            except Exception as e:  # noqa: BLE001 -- deliberate catch-all
                retries += 1
                log.warning("step %d failed (%s: %s), retry %d/%d",
                            step, type(e).__name__, e, retries,
                            self.cfg.max_retries_per_step)
                if retries <= self.cfg.max_retries_per_step:
                    continue
                if self.restore_fn is not None and self._restores < self.cfg.max_restores:
                    self._restores += 1
                    log.warning("restoring from checkpoint (restore %d/%d)",
                                self._restores, self.cfg.max_restores)
                    state, _ = self.restore_fn()
                    retries = 0
                    continue
                raise

    # ---------------------------------------------------------------- #
    def _note_duration(self, dt: float) -> bool:
        w = self._durations[-self.cfg.straggler_window:]
        straggler = bool(w) and dt > self.cfg.straggler_slack * (sum(w) / len(w))
        self._durations.append(dt)
        return straggler

    def _avg(self) -> float:
        w = self._durations[-self.cfg.straggler_window:]
        return sum(w) / max(1, len(w))
