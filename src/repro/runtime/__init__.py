"""Runtime: fault tolerance, gradient compression, elastic mesh planning.

Attribute access is lazy (PEP 562): ``repro.runtime.fault_tolerance``
holds the jax-free watchdog/retry core that sweep worker PROCESSES import
on the numpy path, and an eager ``from .compression import ...`` here
would drag the multi-second jax import into every spawned worker.
``from repro.runtime import FaultTolerantRunner`` etc. keep working
unchanged -- the submodule is imported on first attribute access.
"""

_EXPORTS = {
    "FaultTolerantRunner": "repro.runtime.fault_tolerance",
    "RunnerConfig": "repro.runtime.fault_tolerance",
    "StepTimeoutError": "repro.runtime.fault_tolerance",
    "CallTimeoutError": "repro.runtime.fault_tolerance",
    "RetryPolicy": "repro.runtime.fault_tolerance",
    "RetryStats": "repro.runtime.fault_tolerance",
    "retry_call": "repro.runtime.fault_tolerance",
    "call_with_deadline": "repro.runtime.fault_tolerance",
    "StragglerMeter": "repro.runtime.fault_tolerance",
    "compress_int8": "repro.runtime.compression",
    "decompress_int8": "repro.runtime.compression",
    "error_feedback_update": "repro.runtime.compression",
    "make_compressed_allreduce": "repro.runtime.compression",
    "plan_mesh": "repro.runtime.elastic",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.runtime' has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(mod), name)
    globals()[name] = value  # cache: subsequent access skips __getattr__
    return value


def __dir__():
    return __all__
