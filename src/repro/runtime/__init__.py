from repro.runtime.fault_tolerance import (  # noqa: F401
    FaultTolerantRunner,
    RunnerConfig,
    StepTimeoutError,
)
from repro.runtime.compression import (  # noqa: F401
    compress_int8,
    decompress_int8,
    error_feedback_update,
    make_compressed_allreduce,
)
from repro.runtime.elastic import plan_mesh  # noqa: F401
