"""Elastic mesh planning: rebuild the (pod, data, model) mesh from whatever
devices survive, keeping TP intact and shrinking DP.

Policy: the 'model' axis encodes intra-operator sharding whose degree is
baked into layer shapes' divisibility -- changing it invalidates the
compiled program AND the weight layout, so elasticity preserves `model`
and re-plans (pod, data) from the surviving chip count. The checkpoint
layer re-places saved (unsharded) leaves under the new mesh, so a job
saved on 2x16x16 restarts cleanly on e.g. 1x12x16.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh


def plan_mesh(
    n_devices: int,
    *,
    model: int = 16,
    prefer_pods: int = 2,
    devices: Optional[Sequence] = None,
) -> Mesh:
    """Largest (pod, data, model) mesh fitting n_devices with fixed TP."""
    if n_devices < model:
        # degenerate small-host case (CPU tests): shrink TP to fit
        model = math.gcd(n_devices, model) or 1
    chips_per_pod_max = n_devices // prefer_pods
    pods = prefer_pods
    if chips_per_pod_max < model:
        pods = 1
    data = (n_devices // pods) // model
    if data < 1:
        pods, data = 1, max(1, n_devices // model)
    used = pods * data * model
    devs = list(devices if devices is not None else jax.devices())[:used]
    import numpy as np

    grid = np.array(devs).reshape(pods, data, model)
    return Mesh(grid, ("pod", "data", "model"))
