"""codeqwen1.5-7b [dense] -- qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (GQA kv=32 == MHA) d_ff=13440 vocab=92416, QKV bias
(qwen1.5 family uses attention QKV bias), SwiGLU, RoPE.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab=92416,
        qkv_bias=True,
        rope_theta=1e6,
        act="silu",
        notes="full-attention dense LM; long_500k skipped (quadratic attn)",
    )
)
