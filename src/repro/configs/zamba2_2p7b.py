"""zamba2-2.7b [hybrid] -- Mamba2 + shared attention blocks [arXiv:2411.15242].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Zamba2 interleaves Mamba-2 blocks with a (shared-weight) full attention
block; we model the repeating unit as 5x mamba2 + 1x attn (9 units = 54L).
Weight sharing of the attention block is noted but instantiated per-unit
(same FLOPs/collectives; weight-sharing only changes parameter bytes --
recorded in DESIGN.md).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab=32000,
        block_pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "attn"),
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_groups=1,
        conv_width=4,
        act="silu",
        notes="hybrid SSM+attn; runs long_500k (constant-size SSM state, "
        "attention KV only at 9 shared blocks)",
    )
)
