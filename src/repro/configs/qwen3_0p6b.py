"""qwen3-0.6b [dense] -- qk_norm + GQA [hf:Qwen/Qwen3-8B family].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936; head_dim=128
(qwen3 uses wide heads: 16H x 128 = 2048 > d_model), qk-norm, no bias.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-0.6b",
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_head=128,
        d_ff=3072,
        vocab=151936,
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1e6,
        act="silu",
        notes="qk-norm GQA; tied embeddings; long_500k skipped",
    )
)
