"""deepseek-v2-lite-16b [moe] -- MLA kv_lora=512 [arXiv:2405.04434].

27L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=102400.
MoE: 64 routed experts top-6 + 2 shared, first layer dense (d_ff=10944).
NOTE: the assignment line mentions both "64e top-6" and "160 routed"; 160
routed belongs to full DeepSeek-V2 -- V2-Lite (hf config) is 64 routed +
2 shared, top-6, which we follow (recorded in DESIGN.md).
MLA: kv_lora_rank=512, rope_head_dim=64, nope=128, v_head=128, no q-lora.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,  # dense first layer FFN width
        vocab=102400,
        n_routed_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_expert=1408,
        first_k_dense=1,
        use_mla=True,
        kv_lora_rank=512,
        q_lora_rank=0,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
        act="silu",
        notes="MLA latent KV cache; EP over model axis; long_500k skipped",
    )
)
