"""starcoder2-15b [dense] -- GQA, RoPE [arXiv:2402.19173].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152. StarCoder2 uses
bias on projections and gelu MLP (non-gated).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab=49152,
        qkv_bias=True,
        rope_theta=1e5,
        act="gelu",
        notes="GQA kv=4; gelu (non-gated) FFN; long_500k skipped",
    )
)
