"""Model/shape configuration schema + registry.

Every assigned architecture is a ``ModelConfig``; the four input-shape
regimes are ``ShapeConfig``s. A (ModelConfig, ShapeConfig) pair defines one
dry-run cell. ``reduced()`` gives the CPU-smoke-test version of a config.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 => d_model // n_heads

    # attention flags
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    encoder_only: bool = False

    # MoE
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    first_k_dense: int = 0  # leading dense layers (DeepSeek style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (DeepSeek-V2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # SSM / hybrid: repeating block pattern; n_layers % len(pattern) == 0
    block_pattern: Tuple[str, ...] = ("attn",)  # attn | mamba2 | mlstm | slstm
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4

    # modality stub frontends
    frontend: str = "none"  # none | vision_stub | audio_stub
    d_frontend: int = 0
    n_frontend_tokens: int = 0  # tokens contributed by the frontend

    # norm / act
    rms_eps: float = 1e-6
    act: str = "silu"

    notes: str = ""

    # ------------------------------------------------------------------ #
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def n_units(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern len {len(self.block_pattern)}"
        )
        return self.n_layers // len(self.block_pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k (sub-quadratic sequence handling)?"""
        return any(b in ("mamba2", "mlstm", "slstm") for b in self.block_pattern)

    # ------------------------------------------------------------------ #
    def num_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and sanity)."""
        d, h, kv, hd, ff, v = (
            self.d_model, self.n_heads, self.n_kv_heads, self.head_dim,
            self.d_ff, self.vocab,
        )
        per_block: Dict[str, int] = {}
        if self.use_mla:
            attn = (
                d * self.kv_lora_rank  # kv down
                + d * self.rope_head_dim  # shared rope key
                + self.kv_lora_rank * h * (self.nope_head_dim + self.v_head_dim)
                + (d * self.q_lora_rank + self.q_lora_rank * h *
                   (self.nope_head_dim + self.rope_head_dim)
                   if self.q_lora_rank else d * h * (self.nope_head_dim + self.rope_head_dim))
                + h * self.v_head_dim * d  # out proj
            )
        else:
            attn = d * h * hd + 2 * d * kv * hd + h * hd * d
            if self.qkv_bias:
                attn += (h + 2 * kv) * hd
        per_block["attn"] = attn + 2 * d  # + norms
        if self.n_routed_experts:
            expert = 3 * d * self.d_expert
            moe = (
                self.n_routed_experts * expert
                + self.n_shared_experts * expert
                + d * self.n_routed_experts  # router
            )
            per_block["ffn"] = moe + d
            per_block["ffn_dense"] = 3 * d * ff + d if ff else 0
        else:
            if self.act in ("silu", "swiglu"):
                per_block["ffn"] = 3 * d * ff + d
            else:
                per_block["ffn"] = 2 * d * ff + d
        # ssm blocks
        di, n, g, p = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_head_dim
        nh = self.n_ssm_heads if self.ssm_state else 0
        per_block["mamba2"] = (
            d * (2 * di + 2 * g * n + nh) + self.conv_width * (di + 2 * g * n)
            + nh * 2 + di + di * d + 2 * d
        ) if self.ssm_state else 0
        per_block["mlstm"] = (4 * d * d + d * d + 3 * d + 2 * d) if "mlstm" in self.block_pattern else 0
        # slstm: 4 gates x (input + per-head recurrent)
        hd_s = d // max(1, self.n_heads)
        per_block["slstm"] = (
            4 * d * d + 4 * self.n_heads * hd_s * hd_s + 4 * d + 2 * d
        ) if "slstm" in self.block_pattern else 0

        total = 0
        for i, b in enumerate(self.block_pattern * self.n_units):
            if b == "attn":
                total += per_block["attn"]
                if self.family not in ("hybrid",):
                    layer_idx = i
                    if self.n_routed_experts and layer_idx >= self.first_k_dense:
                        total += per_block["ffn"]
                    elif self.n_routed_experts:
                        total += per_block["ffn_dense"]
                    elif self.d_ff:
                        total += per_block["ffn"]
            elif b == "mamba2":
                total += per_block["mamba2"]
            elif b == "mlstm":
                total += per_block["mlstm"]
            elif b == "slstm":
                total += per_block["slstm"]
        total += v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        if self.frontend != "none":
            total += self.d_frontend * d + d * d  # projector MLP
        return int(total)

    def active_params(self) -> int:
        """Per-token active parameters (MoE: only top_k + shared experts)."""
        if not self.n_routed_experts:
            return self.num_params()
        expert = 3 * self.d_model * self.d_expert
        inactive = (self.n_routed_experts - self.top_k) * expert
        n_moe_layers = self.n_layers - self.first_k_dense
        return self.num_params() - n_moe_layers * inactive

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        pat = self.block_pattern
        return replace(
            self,
            name=self.name + "_smoke",
            n_layers=max(len(pat), 2 if len(pat) == 1 else len(pat)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            n_routed_experts=min(self.n_routed_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 2),
            top_k=min(self.top_k, 2),
            d_expert=32 if self.d_expert else 0,
            first_k_dense=min(self.first_k_dense, 1),
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            q_lora_rank=0,
            rope_head_dim=8 if self.use_mla else self.rope_head_dim,
            nope_head_dim=16 if self.use_mla else self.nope_head_dim,
            v_head_dim=16 if self.use_mla else self.v_head_dim,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            d_frontend=32 if self.d_frontend else 0,
            n_frontend_tokens=8 if self.n_frontend_tokens else 0,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name.endswith("_smoke"):
        return _REGISTRY[name[: -len("_smoke")]].reduced()
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def runnable_cells() -> Tuple[Tuple[str, str], ...]:
    """All (arch, shape) dry-run cells after the mandated skip rules."""
    cells = []
    for name in list_configs():
        cfg = _REGISTRY[name]
        for shape in SHAPES.values():
            if shape.kind == "decode" and not cfg.supports_decode:
                continue  # encoder-only: no autoregressive step
            if shape.name == "long_500k" and not cfg.subquadratic:
                continue  # needs sub-quadratic attention
            cells.append((name, shape.name))
    return tuple(cells)
