"""xlstm-1.3b [ssm] -- sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304. xLSTM blocks carry their
own up/down projections (d_ff=0: no separate FFN). We use the paper's
mostly-mLSTM ratio: repeating unit = 5x mLSTM + 1x sLSTM (8 units = 48L).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        block_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
        ssm_expand=2,
        ssm_head_dim=512,
        act="gelu",
        notes="pure recurrent; runs long_500k; d_ff=0 (projections inside blocks)",
    )
)
