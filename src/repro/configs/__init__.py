"""Architecture configs: the 10 assigned architectures + paper workloads."""

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    register,
    get_config,
    list_configs,
)

# import for registration side effects
from repro.configs import (  # noqa: F401
    codeqwen15_7b,
    qwen3_0p6b,
    starcoder2_15b,
    qwen15_110b,
    zamba2_2p7b,
    xlstm_1p3b,
    deepseek_v2_lite,
    qwen2_moe_a2p7b,
    llava_next_34b,
    hubert_xlarge,
)
