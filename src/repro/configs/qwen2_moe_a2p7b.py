"""qwen2-moe-a2.7b [moe] -- 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408 vocab=151936.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=0,  # every layer is MoE
        vocab=151936,
        n_routed_experts=60,
        n_shared_experts=4,
        top_k=4,
        d_expert=1408,
        qkv_bias=True,
        act="silu",
        notes="all-MoE layers; shared experts always active; long_500k skipped",
    )
)
