"""hubert-xlarge [audio] -- encoder-only [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means target codebook).
The 7-layer strided conv feature extractor is a STUB: input_specs()
provides precomputed 20ms frame embeddings (d_frontend=512) projected into
d_model. Encoder-only: bidirectional attention, no decode shapes.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab=504,
        encoder_only=True,
        frontend="audio_stub",
        d_frontend=512,
        act="gelu",
        notes="encoder-only w2v2-style stack; decode_32k/long_500k skipped",
    )
)
