"""llava-next-34b [vlm] -- anyres tiling [hf:llava-hf/llava-v1.6 family].

Backbone: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The vision tower is a STUB: input_specs() provides precomputed anyres
patch embeddings (n_frontend_tokens x d_frontend) which a 2-layer MLP
projector maps into the LM embedding space (the llava recipe).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab=64000,
        rope_theta=5e6,
        frontend="vision_stub",
        d_frontend=1024,  # CLIP-L/14 penultimate features
        n_frontend_tokens=2880,  # anyres: base 576 + 4 tiles x 576
        act="silu",
        notes="vision frontend stubbed as precomputed patch embeddings; "
        "long_500k skipped (quadratic attn)",
    )
)
