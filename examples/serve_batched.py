"""Serve a small model with batched requests: wave-batched prefill +
lock-step greedy decode through the SAME serve_step the 512-chip dry-run
compiles.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.serve import Request, WaveServer
from repro.models import init_params

ARCH = "qwen3-0.6b_smoke"  # reduced config; swap for any decoder arch id


def main() -> None:
    cfg = get_config(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    server = WaveServer(cfg, params, batch_slots=4, max_len=96)

    rng = np.random.default_rng(0)
    n_requests, max_new = 10, 24
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(3, 16))).tolist()
        server.submit(Request(rid, prompt, max_new))

    t0 = time.time()
    done = server.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out[:10]}...")
    assert len(done) == n_requests
    print("OK")


if __name__ == "__main__":
    main()
