"""Quickstart: the Union co-design loop in 60 lines.

1. Describe a tensor operation as a Union Problem (or lower a LayerOp).
2. Describe an accelerator as a cluster hierarchy.
3. Let Union-opt search the map-space with any mapper x any cost model.
4. Read the mapping back as a loop nest -- and, on the TPU target, as the
   exact BlockSpec tiles the Pallas matmul kernel will execute.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.architecture import cloud_accelerator, tpu_chip
from repro.core.ir.dialects import LayerOp, TensorType
from repro.core.ir.lowering import lower_layer_to_problem
from repro.core.optimizer import union_opt
from repro.core.constraints import mxu_aligned

# -- 1. a workload: one BERT FFN GEMM, written as a domain-level LayerOp --
op = LayerOp(
    "bert_ffn", "linear",
    {"x": TensorType((256, 768)), "w": TensorType((768, 3072))},
    {"y": TensorType((256, 3072))},
)
problem = lower_layer_to_problem(op)  # TOSA-ish -> linalg-ish -> affine -> Problem
print(f"problem: {problem}\n")

# -- 2+3. two accelerators, two cost models, one mapper API ---------------
for arch, cm in ((cloud_accelerator(), "timeloop"), (cloud_accelerator(), "maestro")):
    sol = union_opt(problem, arch, mapper="heuristic", cost_model=cm, metric="edp")
    print(f"{arch.name} x {cm:8s}: EDP {sol.cost.edp:.3e} J*s, "
          f"utilization {sol.cost.utilization:.0%}")

# -- 4. the same machinery tiles the TPU Pallas kernel --------------------
from repro.kernels.matmul import matmul, plan_tiles

M, N, K = 512, 3072, 768
tiles = plan_tiles(M, N, K)
print(f"\nUnion-planned BlockSpec tiles for a {M}x{N}x{K} matmul on one "
      f"TPU chip: bm,bn,bk = {tiles}")

x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
y = matmul(x, w, tiles=tiles, interpret=True)  # interpret=True: CPU container
np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=2e-4, atol=2e-4)
print("Pallas kernel with the planned tiles matches jnp: OK")

# -- bonus: the mapping rendered as the paper's loop-nest form ------------
sol = union_opt(problem, tpu_chip(), mapper="heuristic", cost_model="timeloop",
                metric="latency", constraints=mxu_aligned(["b", "i", "o"]))
print("\nloop nest (paper Fig. 5e form) on the TPU chip hierarchy:")
print(sol.loop_nest())
