"""HW-SW co-design exploration: the paper's three case studies in one
script, on YOUR operator.

Given one tensor op (a GEMM from an LM FFN), explore:
  (a) algorithm   -- native vs TTGT-style flattening  (paper Sec. V-A)
  (b) mapping     -- mapper/cost-model grid            (paper Sec. V-B)
  (c) hardware    -- aspect ratios + chiplet fill bw   (paper Sec. V-B/C)
and close the loop on the TPU target: the best mapping becomes the
Pallas BlockSpec + the mesh PartitionSpec.

Run:  PYTHONPATH=src python examples/codesign_explore.py
"""

from repro.core.architecture import (
    chiplet_accelerator,
    cloud_accelerator,
    tpu_chip,
)
from repro.core.constraints import mxu_aligned
from repro.core.optimizer import union_opt
from repro.core.problem import Problem

# the operator under study: a d_ff=8960 x d=2048 FFN GEMM at batchxseq=4096
P = Problem.gemm(4096, 8960, 2048, name="ffn_gemm", word_bytes=1)

print("== (b) mapping exploration: mapper x cost model ==")
for cm in ("timeloop", "maestro"):
    for mp in ("heuristic", "genetic", "random"):
        sol = union_opt(P, cloud_accelerator(), mapper=mp, cost_model=cm, metric="edp")
        print(f"  {cm:9s} x {mp:9s}: EDP {sol.cost.edp:.3e} "
              f"util {sol.cost.utilization:5.0%} ({sol.search.evaluated} evals)")

print("\n== (c) hardware exploration: aspect ratio ==")
for aspect in ((1, 2048), (8, 256), (32, 64)):
    sol = union_opt(P, cloud_accelerator(aspect=aspect), mapper="heuristic",
                    cost_model="maestro", metric="edp")
    print(f"  {aspect[0]:2d}x{aspect[1]:<4d}: EDP {sol.cost.edp:.3e} "
          f"util {sol.cost.utilization:5.0%}")

print("\n== (c') hardware exploration: chiplet fill bandwidth ==")
for bw in (1e9, 4e9, 16e9):
    sol = union_opt(P, chiplet_accelerator(fill_bandwidth=bw),
                    mapper="heuristic", cost_model="timeloop", metric="edp")
    print(f"  fill {bw/1e9:4.0f} GB/s: EDP {sol.cost.edp:.3e}")

print("\n== closing the loop on TPU ==")
from repro.kernels.matmul import plan_tiles

bm, bn, bk = plan_tiles(4096, 8960 + 128 * 2, 2048)  # pad 8960 -> /128-friendly
print(f"  VMEM-level temporal tile -> BlockSpec (bm,bn,bk) = ({bm}, {bn}, {bk})")
print(f"  (this is exactly what repro.kernels.matmul.plan_tiles feeds "
      f"pl.pallas_call; see examples/quickstart.py)")
print("OK")
