"""End-to-end driver: train a ~100M-param qwen3-family LM for a few
hundred steps on the synthetic pipeline, with checkpointing + fault
tolerance, and report the loss curve.

This is the same launch/train.py entry the 512-chip dry-run step uses --
only the config size and mesh differ. ~100M params:
  14 layers x d_model 576 x heads 8 (GQA kv 4) x d_ff 2048, vocab 32768
  => ~105M params. A few hundred steps of batch 16 x seq 256.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~6 s/step on this CPU container; 300 steps ~ 30 min. On a TPU slice use
--mesh to shard; the step function is identical to the dry-run's.)
"""

import argparse
import dataclasses
import sys

from repro.configs.base import ModelConfig, register
from repro.launch import train as train_mod

CFG_100M = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=14,
    d_model=576,
    n_heads=8,
    n_kv_heads=4,
    d_ff=2048,
    vocab=32768,
    qk_norm=True,
    rope_theta=1e4,
    notes="~100M-param example model (qwen3 family shape)",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/union_lm100m")
    args = ap.parse_args()

    register(CFG_100M)
    n_params = CFG_100M.num_params()
    print(f"training {CFG_100M.name}: {n_params/1e6:.0f}M params, "
          f"{args.steps} steps x ({args.batch} x {args.seq}) tokens")
    out = train_mod.main([
        "--arch", "lm-100m",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--lr", "6e-4", "--warmup", "40",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])
    drop = out["first_loss"] - out["last_loss"]
    print(f"\nloss {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"(drop {drop:.3f} over {out['steps']} steps)")
    want = 0.3 if args.steps >= 100 else 0.02  # short runs: sanity only
    if drop <= want:
        sys.exit(f"FAIL: expected the loss to drop by > {want}")
    print("OK")


if __name__ == "__main__":
    main()
