"""Unit coverage for ``launch.hloparse.parse_collectives`` -- previously
only exercised indirectly through dryrun artifacts.

Covers both replica-group syntaxes (brace ``{{...}}`` lists and iota
``[n,m]<=[k]``), tuple results with mixed dtypes, the per-collective
ring-convention byte math, async ``-start`` forms, and the
unknown-dtype count-and-warn path with its skipped-bytes tally."""

import warnings

import pytest

from repro.launch.hloparse import CollectiveStats, parse_collectives


def test_all_gather_brace_groups():
    hlo = ("%ag = bf16[4,256]{1,0} all-gather(bf16[1,256] %x), "
           "replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}")
    st = parse_collectives(hlo)
    assert st.counts["all-gather"] == 1
    out_bytes = 4 * 256 * 2
    assert st.raw_bytes["all-gather"] == out_bytes
    # ring all-gather: (n-1)/n of the gathered bytes cross the link
    assert st.link_bytes["all-gather"] == pytest.approx(out_bytes * 3 / 4)


def test_all_reduce_iota_groups():
    hlo = ("%ar = f32[128]{0} all-reduce(f32[128] %y), "
           "replica_groups=[8,4]<=[32], to_apply=%add")
    st = parse_collectives(hlo)
    assert st.counts["all-reduce"] == 1
    # iota [ngroups, gsize] <= [total]: group size is the SECOND field
    assert st.link_bytes["all-reduce"] == pytest.approx(128 * 4 * 2 * 3 / 4)


def test_reduce_scatter_and_all_to_all_and_permute():
    hlo = "\n".join([
        "%rs = f32[64]{0} reduce-scatter(f32[256] %z), replica_groups={{0,1,2,3}}, dimensions={0}",
        "%aa = bf16[512]{0} all-to-all(bf16[512] %w), replica_groups={{0,1}}",
        "%cp = u8[100]{0} collective-permute(u8[100] %v), source_target_pairs={{0,1}}",
    ])
    st = parse_collectives(hlo)
    # reduce-scatter: bytes_out x (n-1); all-to-all: (n-1)/n; permute: 1 hop
    assert st.link_bytes["reduce-scatter"] == pytest.approx(64 * 4 * 3)
    assert st.link_bytes["all-to-all"] == pytest.approx(512 * 2 * 1 / 2)
    assert st.link_bytes["collective-permute"] == pytest.approx(100)
    assert st.total_link_bytes == pytest.approx(64 * 4 * 3 + 512 + 100)


def test_async_start_tuple_mixed_dtypes():
    """-start forms carry tuple results mixing payload and control dtypes;
    every known-dtype member counts, at the op's ring convention."""
    hlo = ("%ags = (bf16[128]{0}, bf16[512]{0}, u32[], u32[]) "
           "all-gather-start(bf16[128] %q), replica_groups={{0,1,2,3}}")
    st = parse_collectives(hlo)
    assert st.counts["all-gather"] == 1
    tup = 128 * 2 + 512 * 2 + 4 + 4
    assert st.link_bytes["all-gather"] == pytest.approx(tup * 3 / 4)


def test_default_group_size_when_unannotated():
    st = parse_collectives("%ar = f32[16]{0} all-reduce(f32[16] %y)",
                           default_group=8)
    assert st.link_bytes["all-reduce"] == pytest.approx(16 * 4 * 2 * 7 / 8)


def test_non_collective_lines_ignored():
    hlo = "\n".join([
        "%p = f32[64]{0} parameter(0)",
        "%d = f32[64]{0} dot(f32[64] %p, f32[64] %p)",
        "ENTRY %main (p: f32[64]) -> f32[64] {",
    ])
    st = parse_collectives(hlo)
    assert st.total_link_bytes == 0
    assert not st.counts


def test_unknown_dtype_warns_and_tallies():
    """Unknown dtypes are counted and warned about, never silently
    dropped; row() reports the 1-byte/element lower-bound tally."""
    hlo = ("%ag = (bf16[64]{0}, f4e2m1fn[2048]{0}) "
           "all-gather-start(bf16[64] %x), replica_groups={{0,1}}")
    with pytest.warns(UserWarning, match="unknown HLO dtype 'f4e2m1fn'"):
        st = parse_collectives(hlo)
    # known members still count at their ring share
    assert st.link_bytes["all-gather"] == pytest.approx(64 * 2 * 1 / 2)
    assert st.unknown_dtypes == {"f4e2m1fn": 2048}
    assert st.skipped_bytes == 2048
    row = st.row()
    assert row["unknown_dtype_count"] == 1
    assert row["skipped_bytes"] == 2048


def test_known_dtypes_do_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        st = parse_collectives(
            "%ar = bf16[32]{0} all-reduce(bf16[32] %x), replica_groups={{0,1}}")
    assert st.skipped_bytes == 0
    assert st.row()["unknown_dtype_count"] == 0


def test_row_schema_is_numeric():
    """Every row() value must be numeric: dryrun's corrected_costs
    linearly extrapolates over ALL row keys."""
    st = parse_collectives(
        "%ag = (q8[16]{0}) all-gather-start(q8[16] %x), replica_groups={{0,1}}")
    for k, v in CollectiveStats().row().items():
        assert isinstance(v, (int, float)), k
    for k, v in st.row().items():
        assert isinstance(v, (int, float)), k
