"""Hypothesis property tests on the system's invariants."""

import math
import random

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.architecture import edge_accelerator
from repro.core.cost import TimeloopLikeModel
from repro.core.ir.ttgt import best_ttgt_plan
from repro.core.mapspace import MapSpace, divisors
from repro.core.problem import AffineExpr, Problem
from repro.runtime.compression import compress_int8, decompress_int8

SIZES = st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 24, 32])


@given(st.integers(1, 10_000))
@settings(max_examples=60, deadline=None)
def test_divisors_correct(n):
    ds = divisors(n)
    assert ds == sorted(ds)
    assert all(n % d == 0 for d in ds)
    assert ds[0] == 1 and ds[-1] == n
    assert len(ds) == sum(1 for i in range(1, n + 1) if n % i == 0)


@given(SIZES, SIZES, SIZES, st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_random_mappings_always_legal_and_cover(m, n, k, seed):
    """Any sampled mapping is legal, and steps x parallelism over all levels
    covers the iteration space exactly (paper rule R4)."""
    p = Problem.gemm(m, n, k)
    sp = MapSpace(p, edge_accelerator())
    mp = sp.random_mapping(random.Random(seed))
    assert mp.is_legal(p, sp.arch)
    total = 1
    for i in range(len(mp.levels)):
        total *= mp.steps(i, p) * mp.parallelism(i, p)
    # the innermost temporal tile is what one PE computes per visit
    leaf_tile = 1
    for d in p.dims:
        leaf_tile *= mp.levels[-1].st(d)
    assert total * leaf_tile == p.iteration_space


@given(SIZES, SIZES, SIZES, st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_cost_respects_compute_bound(m, n, k, seed):
    p = Problem.gemm(m, n, k, word_bytes=1)
    arch = edge_accelerator()
    sp = MapSpace(p, arch)
    mp = sp.random_mapping(random.Random(seed))
    c = TimeloopLikeModel().evaluate(p, mp, arch)
    assert c.latency_cycles >= p.macs / arch.peak_macs_per_cycle - 1e-9
    assert c.energy_pj >= p.macs * arch.clusters[-1].mac_energy - 1e-9
    assert 0 < c.utilization <= 1.0


@given(
    st.lists(st.integers(1, 6), min_size=1, max_size=3),
    st.integers(1, 4),
)
@settings(max_examples=30, deadline=None)
def test_affine_extent_monotone_in_tile(coeffs, tile):
    """Footprint extent is monotone non-decreasing in every tile size."""
    expr = AffineExpr.of(*[(c, f"d{i}") for i, c in enumerate(coeffs)])
    t1 = {f"d{i}": tile for i in range(len(coeffs))}
    t2 = {f"d{i}": tile + 1 for i in range(len(coeffs))}
    assert expr.extent(t2) >= expr.extent(t1)
    assert expr.extent({f"d{i}": 1 for i in range(len(coeffs))}) == 1


@given(st.integers(2, 5), st.integers(2, 5), st.integers(2, 5))
@settings(max_examples=20, deadline=None)
def test_ttgt_work_preserving(a, b, c):
    """TTGT flattening never changes the MAC count for any TC."""
    p = Problem.from_einsum(
        "tc", "xz,zy->xy", {"x": a, "z": b, "y": c}, "TC"
    )
    plan = best_ttgt_plan(p)
    assert plan.M * plan.N * plan.K == p.macs


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 100.0))
@settings(max_examples=25, deadline=None)
def test_int8_compression_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed % 2**31), (128,)) * scale
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-5


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_segsum_stability(seed):
    """models.ssm._segsum: finite below diagonal, -inf above, telescoping."""
    from repro.models.ssm import _segsum

    x = jax.random.normal(jax.random.PRNGKey(seed), (6,)).astype(jnp.float32)
    out = np.asarray(_segsum(x))
    for i in range(6):
        assert out[i, i] == 0.0
        for j in range(6):
            if j > i:
                assert out[i, j] == -np.inf
            elif j < i:
                np.testing.assert_allclose(
                    out[i, j], float(jnp.sum(x[j + 1 : i + 1])), rtol=1e-5, atol=1e-5
                )
