"""Union problem abstraction (paper Sec. IV-B)."""

import math

import pytest

from repro.core.problem import AffineExpr, DataSpace, Problem


def test_gemm_dims_and_macs():
    p = Problem.gemm(64, 32, 16)
    assert p.dims == {"m": 64, "k": 16, "n": 32}
    assert p.macs == 64 * 32 * 16
    assert p.flops == 2 * p.macs
    assert p.operation == "GEMM"
    assert p.reduction_dims() == ("k",)


def test_gemm_footprints():
    p = Problem.gemm(64, 32, 16)
    a = p.data_space("In0")
    b = p.data_space("In1")
    c = p.data_space("Out")
    assert a.footprint(p.dims) == 64 * 16
    assert b.footprint(p.dims) == 16 * 32
    assert c.footprint(p.dims) == 64 * 32
    assert c.is_output
    tile = {"m": 8, "n": 4, "k": 2}
    assert a.footprint(tile) == 16
    assert c.footprint_bytes(tile) == 8 * 4 * 2  # bf16


def test_conv2d_strided_window_footprint():
    # paper Algorithm 1: IA[x*stride + r]
    p = Problem.conv2d(N=1, K=4, C=3, X=8, Y=8, R=3, S=3, stride=2)
    ia = p.data_space("Inputs")
    # input rows touched by x-tile t, r-tile 3, stride 2: 2*(t-1) + 3
    tile = dict(n=1, c=1, x=4, y=1, r=3, s=1)
    xy_expr = ia.projection[2]
    assert xy_expr.extent(tile) == 2 * 3 + 3
    assert p.reduction_dims() == ("c", "r", "s")


def test_tc_ccsd_t4_matches_paper_algorithm2():
    p = Problem.tc_ccsd_t4(16)
    assert set(p.dims) == set("abcdefg")
    assert p.reduction_dims() == ("g",)
    out = p.outputs()[0]
    assert len(out.projection) == 6  # 6D output
    assert p.macs == 16 ** 7


def test_mttkrp_unit_op():
    p = Problem.mttkrp(4, 5, 6, 7)
    assert p.unit_op == "mac3"


def test_validate_rejects_unknown_dim():
    ds = DataSpace("X", (AffineExpr.of("z"),))
    with pytest.raises(ValueError):
        Problem("bad", {"m": 4}, (ds,)).validate()


def test_validate_requires_output():
    ds = DataSpace("X", (AffineExpr.of("m"),), is_output=False)
    with pytest.raises(ValueError):
        Problem("bad", {"m": 4}, (ds,)).validate()


def test_from_einsum_attrs():
    p = Problem.from_einsum("bmm", "bmk,bkn->bmn", {"b": 2, "m": 4, "k": 8, "n": 16})
    assert p.attrs["einsum"] == "bmk,bkn->bmn"
    assert p.iteration_space == 2 * 4 * 8 * 16
