"""Vectorized batch analysis: the array-program backend must be
bit-identical to the scalar evaluation path for every cost model, the
engine's batch counters must match the dedup semantics exactly, and the
fig8 TTGT comparison must include the transpose DRAM traffic."""

import math
import random

import pytest

from repro.core.architecture import (
    cloud_accelerator,
    edge_accelerator,
    tpu_v5e_pod,
)
from repro.core.cost import (
    EvaluationEngine,
    MaestroLikeModel,
    TimeloopLikeModel,
    TPURooflineModel,
)
from repro.core.cost.analysis import get_context
from repro.core.ir.ttgt import best_ttgt_plan, enumerate_ttgt_plans, transpose_cost
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace
from repro.core.optimizer import union_opt
from repro.core.problem import Problem

GEMM = Problem.gemm(64, 32, 16, word_bytes=1)
CONV = Problem.conv2d(2, 8, 8, 7, 7, 3, 3, stride=2, name="conv_t", word_bytes=1)
MODELS = [TimeloopLikeModel, MaestroLikeModel, TPURooflineModel]


def _costs_equal(a, b):
    return (
        a.latency_cycles == b.latency_cycles
        and a.energy_pj == b.energy_pj
        and a.utilization == b.utilization
        and a.macs == b.macs
        and a.frequency_hz == b.frequency_hz
        and a.breakdown == b.breakdown
    )


def _scalar_cost(cm, problem, arch, genome, sig):
    """The engine's per-candidate path: fused signature evaluation when the
    model provides it, full evaluate otherwise."""
    c = cm.evaluate_signature(problem, arch, sig)
    if c is None:
        c = cm.evaluate(problem, genome.to_mapping(), arch)
    return c


@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", MODELS)
@pytest.mark.parametrize(
    "mk_arch",
    [edge_accelerator, cloud_accelerator, lambda: tpu_v5e_pod(1, 2, 2)],
    ids=["edge", "cloud", "tpu_pod"],
)
def test_batch_bit_identical_to_scalar(problem, model_cls, mk_arch):
    """evaluate_signature_batch == the scalar path, bit for bit, for all
    three cost models (incl. the roofline's collective terms on a mesh
    architecture)."""
    arch = mk_arch()
    cm = model_cls()
    ctx = get_context(problem, arch)
    space = MapSpace(problem, arch)
    rng = random.Random(0)
    genomes = [space.random_genome(rng) for _ in range(40)]
    sigs = [g.signature(ctx.dims) for g in genomes]
    batch = cm.evaluate_signature_batch(problem, arch, sigs)
    assert batch is not None and len(batch) == len(sigs)
    for g, sig, c in zip(genomes, sigs, batch):
        assert _costs_equal(c, _scalar_cost(cm, problem, arch, g, sig))
        # and therefore identical to the full evaluate as well
        assert c.latency_cycles == cm.evaluate(problem, g.to_mapping(), arch).latency_cycles


@pytest.mark.parametrize("model_cls", MODELS)
def test_batch_fixed_cases(model_cls):
    """Deterministic corner candidates: the trivial all-serial mapping and
    a heavily-spatial one must also round-trip bit-identically."""
    arch = cloud_accelerator()
    cm = model_cls()
    ctx = get_context(GEMM, arch)
    space = MapSpace(GEMM, arch)
    trivial = Mapping.trivial(GEMM, arch)
    others = [space.random_genome(random.Random(s)) for s in range(5)]
    cands = [trivial] + [g.to_mapping() for g in others]
    from repro.core.mapping import mapping_signature

    sigs = [mapping_signature(m, ctx.dims) for m in cands]
    batch = cm.evaluate_signature_batch(GEMM, arch, sigs)
    assert batch is not None
    for m, c in zip(cands, batch):
        assert _costs_equal(c, cm.evaluate(GEMM, m, arch))


def test_hypothesis_batch_equivalence():
    """Randomized GEMM shapes x seeds: batch == scalar, bit for bit."""
    pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)"
    )
    from hypothesis import given, settings, strategies as st

    sizes = st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64])

    @given(sizes, sizes, sizes, st.integers(0, 2**20))
    @settings(max_examples=25, deadline=None)
    def check(M, N, K, seed):
        problem = Problem.gemm(M, N, K, word_bytes=1)
        arch = edge_accelerator()
        ctx = get_context(problem, arch)
        space = MapSpace(problem, arch)
        rng = random.Random(seed)
        genomes = [space.random_genome(rng) for _ in range(6)]
        sigs = [g.signature(ctx.dims) for g in genomes]
        for cm in (TimeloopLikeModel(), MaestroLikeModel()):
            batch = cm.evaluate_signature_batch(problem, arch, sigs)
            assert batch is not None
            for g, sig, c in zip(genomes, sigs, batch):
                assert _costs_equal(c, _scalar_cost(cm, problem, arch, g, sig))

    check()


def test_jax_backend_matches_numpy():
    """The jitted JAX backend (x64 forced inside the core) produces the
    same stacked traffic as numpy, and engine results stay bit-identical."""
    pytest.importorskip("jax")
    import numpy as np

    arch = cloud_accelerator()
    ctx = get_context(GEMM, arch)
    space = MapSpace(GEMM, arch)
    rng = random.Random(11)
    sigs = [space.random_genome(rng).signature(ctx.dims) for _ in range(13)]
    bt_np = ctx.signature_traffic_batch(sigs, backend="numpy")
    bt_jax = ctx.signature_traffic_batch(sigs, backend="jax")
    if ctx._jax_failed:
        pytest.skip("jax batch core unavailable on this platform")
    assert np.array_equal(bt_np.compute_cycles, bt_jax.compute_cycles)
    assert np.array_equal(bt_np.inst_at, bt_jax.inst_at)
    for rn, rj in zip(bt_np.rows, bt_jax.rows):
        for a, b in zip(rn, rj):
            assert np.array_equal(a, b)
    cm = TimeloopLikeModel()
    costs_np = cm.evaluate_signature_batch(GEMM, arch, sigs, backend="numpy")
    costs_jax = cm.evaluate_signature_batch(GEMM, arch, sigs, backend="jax")
    for a, b in zip(costs_np, costs_jax):
        assert _costs_equal(a, b)


def test_engine_backend_search_identical():
    """A full search through the vectorized engine == the scalar engine:
    same best mapping, same cost, same counters."""
    arch = cloud_accelerator()
    sols = {
        be: union_opt(
            GEMM, arch, mapper="random", cost_model="timeloop",
            samples=400, engine_backend=be,
        )
        for be in ("numpy", "none")
    }
    a, b = sols["numpy"], sols["none"]
    assert a.cost.edp == b.cost.edp
    assert a.mapping.to_dict() == b.mapping.to_dict()
    for attr in ("evaluated", "analyzed", "cache_hits", "pruned"):
        assert getattr(a.search, attr) == getattr(b.search, attr), attr


def test_duplicate_pruned_batch_counters():
    """In-batch duplicates of a pruned candidate: the bound runs ONCE and
    ``stats.pruned`` counts the candidate once per batch (matching the
    dedup semantics of ``evaluated``)."""
    arch = cloud_accelerator()
    cm = TimeloopLikeModel()
    space = MapSpace(GEMM, arch)
    eng = EvaluationEngine(cm, GEMM, arch, metric="edp")
    # a strong incumbent plus the worst legal mapping => certain prune
    incumbent = union_opt(GEMM, arch, mapper="heuristic", cost_model=cm).cost.edp
    bad = Mapping.trivial(GEMM, arch)
    assert eng._should_prune(bad, incumbent)

    calls = []
    orig = eng._should_prune
    eng._should_prune = lambda cand, inc: calls.append(1) or orig(cand, inc)
    res = eng.evaluate_batch([bad, bad, bad], incumbent=incumbent)
    assert res == [None, None, None]
    assert eng.stats.pruned == 1  # counted once per batch, not per duplicate
    assert len(calls) == 1  # bound work matches the dedup semantics
    # pruned keys are tracked PER BATCH: a later batch re-admits the key
    eng.evaluate_batch([bad], incumbent=incumbent)
    assert eng.stats.pruned == 2


def test_probe_chunk_identical_results():
    """Incumbent-aware first-chunk sizing changes counters, never results."""
    arch = cloud_accelerator()
    base = union_opt(GEMM, arch, mapper="random", cost_model="timeloop",
                     samples=500, probe=0)
    probed = union_opt(GEMM, arch, mapper="random", cost_model="timeloop",
                       samples=500, probe=8)
    assert probed.cost.edp == base.cost.edp
    assert probed.mapping.to_dict() == base.mapping.to_dict()
    # the warm start admits the bound filter earlier => at least as many prunes
    assert probed.search.pruned >= base.search.pruned
    ex_base = union_opt(GEMM, arch, mapper="exhaustive", cost_model="timeloop",
                        max_mappings=600, probe=0)
    ex_probe = union_opt(GEMM, arch, mapper="exhaustive", cost_model="timeloop",
                         max_mappings=600, probe=8)
    assert ex_probe.cost.edp == ex_base.cost.edp
    assert ex_probe.mapping.to_dict() == ex_base.mapping.to_dict()
    dc_base = union_opt(GEMM, arch, mapper="decoupled", cost_model="timeloop",
                        offchip_samples=100, onchip_samples=100, probe=0)
    dc_probe = union_opt(GEMM, arch, mapper="decoupled", cost_model="timeloop",
                         offchip_samples=100, onchip_samples=100, probe=8)
    assert dc_probe.cost.edp == dc_base.cost.edp
    assert dc_probe.mapping.to_dict() == dc_base.mapping.to_dict()
    assert dc_probe.search.pruned >= dc_base.search.pruned


def test_fig8_includes_transpose_traffic():
    """The TTGT side of the fig8 comparison pays for its transposes."""
    from benchmarks.fig8_ttgt import ttgt_total_edp

    problem = Problem.tc_intensli2(16, word_bytes=1)
    arch = cloud_accelerator()
    plans = [p for p in enumerate_ttgt_plans(problem) if p.transpose_elems > 0]
    assert plans, "expected at least one plan with explicit transposes"
    plan = plans[0]
    cyc, pj = transpose_cost(plan, arch, word_bytes=1)
    assert pj > 0  # outermost-level read+write energy is charged
    assert cyc > 0  # and the bytes take time through the fill boundary
    gemm = plan.gemm_problem(word_bytes=1)
    sol = union_opt(gemm, arch, mapper="heuristic", cost_model="timeloop")
    with_t = ttgt_total_edp(sol.cost, plan, arch, include_transpose=True)
    without = ttgt_total_edp(sol.cost, plan, arch, include_transpose=False)
    assert without == sol.cost.edp  # --no-transpose-cost reproduces old numbers
    assert with_t > without  # transposes are no longer free
    expected = ((sol.cost.energy_pj + pj) * 1e-12) * (
        (sol.cost.latency_cycles + cyc) / sol.cost.frequency_hz
    )
    assert with_t == expected
    # a transpose-free plan costs nothing extra
    free = [p for p in enumerate_ttgt_plans(problem) if p.transpose_elems == 0]
    for p in free:
        assert transpose_cost(p, arch) == (0.0, 0.0)


def test_best_plan_minimizes_transpose_volume():
    for tds in (4, 16):
        problem = Problem.tc_ccsd7(tds, word_bytes=1)
        plans = enumerate_ttgt_plans(problem)
        assert best_ttgt_plan(problem).transpose_elems == min(
            p.transpose_elems for p in plans
        )
