"""EvaluationEngine correctness: cache/bound/batch paths must be exactly
the direct cost-model evaluation, and pruning must never discard a
candidate better than the incumbent."""

import math
import random

import pytest

from repro.core.architecture import cloud_accelerator, edge_accelerator
from repro.core.cost import (
    EvaluationEngine,
    MaestroLikeModel,
    TimeloopLikeModel,
    TPURooflineModel,
    mapping_signature,
)
from repro.core.cost.analysis import get_context
from repro.core.mapspace import MapSpace
from repro.core.optimizer import union_opt
from repro.core.problem import Problem

GEMM = Problem.gemm(64, 32, 16, word_bytes=1)
CONV = Problem.conv2d(2, 8, 8, 7, 7, 3, 3, stride=2, name="conv_t", word_bytes=1)
MODELS = [TimeloopLikeModel, MaestroLikeModel, TPURooflineModel]


def _costs_equal(a, b):
    return (
        a.latency_cycles == b.latency_cycles
        and a.energy_pj == b.energy_pj
        and a.utilization == b.utilization
        and a.macs == b.macs
        and a.frequency_hz == b.frequency_hz
        and a.breakdown == b.breakdown
    )


@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", MODELS)
def test_engine_bitwise_identical_to_direct_evaluate(problem, model_cls):
    """Engine-cached results == direct cost_model.evaluate, bit for bit,
    for all three cost models on GEMM and CONV."""
    arch = edge_accelerator()
    cm = model_cls()
    space = MapSpace(problem, arch)
    rng = random.Random(0)
    eng = EvaluationEngine(cm, problem, arch, metric="edp")
    mappings = [space.random_mapping(rng) for _ in range(30)]
    genomes = [space.random_genome(rng) for _ in range(30)]
    for m in mappings:
        assert _costs_equal(eng.evaluate(m), cm.evaluate(problem, m, arch))
    # second pass: served from cache, still identical
    hits_before = eng.stats.cache_hits
    for m in mappings:
        assert _costs_equal(eng.evaluate(m), cm.evaluate(problem, m, arch))
    assert eng.stats.cache_hits >= hits_before + len(mappings)
    # genome candidates and the batch path agree too
    costs = eng.evaluate_batch(genomes)
    for g, c in zip(genomes, costs):
        assert _costs_equal(c, cm.evaluate(problem, g.to_mapping(), arch))


@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", MODELS)
def test_lower_bound_never_exceeds_true_cost(problem, model_cls):
    """Seeded property test: the admission bound is a true lower bound, so
    pruning can never discard a candidate better than the incumbent."""
    arch = cloud_accelerator()
    cm = model_cls()
    space = MapSpace(problem, arch)
    ctx = get_context(problem, arch)
    rng = random.Random(1234)
    for metric in ("edp", "latency", "energy"):
        eng = EvaluationEngine(cm, problem, arch, metric=metric)
        for _ in range(120):
            g = space.random_genome(rng)
            m = g.to_mapping()
            true = cm.evaluate(problem, m, arch).metric(metric)
            lb = eng.lower_bound(m)
            assert lb <= true + 1e-12 * max(1.0, abs(true)), (
                model_cls.__name__,
                metric,
            )
            # chain-level bound (genome fast path) matches the sig bound
            fn = cm.lower_bound_chains_fn(problem, arch)
            if fn is not None:
                assert fn(g.chain_list, g.orders) == cm.lower_bound_fn(
                    problem, arch
                )(g.signature(ctx.dims))


@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", [TimeloopLikeModel, MaestroLikeModel])
def test_pruned_candidates_cannot_beat_incumbent(problem, model_cls):
    arch = cloud_accelerator()
    cm = model_cls()
    space = MapSpace(problem, arch)
    rng = random.Random(7)
    eng = EvaluationEngine(cm, problem, arch, metric="edp")
    incumbent = cm.evaluate(problem, space.random_mapping(rng), arch).metric("edp")
    pruned_seen = 0
    for _ in range(200):
        g = space.random_genome(rng)
        c = eng.evaluate_admit(g, incumbent)
        true = cm.evaluate(problem, g.to_mapping(), arch).metric("edp")
        if c is None:
            pruned_seen += 1
            assert true >= incumbent  # never prunes an improver
        else:
            assert c.metric("edp") == true
    assert pruned_seen > 0  # the filter actually engages on this workload
    assert eng.stats.pruned == pruned_seen


def test_bound_pruned_search_identical_to_unpruned():
    """Search with cache+bound on == search with both off: same best cost."""
    arch = cloud_accelerator()
    for mapper in ("random", "genetic", "heuristic", "exhaustive"):
        kw = {"max_mappings": 400} if mapper == "exhaustive" else {}
        on = union_opt(GEMM, arch, mapper=mapper, cost_model="timeloop", **kw)
        off = union_opt(
            GEMM, arch, mapper=mapper, cost_model="timeloop",
            engine_prune=False, engine_cache=1, **kw,
        )
        assert on.cost.edp == off.cost.edp, mapper
        assert on.mapping.to_dict() == off.mapping.to_dict(), mapper


def test_signature_canonicalizes_equivalent_orders():
    arch = edge_accelerator()
    space = MapSpace(GEMM, arch)
    m = space.random_mapping(random.Random(5))
    dims = tuple(GEMM.dims)
    for lm in m.levels:  # declared order = problem order at every level
        lm.temporal_order = dims
    sig1 = mapping_signature(m, dims)
    m2 = m.clone()
    # an empty declared order normalizes to problem order: same signature
    m2.levels[0].temporal_order = ()
    assert mapping_signature(m2, dims) == sig1


def test_search_counters_reported():
    arch = cloud_accelerator()
    sol = union_opt(
        dnn := Problem.gemm(128, 64, 64, word_bytes=1), arch,
        mapper="random", cost_model="timeloop", samples=600,
    )
    res = sol.search
    assert res.pruned > 0
    assert res.analyzed > 0
    assert res.candidates == res.evaluated + res.pruned
    assert res.evals_per_s > 0
    gen = union_opt(dnn, arch, mapper="genetic", cost_model="timeloop")
    assert gen.search.cache_hits > 0


def test_engine_batch_dedups_within_batch():
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    space = MapSpace(GEMM, arch)
    g = space.random_genome(random.Random(3))
    eng = EvaluationEngine(cm, GEMM, arch)
    costs = eng.evaluate_batch([g, g, g])
    assert eng.stats.evaluated == 1
    assert all(c is costs[0] for c in costs)


def test_engine_worker_pool_matches_serial():
    """Optional process-pool fan-out returns the same costs (skipped
    gracefully if the sandbox forbids subprocesses)."""
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    space = MapSpace(GEMM, arch)
    rng = random.Random(9)
    ms = [space.random_mapping(rng) for _ in range(16)]
    serial = EvaluationEngine(cm, GEMM, arch)
    pooled = EvaluationEngine(cm, GEMM, arch, workers=2)
    try:
        got = pooled.evaluate_batch(ms)
        want = serial.evaluate_batch(ms)
        for a, b in zip(got, want):
            assert _costs_equal(a, b)
    finally:
        pooled.close()


# --------------------------------------------------------------------- #
# Nearest-neighbor incumbent seeding (seed_incumbent)
# --------------------------------------------------------------------- #
def test_seed_incumbent_prunes_early_but_never_changes_results():
    """A valid (upper-bound) seed warm-starts admission pruning from
    candidate #1 yet the search converges to the identical best."""
    from repro.core.mappers import RandomMapper

    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    space = MapSpace(GEMM, arch)

    plain = EvaluationEngine(cm, GEMM, arch, metric="edp")
    ref = RandomMapper(samples=200, seed=3).search(
        space, cm, "edp", engine=plain
    )
    assert ref.best_mapping is not None

    seeded = EvaluationEngine(cm, GEMM, arch, metric="edp")
    seeded.seed_incumbent = ref.best_metric * 2.0  # a sound upper bound
    res = RandomMapper(samples=200, seed=3).search(
        space, cm, "edp", engine=seeded
    )
    assert res.best_metric == ref.best_metric
    assert res.best_mapping.to_dict() == ref.best_mapping.to_dict()
    assert seeded.stats.seeded_batches > 0
    assert seeded.stats.pruned >= plain.stats.pruned  # never prunes less


def test_seed_incumbent_too_optimistic_prunes_everything():
    """An absurdly low seed bounds out every candidate: the search comes
    back empty (the CALLER's cue to retry unseeded) rather than silently
    returning a worse-than-seed mapping."""
    from repro.core.mappers import RandomMapper

    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    space = MapSpace(GEMM, arch)
    eng = EvaluationEngine(cm, GEMM, arch, metric="edp")
    eng.seed_incumbent = 1e-300
    res = RandomMapper(samples=100, seed=5).search(
        space, cm, "edp", engine=eng
    )
    assert res.best_mapping is None
    assert eng.stats.pruned > 0


def test_seed_incumbent_ignored_by_population_fitness_calls():
    """Genetic full-fitness batches (incumbent=inf, no probe) must never
    consume the seed -- every individual needs a true score."""
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    ref = union_opt(GEMM, arch, mapper="genetic", cost_model="timeloop")

    from repro.core.mappers import GeneticMapper

    space = MapSpace(GEMM, arch)
    eng = EvaluationEngine(cm, GEMM, arch, metric="edp")
    eng.seed_incumbent = 1e-300  # would prune EVERYTHING if consumed
    res = GeneticMapper().search(space, cm, "edp", engine=eng)
    assert res.best_mapping is not None
    assert res.best_metric == ref.search.best_metric
    assert eng.stats.seeded_batches == 0


def test_seed_incumbent_ignored_with_finite_incumbent_or_no_prune():
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    eng = EvaluationEngine(cm, GEMM, arch, metric="edp")
    eng.seed_incumbent = 123.0
    assert eng._seed_for(math.inf, 8) == 123.0
    assert eng._seed_for(50.0, 8) is None  # a real incumbent exists
    assert eng._seed_for(math.inf, 0) is None  # not a probe batch
    eng2 = EvaluationEngine(cm, GEMM, arch, metric="edp", prune=False)
    eng2.seed_incumbent = 123.0
    assert eng2._seed_for(math.inf, 8) is None  # nothing to prune with
    eng.seed_incumbent = math.inf
    assert eng._seed_for(math.inf, 8) is None  # non-finite seed dropped


# --------------------------------------------------------------------- #
# Circuit-breaker hook: degrade -> open, restore -> probe -> closed
# --------------------------------------------------------------------- #
def test_engine_breaker_degrade_open_then_probe_recovers():
    pytest.importorskip("jax")
    from repro.core.cost.analysis import get_context as _ctx_of
    from repro.core.mappers import RandomMapper
    from repro.runtime.fault_tolerance import CircuitBreaker

    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    space = MapSpace(GEMM, arch)
    br = CircuitBreaker(failure_threshold=1, probe_interval=1)
    eng = EvaluationEngine(cm, GEMM, arch, metric="edp", backend="jax",
                           breaker=br)
    ctx = _ctx_of(GEMM, arch)
    prior = ctx._jax_failed
    try:
        ctx._jax_failed = True  # poison: next batch degrades
        res = RandomMapper(samples=64, seed=2).search(
            space, cm, "edp", engine=eng
        )
        assert res.best_mapping is not None  # numpy path kept answering
        assert eng.backend == "numpy"
        assert eng.stats.backend_fallbacks == 1
        assert br.state == CircuitBreaker.OPEN

        # fault cleared + breaker admits the probe: jax path re-armed
        ctx._jax_failed = False
        assert eng.maybe_restore_backend() is True
        assert eng.backend == "jax" and br.state == CircuitBreaker.HALF_OPEN
        before = eng.stats.fused_dispatches
        res2 = RandomMapper(samples=64, seed=4).search(
            space, cm, "edp", engine=eng
        )
        assert res2.best_mapping is not None
        assert eng.stats.fused_dispatches > before  # real jax evidence
        assert br.state == CircuitBreaker.CLOSED
        assert br.recovered == 1
        assert br.transitions == [
            "closed->open", "open->half_open", "half_open->closed"
        ]
    finally:
        ctx._jax_failed = prior


def test_maybe_restore_backend_noop_paths():
    from repro.runtime.fault_tolerance import CircuitBreaker

    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    # no breaker: PR 6's one-way degradation is preserved
    plain = EvaluationEngine(cm, GEMM, arch, backend="numpy")
    assert plain.maybe_restore_backend() is False
    # breaker attached but the backend never degraded: nothing to do
    br = CircuitBreaker(failure_threshold=1, probe_interval=1)
    jax_eng = EvaluationEngine(cm, GEMM, arch, backend="jax", breaker=br)
    if jax_eng.backend == "jax":  # may auto-degrade where jax is absent
        assert jax_eng.maybe_restore_backend() is False
    # degraded with the circuit still open and no probe due: denied
    br2 = CircuitBreaker(failure_threshold=1, probe_interval=3)
    eng = EvaluationEngine(cm, GEMM, arch, backend="jax", breaker=br2)
    eng.backend = "numpy"
    br2.record_failure()
    assert br2.state == CircuitBreaker.OPEN
    assert eng.maybe_restore_backend() is False  # denied call 1 of 3
    assert eng.backend == "numpy"
