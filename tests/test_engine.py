"""EvaluationEngine correctness: cache/bound/batch paths must be exactly
the direct cost-model evaluation, and pruning must never discard a
candidate better than the incumbent."""

import random

import pytest

from repro.core.architecture import cloud_accelerator, edge_accelerator
from repro.core.cost import (
    EvaluationEngine,
    MaestroLikeModel,
    TimeloopLikeModel,
    TPURooflineModel,
    mapping_signature,
)
from repro.core.cost.analysis import get_context
from repro.core.mapspace import MapSpace
from repro.core.optimizer import union_opt
from repro.core.problem import Problem

GEMM = Problem.gemm(64, 32, 16, word_bytes=1)
CONV = Problem.conv2d(2, 8, 8, 7, 7, 3, 3, stride=2, name="conv_t", word_bytes=1)
MODELS = [TimeloopLikeModel, MaestroLikeModel, TPURooflineModel]


def _costs_equal(a, b):
    return (
        a.latency_cycles == b.latency_cycles
        and a.energy_pj == b.energy_pj
        and a.utilization == b.utilization
        and a.macs == b.macs
        and a.frequency_hz == b.frequency_hz
        and a.breakdown == b.breakdown
    )


@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", MODELS)
def test_engine_bitwise_identical_to_direct_evaluate(problem, model_cls):
    """Engine-cached results == direct cost_model.evaluate, bit for bit,
    for all three cost models on GEMM and CONV."""
    arch = edge_accelerator()
    cm = model_cls()
    space = MapSpace(problem, arch)
    rng = random.Random(0)
    eng = EvaluationEngine(cm, problem, arch, metric="edp")
    mappings = [space.random_mapping(rng) for _ in range(30)]
    genomes = [space.random_genome(rng) for _ in range(30)]
    for m in mappings:
        assert _costs_equal(eng.evaluate(m), cm.evaluate(problem, m, arch))
    # second pass: served from cache, still identical
    hits_before = eng.stats.cache_hits
    for m in mappings:
        assert _costs_equal(eng.evaluate(m), cm.evaluate(problem, m, arch))
    assert eng.stats.cache_hits >= hits_before + len(mappings)
    # genome candidates and the batch path agree too
    costs = eng.evaluate_batch(genomes)
    for g, c in zip(genomes, costs):
        assert _costs_equal(c, cm.evaluate(problem, g.to_mapping(), arch))


@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", MODELS)
def test_lower_bound_never_exceeds_true_cost(problem, model_cls):
    """Seeded property test: the admission bound is a true lower bound, so
    pruning can never discard a candidate better than the incumbent."""
    arch = cloud_accelerator()
    cm = model_cls()
    space = MapSpace(problem, arch)
    ctx = get_context(problem, arch)
    rng = random.Random(1234)
    for metric in ("edp", "latency", "energy"):
        eng = EvaluationEngine(cm, problem, arch, metric=metric)
        for _ in range(120):
            g = space.random_genome(rng)
            m = g.to_mapping()
            true = cm.evaluate(problem, m, arch).metric(metric)
            lb = eng.lower_bound(m)
            assert lb <= true + 1e-12 * max(1.0, abs(true)), (
                model_cls.__name__,
                metric,
            )
            # chain-level bound (genome fast path) matches the sig bound
            fn = cm.lower_bound_chains_fn(problem, arch)
            if fn is not None:
                assert fn(g.chain_list, g.orders) == cm.lower_bound_fn(
                    problem, arch
                )(g.signature(ctx.dims))


@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", [TimeloopLikeModel, MaestroLikeModel])
def test_pruned_candidates_cannot_beat_incumbent(problem, model_cls):
    arch = cloud_accelerator()
    cm = model_cls()
    space = MapSpace(problem, arch)
    rng = random.Random(7)
    eng = EvaluationEngine(cm, problem, arch, metric="edp")
    incumbent = cm.evaluate(problem, space.random_mapping(rng), arch).metric("edp")
    pruned_seen = 0
    for _ in range(200):
        g = space.random_genome(rng)
        c = eng.evaluate_admit(g, incumbent)
        true = cm.evaluate(problem, g.to_mapping(), arch).metric("edp")
        if c is None:
            pruned_seen += 1
            assert true >= incumbent  # never prunes an improver
        else:
            assert c.metric("edp") == true
    assert pruned_seen > 0  # the filter actually engages on this workload
    assert eng.stats.pruned == pruned_seen


def test_bound_pruned_search_identical_to_unpruned():
    """Search with cache+bound on == search with both off: same best cost."""
    arch = cloud_accelerator()
    for mapper in ("random", "genetic", "heuristic", "exhaustive"):
        kw = {"max_mappings": 400} if mapper == "exhaustive" else {}
        on = union_opt(GEMM, arch, mapper=mapper, cost_model="timeloop", **kw)
        off = union_opt(
            GEMM, arch, mapper=mapper, cost_model="timeloop",
            engine_prune=False, engine_cache=1, **kw,
        )
        assert on.cost.edp == off.cost.edp, mapper
        assert on.mapping.to_dict() == off.mapping.to_dict(), mapper


def test_signature_canonicalizes_equivalent_orders():
    arch = edge_accelerator()
    space = MapSpace(GEMM, arch)
    m = space.random_mapping(random.Random(5))
    dims = tuple(GEMM.dims)
    for lm in m.levels:  # declared order = problem order at every level
        lm.temporal_order = dims
    sig1 = mapping_signature(m, dims)
    m2 = m.clone()
    # an empty declared order normalizes to problem order: same signature
    m2.levels[0].temporal_order = ()
    assert mapping_signature(m2, dims) == sig1


def test_search_counters_reported():
    arch = cloud_accelerator()
    sol = union_opt(
        dnn := Problem.gemm(128, 64, 64, word_bytes=1), arch,
        mapper="random", cost_model="timeloop", samples=600,
    )
    res = sol.search
    assert res.pruned > 0
    assert res.analyzed > 0
    assert res.candidates == res.evaluated + res.pruned
    assert res.evals_per_s > 0
    gen = union_opt(dnn, arch, mapper="genetic", cost_model="timeloop")
    assert gen.search.cache_hits > 0


def test_engine_batch_dedups_within_batch():
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    space = MapSpace(GEMM, arch)
    g = space.random_genome(random.Random(3))
    eng = EvaluationEngine(cm, GEMM, arch)
    costs = eng.evaluate_batch([g, g, g])
    assert eng.stats.evaluated == 1
    assert all(c is costs[0] for c in costs)


def test_engine_worker_pool_matches_serial():
    """Optional process-pool fan-out returns the same costs (skipped
    gracefully if the sandbox forbids subprocesses)."""
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    space = MapSpace(GEMM, arch)
    rng = random.Random(9)
    ms = [space.random_mapping(rng) for _ in range(16)]
    serial = EvaluationEngine(cm, GEMM, arch)
    pooled = EvaluationEngine(cm, GEMM, arch, workers=2)
    try:
        got = pooled.evaluate_batch(ms)
        want = serial.evaluate_batch(ms)
        for a, b in zip(got, want):
            assert _costs_equal(a, b)
    finally:
        pooled.close()
