"""Fault-tolerant concurrent sweep executor (``repro.core.sweep_exec``):
retry/deadline/degradation fault matrix, crash-safe journal resume
(including a real SIGKILL + byte-identity check), and the underlying
watchdog/retry primitives from ``repro.runtime.fault_tolerance``."""

import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.core.architecture import edge_accelerator
from repro.core.cost import ResultStore
from repro.core.cost.store import SweepJournal
from repro.core.optimizer import SweepTask, union_opt_sweep
from repro.core.problem import Problem
from repro.core.sweep_exec import FaultSpec, task_fingerprint
from repro.runtime.fault_tolerance import (
    CallTimeoutError,
    CircuitBreaker,
    RetryPolicy,
    RetryStats,
    StragglerMeter,
    backoff_delay,
    call_with_deadline,
    retry_call,
)

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _tasks():
    """3 groups (distinct problems) x 2 tasks each; small enough that the
    whole matrix runs in a couple of seconds."""
    tasks = []
    for i, (m, n, k) in enumerate([(64, 64, 64), (128, 64, 32), (96, 48, 64)]):
        p = Problem.gemm(m, n, k, name=f"sweepexec-g{i}")
        arch = edge_accelerator(aspect=(16, 16))
        tasks.append(SweepTask(p, arch, mapper="random", cost_model="timeloop",
                               metric="edp", mapper_kw={"samples": 200}))
        tasks.append(SweepTask(p, arch, mapper="heuristic",
                               cost_model="timeloop", metric="edp"))
    return tasks


def _shape(sweep):
    """Comparable view of a sweep's solutions: cost + mapping only."""
    return [(s.cost.edp, s.mapping.to_dict()) for s in sweep]


# ------------------------------------------------------------------ #
# fault-spec grammar
# ------------------------------------------------------------------ #
def test_fault_spec_parse_and_checks():
    fs = FaultSpec.parse("fail:1@0; hang:2@1:0.25; jaxfail:0; kill-after:3")
    with pytest.raises(RuntimeError):
        fs.check_fail(1, 0)
    fs.check_fail(1, 1)  # only attempt 0 fails
    fs.check_fail(0, 0)
    assert fs.hang_s(2, 1) == 0.25
    assert fs.hang_s(2, 0) == 0.0
    assert fs.hang_s(0, 0) == 0.0
    assert 0 in fs.jaxfail and 1 not in fs.jaxfail
    assert fs.kill_after == 3
    # hang without explicit seconds gets the default
    assert FaultSpec.parse("hang:0@0").hang_s(0, 0) == 5.0
    empty = FaultSpec.parse(None)
    assert not empty.fails and not empty.hangs and empty.kill_after is None


def test_fault_spec_rejects_bad_clause():
    with pytest.raises(ValueError):
        FaultSpec.parse("explode:1@0")
    with pytest.raises(ValueError):
        FaultSpec.parse("fail:one@0")


def test_slow_spec_parse_and_accessor():
    fs = FaultSpec.parse("slow:1@0:0.25; slow:2@1")
    assert fs.slow_s(1, 0) == 0.25
    assert fs.slow_s(1, 1) == 0.0  # only attempt 0 is slowed
    assert fs.slow_s(2, 1) == 1.0  # default seconds
    assert fs.slow_s(0, 0) == 0.0
    empty = FaultSpec.parse(None)
    assert not empty.slows and empty.slow_s(0, 0) == 0.0


def test_slow_injection_completes_and_converges_to_baseline():
    """``slow`` stretches a group's wall clock but never its results:
    unlike ``hang`` the work COMPLETES, so no retry/timeout machinery
    fires and the sweep is bit-identical to the unslowed baseline."""
    tasks = _tasks()
    baseline = union_opt_sweep(tasks)
    t0 = time.monotonic()
    slowed = union_opt_sweep(tasks, fault_spec="slow:1@0:0.4")
    wall = time.monotonic() - t0
    assert _shape(slowed) == _shape(baseline)
    assert slowed.stats["retries"] == 0
    assert slowed.stats["timeouts"] == 0
    assert wall >= 0.4  # the injected latency really was served


# ------------------------------------------------------------------ #
# failure matrix: every injected path converges to baseline results
# ------------------------------------------------------------------ #
def test_injected_fail_and_hang_converge_to_baseline():
    tasks = _tasks()
    baseline = union_opt_sweep(tasks)
    faulty = union_opt_sweep(
        tasks,
        fault_spec="fail:1@0;hang:2@0:2",
        group_timeout_s=0.5,
        max_group_retries=2,
        group_backoff_s=0.0,
    )
    assert _shape(faulty) == _shape(baseline)
    st = faulty.stats
    assert st["retries"] >= 2  # one for the raise, one for the hang
    assert st["timeouts"] >= 1
    assert st["attempts"] >= len(st["group_wall"]) + 2


def test_fail_spec_exhausts_retry_budget():
    tasks = _tasks()
    with pytest.raises(RuntimeError, match="injected failure"):
        union_opt_sweep(tasks, fault_spec="fail:0@0;fail:0@1",
                        max_group_retries=1, group_backoff_s=0.0)


def test_thread_pool_matches_serial():
    tasks = _tasks()
    serial = union_opt_sweep(tasks, workers=1)
    threaded = union_opt_sweep(tasks, workers=2, pool="thread")
    assert _shape(threaded) == _shape(serial)
    assert threaded.stats["pool"] == "thread"
    assert serial.stats["pool"] == "serial"


def test_jax_failure_degrades_to_numpy_bit_identical(monkeypatch):
    tasks = _tasks()
    baseline = union_opt_sweep(tasks, engine_backend="numpy")
    monkeypatch.setenv("UNION_FAULT_JAX", "1")
    degraded = union_opt_sweep(tasks, engine_backend="jax")
    assert _shape(degraded) == _shape(baseline)
    assert degraded.stats["backend_fallbacks"] >= len(
        degraded.stats["group_wall"]
    )
    assert degraded.stats["engine_backend"] == "jax"  # what was REQUESTED


def test_jaxfail_spec_hits_only_named_group(monkeypatch):
    # spec-level injection flips one group's ctx, not the global env
    tasks = _tasks()
    baseline = union_opt_sweep(tasks, engine_backend="numpy")
    degraded = union_opt_sweep(tasks, engine_backend="jax",
                               fault_spec="jaxfail:0")
    assert _shape(degraded) == _shape(baseline)
    assert degraded.stats["backend_fallbacks"] >= 1


# ------------------------------------------------------------------ #
# journal + resume
# ------------------------------------------------------------------ #
def test_journal_resume_replays_groups(tmp_path):
    tasks = _tasks()
    jpath = tmp_path / "sweep_journal.json"
    first = union_opt_sweep(tasks, journal=str(jpath))
    assert jpath.exists()
    resumed = union_opt_sweep(tasks, journal=str(jpath), resume=True)
    assert _shape(resumed) == _shape(first)
    assert resumed.stats["replayed_groups"] == len(first.stats["group_wall"])
    # replayed search stats match byte-for-byte in deterministic mode
    os.environ["UNION_DETERMINISTIC_STATS"] = "1"
    try:
        assert [s.search.stats_dict() for s in resumed] == [
            s.search.stats_dict() for s in first
        ]
    finally:
        del os.environ["UNION_DETERMINISTIC_STATS"]


def test_journal_without_resume_starts_fresh(tmp_path):
    tasks = _tasks()
    jpath = tmp_path / "sweep_journal.json"
    union_opt_sweep(tasks, journal=str(jpath))
    fresh = union_opt_sweep(tasks, journal=str(jpath))  # no resume
    assert fresh.stats["replayed_groups"] == 0


def test_corrupt_journal_discarded(tmp_path):
    jpath = tmp_path / "bad_journal.json"
    jpath.write_text("{not json")
    j = SweepJournal(jpath, resume=True)
    assert j.corrupt == 1 and not j.resumed
    assert not j.groups and not j.tasks
    jpath.write_text(json.dumps({"version": 999, "groups": {}, "tasks": {}}))
    j = SweepJournal(jpath, resume=True)
    assert j.corrupt == 1 and not j.resumed


_DRIVER = '''
import json, sys
sys.path.insert(0, {src!r})
from repro.core.architecture import edge_accelerator
from repro.core.cost import ResultStore
from repro.core.optimizer import SweepTask, union_opt_sweep
from repro.core.problem import Problem

def main():
    out, journal, store_dir, resume = sys.argv[1:5]
    tasks = []
    for i, (m, n, k) in enumerate(
        [(64, 64, 64), (128, 64, 32), (96, 48, 64), (80, 80, 40)]
    ):
        p = Problem.gemm(m, n, k, name=f"killres-g{{i}}")
        tasks.append(SweepTask(p, edge_accelerator(aspect=(16, 16)),
                               mapper="random", cost_model="timeloop",
                               metric="edp", mapper_kw={{"samples": 300}}))
    store = ResultStore(store_dir) if store_dir != "-" else None
    sweep = union_opt_sweep(tasks, result_store=store,
                            journal=None if journal == "-" else journal,
                            resume=resume == "1")
    rows = [{{"edp": s.cost.edp, "mapping": s.mapping.to_dict(),
              "search": s.search.stats_dict()}} for s in sweep]
    with open(out, "w") as f:
        json.dump({{"rows": rows, "sweep": sweep.stats}}, f, indent=1)
    if store is not None:
        store.flush()
        with open(out + ".store", "w") as f:
            json.dump(store.stats_dict(), f)

if __name__ == "__main__":
    main()
'''


def _run_driver(script, args, env_extra, cwd):
    env = dict(os.environ, UNION_DETERMINISTIC_STATS="1", **env_extra)
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    )
    return subprocess.run([sys.executable, str(script)] + args, env=env,
                          cwd=cwd, capture_output=True, text=True,
                          timeout=300)


def test_sigkill_then_resume_is_byte_identical(tmp_path):
    """The acceptance drill: a sweep SIGKILLed right after its 2nd
    journal flush, resumed with the same journal + store, must emit
    byte-identical figure JSON to an uninterrupted run -- and the resumed
    half must run WARM against the store the killed run populated."""
    script = tmp_path / "driver.py"
    script.write_text(_DRIVER.format(src=SRC))
    jpath, spath = str(tmp_path / "journal.json"), str(tmp_path / "store")

    r = _run_driver(script, ["ref.json", "-", "-", "0"], {}, tmp_path)
    assert r.returncode == 0, r.stderr[-2000:]

    r = _run_driver(script, ["never.json", jpath, spath, "0"],
                    {"UNION_FAULT_SPEC": "kill-after:2"}, tmp_path)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-2000:])
    assert Path(jpath).exists()
    assert not (tmp_path / "never.json").exists()

    # kill-after:2 fires between the 2nd group's store flush and its
    # journal record -- the journal holds 1 done group, the store holds 2
    r = _run_driver(script, ["resumed.json", jpath, spath, "1"], {}, tmp_path)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "replaying 1/4" in (r.stdout + r.stderr)

    ref = (tmp_path / "ref.json").read_bytes()
    resumed = (tmp_path / "resumed.json").read_bytes()
    assert ref == resumed
    store_stats = json.loads((tmp_path / "resumed.json.store").read_text())
    assert store_stats["hits"] > 0  # killed run's flushed Costs were reused


# ------------------------------------------------------------------ #
# store hardening
# ------------------------------------------------------------------ #
def test_stale_store_tmp_cleaned_at_flush(tmp_path):
    sdir = tmp_path / "store"
    sdir.mkdir()
    stale = sdir / ".deadspace.999.cafef00d.tmp"
    stale.write_text("{}")
    store = ResultStore(sdir)
    tasks = _tasks()[:1]
    union_opt_sweep(tasks, result_store=store)
    store.flush()
    assert not stale.exists()
    assert store.stats_dict()["stale_tmps"] >= 1


# ------------------------------------------------------------------ #
# fingerprints
# ------------------------------------------------------------------ #
def test_task_fingerprint_stable_and_slot_unique():
    p = Problem.gemm(64, 64, 64, name="fp")
    arch = edge_accelerator(aspect=(16, 16))
    f0 = task_fingerprint("gk", p, arch, ("random", {"samples": 10}),
                         None, None, 0)
    assert f0 == task_fingerprint("gk", p, arch, ("random", {"samples": 10}),
                                  None, None, 0)
    assert f0 != task_fingerprint("gk", p, arch, ("random", {"samples": 10}),
                                  None, None, 1)
    assert f0 != task_fingerprint("gk", p, arch, ("random", {"samples": 11}),
                                  None, None, 0)
    # set-valued fields canonicalize: equal sets, equal fingerprints
    fa = task_fingerprint("gk", p, arch, ("random", {"dims": {"a", "b", "c"}}),
                          None, None, 0)
    fb = task_fingerprint("gk", p, arch, ("random", {"dims": {"c", "b", "a"}}),
                          None, None, 0)
    assert fa == fb


# ------------------------------------------------------------------ #
# watchdog/retry primitives
# ------------------------------------------------------------------ #
def test_retry_call_retries_then_succeeds():
    stats = RetryStats()
    seen = []

    def fn(attempt):
        seen.append(attempt)
        if attempt < 2:
            raise RuntimeError("flaky")
        return "ok"

    out, _ = retry_call(fn, RetryPolicy(max_retries=3, backoff_s=0.0),
                        label="t", stats=stats)
    assert out == "ok"
    assert seen == [0, 1, 2]
    assert stats.retries == 2 and stats.attempts == 3
    assert stats.timeouts == 0


def test_retry_call_exhausts_and_raises():
    stats = RetryStats()

    def fn(attempt):
        raise RuntimeError(f"always (attempt {attempt})")

    with pytest.raises(RuntimeError, match="always"):
        retry_call(fn, RetryPolicy(max_retries=2, backoff_s=0.0),
                   label="t", stats=stats)
    assert stats.attempts == 3 and stats.retries == 2
    assert len(stats.errors) == 3


def test_call_with_deadline_times_out():
    with pytest.raises(CallTimeoutError):
        call_with_deadline(lambda: time.sleep(2), 0.1, label="hang")
    assert call_with_deadline(lambda: 42, 5.0, label="fast") == 42
    assert call_with_deadline(lambda: 7, None, label="inline") == 7


def test_backoff_delay_is_deterministic_and_label_diverse():
    pol = RetryPolicy(max_retries=3, backoff_s=0.1, jitter=0.25)
    a1 = backoff_delay(pol, 1, "group0")
    assert a1 == backoff_delay(pol, 1, "group0")  # deterministic
    assert a1 != backoff_delay(pol, 1, "group1")  # labels de-synchronize
    assert backoff_delay(pol, 2, "group0") > 0
    assert backoff_delay(RetryPolicy(backoff_s=0.0), 1, "x") == 0.0


def test_circuit_breaker_opens_after_threshold():
    br = CircuitBreaker(failure_threshold=3, probe_interval=2)
    assert br.state == CircuitBreaker.CLOSED
    br.record_failure()
    br.record_failure()
    assert br.state == CircuitBreaker.CLOSED  # below threshold
    assert br.allow()
    br.record_failure()
    assert br.state == CircuitBreaker.OPEN
    assert br.opened == 1
    assert br.transitions == ["closed->open"]


def test_circuit_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(failure_threshold=2, probe_interval=2)
    br.record_failure()
    br.record_success()  # streak broken
    br.record_failure()
    assert br.state == CircuitBreaker.CLOSED
    br.record_failure()
    assert br.state == CircuitBreaker.OPEN


def test_circuit_breaker_probe_schedule_is_count_based():
    br = CircuitBreaker(failure_threshold=1, probe_interval=3)
    br.record_failure()
    assert br.state == CircuitBreaker.OPEN
    # denied, denied, then the 3rd call is admitted as the probe
    assert br.allow() is False
    assert br.allow() is False
    assert br.allow() is True
    assert br.state == CircuitBreaker.HALF_OPEN
    assert br.probes == 1 and br.denied == 2
    # only ONE probe in flight: further calls are denied while half-open
    assert br.allow() is False
    br.record_success()
    assert br.state == CircuitBreaker.CLOSED
    assert br.recovered == 1
    assert br.transitions == [
        "closed->open", "open->half_open", "half_open->closed"
    ]


def test_circuit_breaker_failed_probe_reopens():
    br = CircuitBreaker(failure_threshold=1, probe_interval=1)
    br.record_failure()
    assert br.allow() is True  # probe admitted immediately (interval 1)
    br.record_failure()  # the probe lost
    assert br.state == CircuitBreaker.OPEN
    assert br.opened == 2
    # the schedule restarts: the next allow is a fresh probe
    assert br.allow() is True
    br.record_success()
    assert br.state == CircuitBreaker.CLOSED


def test_circuit_breaker_cooldown_uses_injected_clock():
    now = [0.0]
    br = CircuitBreaker(failure_threshold=1, probe_interval=1,
                        cooldown_s=10.0, clock=lambda: now[0])
    br.record_failure()
    assert br.allow() is False  # inside the cooldown window
    now[0] = 10.5
    assert br.allow() is True  # cooldown elapsed -> count-based probe
    assert br.state == CircuitBreaker.HALF_OPEN


def test_circuit_breaker_rejects_bad_params_and_caps_transitions():
    with pytest.raises(ValueError):
        CircuitBreaker(failure_threshold=0)
    with pytest.raises(ValueError):
        CircuitBreaker(probe_interval=0)
    br = CircuitBreaker(failure_threshold=1, probe_interval=1)
    for _ in range(100):  # open -> half-open -> open forever
        br.record_failure()
        br.allow()
    assert len(br.transitions) <= 64
    st = br.stats_dict()
    assert st["state"] == br.state and st["opened"] == br.opened


def test_straggler_meter_flags_outliers():
    m = StragglerMeter(window=10, slack=3.0)
    assert m.note(1.0) is False  # no history yet
    for _ in range(5):
        assert m.note(1.0) is False
    assert m.note(10.0) is True
    assert m.flagged == 1
    assert m.note(1.0) is False  # the outlier raised the average, 1.0 is fine


# ------------------------------------------------------------------ #
# deterministic stats mode
# ------------------------------------------------------------------ #
def test_deterministic_stats_subset(monkeypatch):
    tasks = _tasks()[:2]
    sweep = union_opt_sweep(tasks)
    full = sweep[0].search.stats_dict()
    assert "elapsed_s" in full and "evaluated" in full
    monkeypatch.setenv("UNION_DETERMINISTIC_STATS", "1")
    det = sweep[0].search.stats_dict()  # stats_dict reads the env per call
    assert set(det) == {"considered", "backend_fallbacks", "elapsed_s",
                        "evals_per_s"}
    assert det["elapsed_s"] == 0.0 and det["evals_per_s"] == 0.0
    assert det["considered"] == full["considered"]
    # the sweep-level aggregate is fixed at run time: a det-mode RUN
    # strips the run-variant ledger (walls, timings)
    det_sweep = union_opt_sweep(tasks)
    agg = det_sweep.stats
    assert agg["elapsed_s"] == 0.0
    assert "group_wall" not in agg  # walls are run-variant
