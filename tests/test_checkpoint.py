"""Checkpoint: roundtrip, atomicity, GC, async manager, elastic restore."""

import json
import os
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,), jnp.bfloat16)},
        "opt": {"step": jnp.int32(7), "m": {"w": jnp.ones((8, 16))}},
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_roundtrip(tmp_path):
    t = tree()
    save(tmp_path, 5, t, extra={"loss": 1.5})
    got, step, extra = restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 5 and extra["loss"] == 1.5
    assert_tree_equal(t, got)


def test_latest_step_and_multiple(tmp_path):
    for s in (1, 3, 2):
        save(tmp_path, s, tree(s))
    assert latest_step(tmp_path) == 3
    got, step, _ = restore(tmp_path, jax.eval_shape(lambda: tree()))
    assert step == 3
    assert_tree_equal(tree(3), got)


def test_incomplete_tmp_dir_ignored(tmp_path):
    """Atomicity: a crashed writer's tmp dir is never restored from."""
    save(tmp_path, 1, tree(1))
    fake = tmp_path / "step_000000009.tmp-deadbeef"
    fake.mkdir()
    (fake / "000000.npy").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 1
    # even a completed-looking dir without a manifest is skipped
    nomanifest = tmp_path / "step_000000008"
    nomanifest.mkdir()
    assert latest_step(tmp_path) == 1


def test_restore_shape_mismatch_raises(tmp_path):
    save(tmp_path, 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        restore(tmp_path, {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)})


def test_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, every=10, async_save=True)
    for s in (10, 20, 30, 40):
        assert mgr.should_save(s)
        mgr.save(s, tree(s))
    mgr.wait()
    steps = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert steps == ["step_000000030", "step_000000040"]
    got, step, _ = mgr.restore_latest(jax.eval_shape(lambda: tree()))
    assert step == 40


def test_manager_surfaces_async_errors(tmp_path):
    mgr = CheckpointManager(tmp_path / "sub", keep=1, async_save=True)
    mgr.save(1, tree())
    mgr.wait()
    # poison: point the manager at a path occupied by a FILE, so the
    # background writer's mkdir fails (chmod tricks don't stop root)
    blocked = tmp_path / "blocked"
    blocked.write_text("not a directory")
    mgr.directory = blocked
    mgr.save(2, tree())
    with pytest.raises(Exception):
        mgr.wait()


def test_elastic_restore_resharding(tmp_path):
    """Save unsharded, restore under an explicit (1-device) NamedSharding --
    the same code path reshards onto any larger mesh."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save(tmp_path, 1, t)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    got, _, _ = restore(tmp_path, jax.eval_shape(lambda: t), shardings=sh)
    assert got["w"].sharding.spec == P("data", "model")
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


def test_train_resume_is_bitwise_consistent(tmp_path):
    """Integration: train 6 steps straight == train 3, restore, train 3."""
    from repro.configs.base import get_config
    from repro.launch import steps as steps_mod
    from repro.data import SyntheticLM
    from repro.optim.optimizers import adamw

    cfg = get_config("qwen3-0.6b_smoke")
    opt = adamw(1e-3)
    step_fn = jax.jit(steps_mod.make_train_step(cfg, opt, remat=False))
    src = SyntheticLM(cfg.vocab, seed=0)

    def batch(i):
        return {"tokens": jnp.asarray(src.batch(i, 2, 16)["tokens"])}

    s_a = steps_mod.make_init_state(cfg, opt)(jax.random.PRNGKey(0))
    for i in range(6):
        s_a, _ = step_fn(s_a, batch(i))

    s_b = steps_mod.make_init_state(cfg, opt)(jax.random.PRNGKey(0))
    for i in range(3):
        s_b, _ = step_fn(s_b, batch(i))
    save(tmp_path, 3, s_b)
    s_c, start, _ = restore(tmp_path, jax.eval_shape(lambda: s_b))
    for i in range(start, 6):
        s_c, _ = step_fn(s_c, batch(i))

    for x, y in zip(jax.tree.leaves(s_a["params"]), jax.tree.leaves(s_c["params"])):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), rtol=1e-5, atol=1e-6
        )
