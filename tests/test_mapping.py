"""Union mapping abstraction + the paper's four legality rules (Sec. IV-D)."""

import pytest

from repro.core.architecture import edge_accelerator, tpu_chip
from repro.core.mapping import LevelMapping, Mapping
from repro.core.problem import Problem


def small_gemm():
    return Problem.gemm(32, 16, 8)


def edge():
    return edge_accelerator()  # DRAM / L2 / V2(16@Y) / PE(16@X)


def mk(problem, arch, chain, orders=None):
    return Mapping.from_tiles(problem, arch, chain, orders)


def legal_mapping(problem, arch):
    """Hand-built legal mapping: parallelize m over V2's 16, n over PE's 16."""
    full = dict(problem.dims)
    return mk(
        problem, arch,
        [
            full, full,                                   # DRAM: stream whole
            full, dict(full, m=full["m"] // 16),          # L2 -> V2: m spatial x16
            dict(full, m=full["m"] // 16),                # V2 temporal
            dict(full, m=full["m"] // 16, n=full["n"] // 16),  # V2 -> PE: n x16
            dict(m=1, n=1, k=1), dict(m=1, n=1, k=1),     # PE: elementwise
        ],
    )


def test_legal_mapping_is_legal():
    p, a = small_gemm(), edge()
    m = legal_mapping(p, a)
    assert m.violations(p, a) == []
    assert m.total_parallelism(p) == 256
    assert m.utilization(p, a) == 1.0


def test_trivial_mapping_legal_and_serial():
    p, a = small_gemm(), edge()
    m = Mapping.trivial(p, a)
    assert m.is_legal(p, a)
    assert m.total_parallelism(p) == 1


def test_rule_r2_fanout_violation():
    p, a = small_gemm(), edge()
    m = legal_mapping(p, a)
    # demand x32 parallelism at the V2 level (fanout is 16)
    m.levels[1].spatial_tile_sizes["m"] = 1  # TT=32, ST=1 -> par 32
    errs = m.violations(p, a)
    assert any("R2" in e for e in errs)


def test_rule_r1_inner_tile_exceeds_spatial():
    p, a = small_gemm(), edge()
    m = legal_mapping(p, a)
    # inner temporal tile bigger than this level's spatial tile
    m.levels[2].temporal_tile_sizes["m"] = 32
    errs = m.violations(p, a)
    assert any("R1" in e for e in errs)


def test_rule_r3_memory_violation():
    p = Problem.gemm(4096, 4096, 4096)
    a = edge()  # L2 = 100 KB
    full = dict(p.dims)
    m = mk(p, a, [full, full, full, full, full, full,
                  dict(m=1, n=1, k=1), dict(m=1, n=1, k=1)])
    errs = m.violations(p, a)
    assert any("R3" in e for e in errs)  # 3 x 16M won't fit 100KB L2


def test_rule_r4_divisibility():
    p, a = small_gemm(), edge()
    m = legal_mapping(p, a)
    m.levels[1].temporal_tile_sizes["m"] = 5  # 32 % 5 != 0
    errs = m.violations(p, a)
    assert any("R4" in e for e in errs)


def test_innermost_cannot_parallelize():
    p, a = small_gemm(), edge()
    m = legal_mapping(p, a)
    m.levels[-1].temporal_tile_sizes["m"] = 2  # TT != ST at leaf
    errs = m.violations(p, a)
    assert any("innermost" in e for e in errs)


def test_concurrent_spatial_dims_same_level():
    """The paper's key expressiveness claim: distribute M and N at the SAME
    cluster level concurrently (memory-target abstractions cannot)."""
    p, a = small_gemm(), edge()
    full = dict(p.dims)
    m = mk(
        p, a,
        [
            full, full,
            full, dict(full, m=full["m"] // 4, n=full["n"] // 4),  # m AND n at V2
            dict(full, m=full["m"] // 4, n=full["n"] // 4),
            dict(full, m=full["m"] // 4, n=full["n"] // 4),
            dict(m=1, n=1, k=1), dict(m=1, n=1, k=1),
        ],
    )
    # V2 level distributes both dims: fanout 4*4 = 16 == child fanout
    assert m.parallelism(1, p) == 16
    assert m.is_legal(p, a)
    nest = m.loop_nest_str(p)
    assert "spatial_for" in nest and "concurrent" in nest


def test_serialization_roundtrip():
    p, a = small_gemm(), edge()
    m = legal_mapping(p, a)
    m2 = Mapping.from_json(m.to_json())
    assert m2.to_dict() == m.to_dict()
    assert m2.is_legal(p, a)


def test_steps_times_parallelism_covers_iteration_space():
    p, a = small_gemm(), edge()
    m = legal_mapping(p, a)
    total = 1
    for i in range(len(m.levels)):
        total *= m.steps(i, p) * m.parallelism(i, p)
    leaf_tile = 1
    for d in p.dims:
        leaf_tile *= m.levels[-1].st(d)
    assert total * leaf_tile == p.iteration_space
