import os

# Tests see ONE device (the dry-run sets its own 512-device flag in-process;
# never set that here -- see the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
