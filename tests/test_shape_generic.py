"""Shape-generic fused cores: the ONE-program-per-shape-class machinery
must be bit-identical to the per-context closures it replaces, and the
process-wide program cache must actually be shared -- a second problem in
the same shape class adds zero traces and zero warmup buckets."""

import math
import random

import numpy as np
import pytest

from repro.codesign import CalibrationScale
from repro.core.architecture import cloud_accelerator, edge_accelerator
from repro.core.cost import (
    EvaluationEngine,
    MaestroLikeModel,
    TimeloopLikeModel,
)
from repro.core.cost.analysis import (
    _make_generic_fused_core,
    get_context,
    global_trace_count,
    reset_trace_registry,
)
from repro.core.genome_batch import random_genome_batch
from repro.core.mapspace import MapSpace
from repro.core.problem import Problem

GEMM = Problem.gemm(64, 32, 16, word_bytes=1)
# same shape class as GEMM (ranks/levels/data-space structure), different
# content (dim sizes, word widths) -- the sharing tests hinge on this pair
GEMM_B = Problem.gemm(128, 64, 48, word_bytes=2)
CONV = Problem.conv2d(2, 8, 8, 7, 7, 3, 3, stride=2, name="conv_t", word_bytes=1)
MODELS = [TimeloopLikeModel, MaestroLikeModel]


def _stacked(problem, arch, seed, B=24):
    space = MapSpace(problem, arch)
    gb = random_genome_batch(space, np.random.default_rng(seed), B)
    return gb.stacked()


def _generic_out(cm, problem, arch, sb, metric, incumbent=math.inf):
    """Run the shape-generic fused core with xp=numpy (no jax involved:
    this isolates the generic ALGEBRA from the jit machinery)."""
    ctx = get_context(problem, arch)
    generic = cm.batch_cost_terms_generic(problem, arch)
    assert generic is not None, f"{cm.name} lost its generic terms hook"
    model_key, model_params, terms = generic
    p = dict(ctx.shape_params())
    p.update(model_params)
    core = _make_generic_fused_core(ctx.shape_class_key(), terms, metric, np, None)
    return core(sb.tt, sb.st, sb.perm, incumbent, p)


def _context_out(cm, problem, arch, sb, metric, incumbent=math.inf):
    """The per-context fused core (the pre-generic path) on numpy."""
    ctx = get_context(problem, arch)
    lb_builder = cm.batch_admit_core_builder(problem, arch)
    terms = cm.batch_cost_terms_fn(problem, arch)
    assert lb_builder is not None and terms is not None
    core = ctx._make_fused_core(np, None, lb_builder, terms, metric)
    return core(sb.tt, sb.st, sb.perm, incumbent)


def _assert_fused_equal(g, c):
    g_admit, g_lbmx, g_lat, g_en, g_ut, g_smx, g_extras = g
    c_admit, c_lbmx, c_lat, c_en, c_ut, c_smx, c_extras = c
    assert np.array_equal(np.asarray(g_admit), np.asarray(c_admit))
    assert np.array_equal(np.asarray(g_lat), np.asarray(c_lat))
    assert np.array_equal(np.asarray(g_en), np.asarray(c_en))
    assert np.array_equal(np.asarray(g_ut), np.asarray(c_ut))
    # extras shared by both paths must agree bit for bit too (the generic
    # core ADDS lb_cycles/lb_energy/metric_score on top)
    for k in set(g_extras) & set(c_extras):
        assert np.array_equal(np.asarray(g_extras[k]), np.asarray(c_extras[k])), k


@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", MODELS)
@pytest.mark.parametrize(
    "mk_arch", [edge_accelerator, cloud_accelerator], ids=["edge", "cloud"]
)
@pytest.mark.parametrize("metric", ["edp", "latency", "energy"])
def test_generic_core_bit_identical_to_per_context(problem, model_cls, mk_arch, metric):
    """Generic fused core (values as a parameter pack) == per-context
    fused core (values baked into the closure), bit for bit, on numpy --
    across randomized candidate batches and a real finite incumbent."""
    arch = mk_arch()
    cm = model_cls()
    for seed in (0, 7, 23):
        sb = _stacked(problem, arch, seed)
        g = _generic_out(cm, problem, arch, sb, metric)
        c = _context_out(cm, problem, arch, sb, metric)
        _assert_fused_equal(g, c)
        # admission compares the LOWER-BOUND scores against the incumbent,
        # so a median lb score makes the admit bits non-trivial
        lb_cyc = np.asarray(g[6]["lb_cycles"])
        lb_en = np.asarray(g[6]["lb_energy"])
        if metric == "latency":
            lb_scores = lb_cyc
        elif metric == "energy":
            lb_scores = lb_en
        else:
            lb_scores = (lb_en * 1e-12) * (lb_cyc / arch.frequency_hz)
        inc = float(np.median(lb_scores))
        g2 = _generic_out(cm, problem, arch, sb, metric, incumbent=inc)
        c2 = _context_out(cm, problem, arch, sb, metric, incumbent=inc)
        _assert_fused_equal(g2, c2)
        admit = np.asarray(g2[0])
        assert not admit.all(), "median lb incumbent should reject some rows"
        if np.unique(lb_scores).size > 1:
            assert admit.any(), "median lb incumbent should admit some rows"


@pytest.mark.parametrize("model_cls", MODELS)
def test_generic_core_calibrated_scale_bit_identical(model_cls):
    """With a (non-power-of-two) calibration attached, the traced
    ``calib_scale`` parameter reproduces the per-context calibrated path
    bit for bit -- the same program serves every calibration value."""
    arch = cloud_accelerator()
    cm = model_cls().set_calibration(CalibrationScale(1.7, 1, "test"))
    sb = _stacked(GEMM, arch, 3)
    g = _generic_out(cm, GEMM, arch, sb, "edp")
    c = _context_out(cm, GEMM, arch, sb, "edp")
    _assert_fused_equal(g, c)
    # and the scale really is in effect: raw model differs
    raw = _generic_out(model_cls(), GEMM, arch, sb, "edp")
    assert not np.array_equal(np.asarray(g[2]), np.asarray(raw[2]))


@pytest.mark.parametrize("model_cls", MODELS)
def test_one_generic_program_serves_the_shape_class(model_cls):
    """GEMM and GEMM_B share a shape class; ONE generic core object fed
    each problem's parameter pack must reproduce each problem's own
    per-context core bit for bit."""
    arch = cloud_accelerator()
    cm = model_cls()
    ctx_a = get_context(GEMM, arch)
    ctx_b = get_context(GEMM_B, arch)
    skey = ctx_a.shape_class_key()
    assert skey == ctx_b.shape_class_key()
    _key, _params_a, terms_a = cm.batch_cost_terms_generic(GEMM, arch)
    core = _make_generic_fused_core(skey, terms_a, "edp", np, None)
    for problem, ctx in ((GEMM, ctx_a), (GEMM_B, ctx_b)):
        _mk, model_params, _t = cm.batch_cost_terms_generic(problem, arch)
        p = dict(ctx.shape_params())
        p.update(model_params)
        sb = _stacked(problem, arch, 11)
        g = core(sb.tt, sb.st, sb.perm, math.inf, p)
        c = _context_out(cm, problem, arch, sb, "edp")
        _assert_fused_equal(g, c)


# ------------------------------------------------------------------ #
# jitted path (jax required from here on)
# ------------------------------------------------------------------ #


def _costs_equal(a, b):
    return (
        a.latency_cycles == b.latency_cycles
        and a.energy_pj == b.energy_pj
        and a.utilization == b.utilization
        and a.macs == b.macs
        and a.frequency_hz == b.frequency_hz
        and a.breakdown == b.breakdown
    )


def _engine_costs(cm, problem, arch, backend, seed=5, B=32):
    eng = EvaluationEngine(cm, problem, arch, metric="edp", backend=backend)
    gb = random_genome_batch(
        MapSpace(problem, arch), np.random.default_rng(seed), B
    )
    costs = eng.evaluate_batch(gb)
    assert all(c is not None for c in costs)
    return eng, costs


@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", MODELS)
def test_jax_generic_engine_matches_numpy(problem, model_cls):
    """Engine results through the jitted shape-generic runner ==
    numpy-backend engine results, bit for bit (incl. breakdowns)."""
    pytest.importorskip("jax")
    arch = cloud_accelerator()
    eng_np, costs_np = _engine_costs(model_cls(), problem, arch, "numpy")
    eng_jx, costs_jx = _engine_costs(model_cls(), problem, arch, "jax")
    assert eng_jx.backend == "jax" and not eng_jx._ctx._jax_failed
    for a, b in zip(costs_np, costs_jx):
        assert _costs_equal(a, b)


@pytest.mark.parametrize("model_cls", MODELS)
def test_calibrated_jax_engine_matches_numpy(model_cls):
    """Calibrated models keep the fused jax path and stay bit-identical
    to the numpy engine (the scale is a final multiply on both)."""
    pytest.importorskip("jax")
    arch = cloud_accelerator()
    mk = lambda: model_cls().set_calibration(CalibrationScale(1.7, 1, "test"))
    eng_np, costs_np = _engine_costs(mk(), GEMM, arch, "numpy")
    eng_jx, costs_jx = _engine_costs(mk(), GEMM, arch, "jax")
    assert not eng_jx._ctx._jax_failed
    for a, b in zip(costs_np, costs_jx):
        assert _costs_equal(a, b)
    assert all("calibration_scale" in c.breakdown for c in costs_jx)


def test_second_problem_in_class_adds_zero_traces():
    """After GEMM traces the generic program, a content-different problem
    in the SAME shape class (GEMM_B) dispatches with ZERO new traces --
    one compiled program per (shape class, model, metric)."""
    pytest.importorskip("jax")
    reset_trace_registry()
    arch = cloud_accelerator()
    eng_a, _ = _engine_costs(TimeloopLikeModel(), GEMM, arch, "jax", B=32)
    assert not eng_a._ctx._jax_failed
    assert eng_a.stats.n_traces >= 1
    before = global_trace_count()
    eng_b, costs_b = _engine_costs(TimeloopLikeModel(), GEMM_B, arch, "jax", B=32)
    assert not eng_b._ctx._jax_failed
    assert global_trace_count() == before
    assert eng_b.stats.n_traces == 0
    # and the shared program still produces exact results for problem B
    _, costs_np = _engine_costs(TimeloopLikeModel(), GEMM_B, arch, "numpy", B=32)
    for a, b in zip(costs_np, costs_b):
        assert _costs_equal(a, b)


def test_warmup_covers_the_whole_shape_class():
    """One engine's warmup pre-traces the class-wide program buckets; a
    second engine on a same-class problem has nothing left to trace."""
    pytest.importorskip("jax")
    reset_trace_registry()
    arch = cloud_accelerator()
    eng_a = EvaluationEngine(
        TimeloopLikeModel(), GEMM, arch, metric="edp", backend="jax"
    )
    n_a = eng_a.warmup([16, 64])
    assert n_a == 2
    assert eng_a.stats.n_traces == 2
    # repeat warmup on the SAME engine: all buckets already traced
    assert eng_a.warmup([16, 64]) == 0
    eng_b = EvaluationEngine(
        TimeloopLikeModel(), GEMM_B, arch, metric="edp", backend="jax"
    )
    assert eng_b.warmup([16, 64]) == 0
    assert eng_b.stats.n_traces == 0


def test_trace_counter_attributes_per_engine():
    """``EngineStats.n_traces`` is the engine-local delta of the global
    registry: distinct metrics are distinct programs, repeats are free."""
    pytest.importorskip("jax")
    reset_trace_registry()
    arch = edge_accelerator()
    eng, _ = _engine_costs(MaestroLikeModel(), GEMM, arch, "jax", B=16)
    first = eng.stats.n_traces
    assert first >= 1
    # same bucket again: no retrace
    _ = eng.evaluate_batch(
        random_genome_batch(MapSpace(GEMM, arch), np.random.default_rng(9), 16)
    )
    assert eng.stats.n_traces == first
